"""Quickstart: truss-decompose a graph and inspect its dense cores.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.graphs.gen import rmat_edges
from repro.core import truss_pkt, compute_support
from repro.graphs.csr import build_csr


def main():
    # 1. build a skewed social-network-like graph (R-MAT, 2^10 vertices)
    edges = rmat_edges(scale=10, edge_factor=8, seed=7)
    print(f"graph: {edges.max() + 1} vertices, {len(edges)} edges")

    # 2. trussness of every edge — the paper's PKT algorithm
    #    (k-core reordering happens inside, exactly like the paper)
    truss = truss_pkt(edges, reorder=True)

    # 3. the decomposition is a hierarchy: k-trusses nest
    hist = np.bincount(truss)
    for k in np.nonzero(hist)[0]:
        print(f"  {hist[k]:6d} edges in the {k}-class")

    # 4. extract the maximal-k truss (the densest cohesive subgraph)
    kmax = int(truss.max())
    core_edges = edges[truss == kmax]
    verts = np.unique(core_edges)
    print(f"max truss: k={kmax} with {len(core_edges)} edges on "
          f"{len(verts)} vertices")

    # 5. support (triangles per edge) is the paper's other primitive
    g = build_csr(edges)
    S = compute_support(g)
    print(f"total triangles: {int(S.sum()) // 3}")


if __name__ == "__main__":
    main()
