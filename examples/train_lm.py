"""End-to-end training driver example (deliverable b): train a ~135M-class
model for a few hundred steps with checkpointing and an injected failure.

By default uses a width-reduced smollm so a laptop CPU finishes in minutes;
pass --full for the real 135M config (slow on CPU, same code path).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = [
        "--arch", "smollm_135m",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--ckpt-every", "50",
        "--ckpt-dir", args.ckpt_dir,
        "--log-every", "10",
        # fault-tolerance demo: one injected failure mid-run; the driver
        # restores from the last checkpoint and replays deterministically
        "--fail-at-step", str(args.steps * 2 // 3),
    ]
    if not args.full:
        argv.append("--reduced")
    train_main(argv)


if __name__ == "__main__":
    main()
