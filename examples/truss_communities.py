"""Community detection via truss decomposition (paper's motivating use case).

k-trusses as communities, served from the hierarchy index (DESIGN.md §11):
a graph opened as a persistent ``TrussEngine`` handle carries a lazily-built
*truss community index* — for every level k, the triangle-connected
components of the edges with trussness >= k, nested into a hierarchy with
parent links.  Queries (``handle.communities(k)``,
``handle.community(edge_or_vertex, k)``) read the index; the ad-hoc per-k
union-find this example used to run on the host is now just the index's
parity oracle (``hier_mode="host"``).

The batched single-read path is still shown: a stream of ego-net-style
windows goes through ``submit``/``result`` tickets, bucketed by size class
and decomposed in vmapped dispatches.  And the index *survives updates*:
an edge-churn batch through ``TrussEngine.update`` remaps the untouched
levels instead of rebuilding them.

    PYTHONPATH=src python examples/truss_communities.py
"""

import time

import numpy as np

from repro.graphs.gen import ring_of_cliques_edges, rmat_edges
from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.core import pkt, truss_trilist
from repro.core.pkt import align_to_input
from repro.serve.truss_engine import TrussEngine


def main():
    eng = TrussEngine(mode="chunked")

    # planted communities: 12 cliques of 12, chained in a ring
    E_ring = ring_of_cliques_edges(12, 12)
    # a noisier instance: RMAT social-like graph
    E_rmat = rmat_edges(scale=9, edge_factor=10, seed=3)
    # "traffic": a stream of small ego-net-ish windows of the RMAT graph
    rng = np.random.default_rng(0)
    windows = []
    for _ in range(8):
        lo = int(rng.integers(0, max(1, E_rmat.shape[0] - 400)))
        windows.append(E_rmat[lo:lo + 400])

    # single-read tickets for the window stream (bucketed + vmapped)
    t0 = time.perf_counter()
    tickets = [eng.submit(w) for w in windows]
    eng.flush()
    dt = time.perf_counter() - t0
    print(f"engine: {len(tickets)} window graphs in {dt:.3f}s "
          f"({eng.throughput:.1f} graphs/s across "
          f"{len(eng.stats['buckets'])} buckets)")

    # persistent handles for the graphs we'll query communities on
    h_ring = eng.open(E_ring)
    h_rmat = eng.open(E_rmat)

    # cross-check the engine against the single-graph engines
    n = int(E_ring.max()) + 1
    E_r = relabel(E_ring, degeneracy_order(E_ring, n))
    g = build_csr(E_r, n)
    res = pkt(g)
    assert np.array_equal(align_to_input(res.trussness, g, E_r, n),
                          eng.map([E_r])[0])
    assert np.array_equal(truss_trilist(g), res.trussness)
    print("engines agree (batched == pkt == trilist)")

    # k-truss communities for k = 12: exactly the planted cliques
    k = 12
    comms = h_ring.communities(k)
    print(f"{k}-truss communities: {len(comms)} (planted: 12)")
    assert len(comms) == 12
    assert all(c.shape[0] == 66 for c in comms)  # K12 = 66 edges each
    # and the device index agrees bitwise with the host union-find oracle
    hier = h_ring.hierarchy()
    oracle = h_ring.hierarchy(mode="host")
    assert all(np.array_equal(hier.level_labels(kk), oracle.level_labels(kk))
               for kk in hier.levels)
    print("index parity: device label-prop == host union-find")

    # point queries: the community around one edge / all around one vertex
    c_edge = h_ring.community(tuple(h_ring.edges[0]), k)
    c_vert = h_ring.community(0, k)
    print(f"community of edge {tuple(h_ring.edges[0])} at k={k}: "
          f"{c_edge.shape[0]} edges; vertex 0 sits in {len(c_vert)} "
          f"{k}-truss communities")

    # community-size spectrum of the RMAT instance at several k
    for k in (3, 4, 6, 8):
        comms = h_rmat.communities(k)
        if not comms:
            continue
        sizes = sorted((c.shape[0] for c in comms), reverse=True)
        print(f"k={k}: {sum(sizes):6d} edges, {len(comms):4d} communities, "
              f"largest {sizes[:3]}")

    # the index survives updates: churn a few low-trussness fringe edges —
    # the repair stays local and the untouched (higher) levels remap
    h_rmat.hierarchy().build_all()
    cur = h_rmat.edges
    fringe = cur[np.argsort(h_rmat.trussness)[:2]]
    st = eng.update(h_rmat, remove_edges=fringe)
    hier = h_rmat.hierarchy()
    print(f"update ({st.mode}): index carried "
          f"{hier.stats['remapped_levels']} levels by remap, "
          f"{sum(lv is None for lv in hier._labels)} rebuilt lazily")

    # per-window max trussness (the "serving" answer a caller would read)
    tws = [int(eng.result(t).max(initial=2)) for t in tickets]
    print(f"window t_max spectrum: {sorted(tws)}")


if __name__ == "__main__":
    main()
