"""Community detection via truss decomposition (paper's motivating use case).

k-trusses as community seeds: peel to a target k, take connected components
of the surviving edges.  The decomposition now goes through the batched
``TrussEngine``: the planted-communities graph, an RMAT instance, and a batch
of per-"user" ego-net-style subgraphs are all submitted to one engine, which
buckets them by padded size class and decomposes each bucket in a single
vmapped dispatch.  Single-graph engines (PKT, triangle-list) cross-check the
engine's output.

    PYTHONPATH=src python examples/truss_communities.py
"""

import time

import numpy as np

from repro.graphs.gen import ring_of_cliques_edges, rmat_edges
from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.core import pkt, truss_trilist
from repro.core.pkt import align_to_input
from repro.serve.truss_engine import TrussEngine


def connected_components(edges: np.ndarray, n: int) -> np.ndarray:
    """Union-find over an edge list."""
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in edges:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
    return np.array([find(v) for v in range(n)])


def communities(edges: np.ndarray, trussness: np.ndarray, k: int):
    """Vertex sets of the k-truss components."""
    keep = trussness >= k
    if keep.sum() == 0:
        return keep, np.zeros(0, np.int64)
    n = int(edges.max()) + 1
    comp = connected_components(edges[keep], n)
    verts = np.unique(edges[keep])
    sizes = np.sort(np.bincount(comp[verts]))[::-1]
    return keep, sizes[sizes > 0]


def main():
    eng = TrussEngine(mode="chunked")

    # planted communities: 12 cliques of 12, chained in a ring
    E_ring = ring_of_cliques_edges(12, 12)
    # a noisier instance: RMAT social-like graph
    E_rmat = rmat_edges(scale=9, edge_factor=10, seed=3)
    # "traffic": a stream of small ego-net-ish windows of the RMAT graph
    rng = np.random.default_rng(0)
    windows = []
    for _ in range(8):
        lo = int(rng.integers(0, max(1, E_rmat.shape[0] - 400)))
        windows.append(E_rmat[lo:lo + 400])

    t0 = time.perf_counter()
    tickets = [eng.submit(E_ring), eng.submit(E_rmat)]
    tickets += [eng.submit(w) for w in windows]
    eng.flush()
    dt = time.perf_counter() - t0
    print(f"engine: {len(tickets)} graphs in {dt:.3f}s "
          f"({eng.throughput:.1f} graphs/s across "
          f"{len(eng.stats['buckets'])} buckets)")

    t_ring = eng.result(tickets[0])
    t_rmat = eng.result(tickets[1])

    # cross-check the engine against the single-graph engines
    n = int(E_ring.max()) + 1
    E_r = relabel(E_ring, degeneracy_order(E_ring, n))
    g = build_csr(E_r, n)
    res = pkt(g)
    assert np.array_equal(align_to_input(res.trussness, g, E_r, n),
                          eng.map([E_r])[0])
    assert np.array_equal(truss_trilist(g), res.trussness)
    print("engines agree (batched == pkt == trilist)")

    # extract k-truss communities for k = 12: exactly the planted cliques
    k = 12
    _, sizes = communities(E_ring, t_ring, k)
    print(f"{k}-truss communities: {len(sizes)} (planted: 12)")
    assert len(sizes) == 12
    assert int(t_ring.max()) == 12

    # community-size spectrum of the RMAT instance at several k
    for k in (3, 4, 6, 8):
        keep, sizes = communities(E_rmat, t_rmat, k)
        if sizes.size == 0:
            continue
        print(f"k={k}: {keep.sum():6d} edges, {len(sizes):4d} communities, "
              f"largest {sizes[:3]}")

    # per-window max trussness (the "serving" answer a caller would read)
    tws = [int(eng.result(t).max(initial=2)) for t in tickets[2:]]
    print(f"window t_max spectrum: {sorted(tws)}")


if __name__ == "__main__":
    main()
