"""Community detection via truss decomposition (paper's motivating use case).

k-trusses as community seeds: peel to a target k, take connected components
of the surviving edges. Compares the PKT engine against the triangle-list
variant and the distributed engine on the same graph.

    PYTHONPATH=src python examples/truss_communities.py
"""

import time

import numpy as np

from repro.graphs.gen import ring_of_cliques_edges, rmat_edges
from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.core import pkt, truss_trilist, pkt_dist


def connected_components(edges: np.ndarray, n: int) -> np.ndarray:
    """Union-find over an edge list."""
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in edges:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
    return np.array([find(v) for v in range(n)])


def main():
    # planted communities: 12 cliques of 12, chained in a ring
    E = ring_of_cliques_edges(12, 12)
    n = int(E.max()) + 1
    E = relabel(E, degeneracy_order(E, n))
    g = build_csr(E, n)

    t0 = time.perf_counter()
    res = pkt(g)
    print(f"PKT: {time.perf_counter() - t0:.3f}s, t_max={res.trussness.max()}")

    # cross-check with the two other engines
    assert np.array_equal(truss_trilist(g), res.trussness)
    assert np.array_equal(pkt_dist(g, chunk=1 << 10), res.trussness)
    print("engines agree (pkt == trilist == dist)")

    # extract k-truss communities for k = 12: exactly the planted cliques
    k = 12
    keep = res.trussness >= k
    comp = connected_components(g.El[keep], g.n)
    labels = np.unique(comp[np.unique(g.El[keep])])
    print(f"{k}-truss communities: {len(labels)} (planted: 12)")
    assert len(labels) == 12

    # a noisier instance: RMAT + report community-size spectrum at several k
    E = rmat_edges(scale=9, edge_factor=10, seed=3)
    n = int(E.max()) + 1
    E = relabel(E, degeneracy_order(E, n))
    g = build_csr(E, n)
    res = pkt(g)
    for k in (3, 4, 6, 8):
        keep = res.trussness >= k
        if keep.sum() == 0:
            continue
        comp = connected_components(g.El[keep], g.n)
        verts = np.unique(g.El[keep])
        sizes = np.sort(np.bincount(comp[verts]))[::-1]
        sizes = sizes[sizes > 0]
        print(f"k={k}: {keep.sum():6d} edges, {len(sizes):4d} communities, "
              f"largest {sizes[:3]}")


if __name__ == "__main__":
    main()
