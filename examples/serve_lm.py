"""Batched serving example (deliverable b): prefill + decode a request batch.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main


def main():
    # a batch of 8 requests through a reduced qwen3 (GQA + qk-norm path)
    serve_main(["--arch", "qwen3_8b", "--reduced", "--requests", "8",
                "--prompt-len", "16", "--gen", "24"])
    # and the SSM family (state-based decode, no KV cache)
    serve_main(["--arch", "falcon_mamba_7b", "--reduced", "--requests", "4",
                "--prompt-len", "16", "--gen", "16"])


if __name__ == "__main__":
    main()
