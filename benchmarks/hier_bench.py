"""Community-index build cost, query latency, and device/host label parity.

The serving claim of the hierarchy index (DESIGN.md §11): build once per
decomposition — the device path sweeps levels finest-first, warm-starting
each from the previous and skipping proven-converged levels (§16) — then
answer community queries many times without touching the decomposition
pipeline again.  For each graph this bench times:

  * ``index_build_*_seconds`` — ``TrussHierarchy.build_all()`` per mode
    (device label propagation warm vs the host union-find oracle),
  * ``query_*_seconds`` — per-call latency of the handle query API
    (``communities(k)`` once the index is warm, and per-edge
    ``community(edge, k)`` lookups),
  * ``parity`` — bitwise equality of every level's labels between the two
    builders, which is the CI ``bench-trend`` gate: any device/host label
    mismatch exits nonzero.

Output: ``BENCH_hier.json``.

  PYTHONPATH=src python -m benchmarks.hier_bench [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _bench_graph(name: str, queries: int) -> dict:
    from repro.core.hierarchy import TrussHierarchy
    from repro.graphs.datasets import named_graph
    from repro.serve.truss_engine import TrussEngine

    E = named_graph(name)
    eng = TrussEngine()
    h = eng.open(E)

    # device build: one timed cold build_all (includes the jit compile),
    # then best-of-3 warm rebuilds on fresh indexes (compiled executable
    # reused) — matching the best-of convention of the other benches.
    t0 = time.perf_counter()
    hier_dev = h.hierarchy(mode="device").build_all()
    t_dev_cold = time.perf_counter() - t0

    def _best_of(build, reps: int = 3) -> tuple[float, object]:
        best, built = float("inf"), None
        for _ in range(reps):
            t0 = time.perf_counter()
            built = build()
            best = min(best, time.perf_counter() - t0)
        return best, built

    t_dev_warm, _ = _best_of(
        lambda: TrussHierarchy(h._inc.T, h._inc.tri, mode="device")
        .build_all())
    t_host, hier_host = _best_of(
        lambda: TrussHierarchy(h._inc.T, h._inc.tri, mode="host")
        .build_all())

    parity = all(
        np.array_equal(hier_dev.level_labels(k), hier_host.level_labels(k))
        for k in hier_dev.levels)

    # query latency at a mid level, against the warm device index
    k_mid = max(2, (2 + hier_dev.k_max) // 2)
    t0 = time.perf_counter()
    comms = h.communities(k_mid)
    t_comms = time.perf_counter() - t0
    rng = np.random.default_rng(0)
    sample = rng.integers(0, h.m, size=queries)
    E = h.edges                         # hoisted: El copies stay untimed
    t0 = time.perf_counter()
    for eid in sample:
        h.community(tuple(E[int(eid)]), k_mid)
    t_query = (time.perf_counter() - t0) / max(1, queries)

    return {
        "graph": name, "n": h.n, "m": h.m,
        "k_max": hier_dev.k_max,
        "levels": len(list(hier_dev.levels)),
        "triangles": int(h._inc.tri.shape[0]),
        "index_build_device_seconds": t_dev_cold,
        "index_build_device_warm_seconds": t_dev_warm,
        "index_build_host_seconds": t_host,
        "communities_at_k": {"k": k_mid, "count": len(comms),
                             "seconds": t_comms},
        "query_edge_seconds": t_query,
        "parity": parity,
    }


def run(graphs=("ba-small", "er-small", "rmat-small"), queries: int = 64,
        out_path: str = "BENCH_hier.json") -> int:
    """Run the hierarchy bench suite and write BENCH_hier.json."""
    report = {"bench": "hierarchy-index", "graphs": [], "ok": True}
    for name in graphs:
        g = _bench_graph(name, queries)
        report["graphs"].append(g)
        report["ok"] = report["ok"] and g["parity"]
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    if not report["ok"]:
        print("HIER BENCH FAILED: device/host community-label mismatch",
              file=sys.stderr)
        return 1
    return 0


def rows(quick: bool = True) -> list[str]:
    """benchmarks/run.py adapter: CSV rows from a quick in-memory run."""
    from benchmarks.common import row

    out = []
    for name in ("ba-small",) if quick else ("ba-small", "rmat-small"):
        g = _bench_graph(name, 16)
        out.append(row(
            f"hier/{name}/build-device",
            g["index_build_device_warm_seconds"],
            f"levels={g['levels']};parity={int(g['parity'])}"))
        out.append(row(f"hier/{name}/query-edge", g["query_edge_seconds"],
                       f"k={g['communities_at_k']['k']}"))
    return out


def main() -> None:
    """CLI entry: full suite, or --smoke for the CI parity gate."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph, few queries (the CI parity gate)")
    ap.add_argument("--out", default="BENCH_hier.json")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(run(graphs=("ba-small",), queries=16,
                             out_path=args.out))
    raise SystemExit(run(out_path=args.out))


if __name__ == "__main__":
    main()
