"""Paper Table 3: sequential-ish truss decomposition — PKT vs WC vs Ros.

PKT here is the single-device JAX implementation (the paper's 1-thread
column analogue); WC and Ros follow the paper's algorithms (WC with a hash
table, Ros with array structures + parallel support / serial peel). GWeps =
wedges / time / 1e9 is the paper's rate metric.

Caveat recorded in EXPERIMENTS.md: WC/Ros peels are CPython loops, so the
PKT-vs-WC gap overstates the paper's 8–46× — the *ordering*-driven and
scaling comparisons (Table 2, 4) are the apples-to-apples ones.
"""

from __future__ import annotations

import numpy as np

from repro.core import pkt, truss_wc, truss_ros
from repro.graphs.datasets import GRAPH_SUITE
from benchmarks.common import prep_graph, timeit, row

WC_EDGE_CAP = 60_000      # paper: "did not finish in 1 hour" → we cap
ROS_EDGE_CAP = 300_000


def run(suite=None) -> list[str]:
    """CSV rows: end-to-end decomposition seconds (paper Table 3)."""
    out = []
    for name in suite or GRAPH_SUITE:
        g, stats = prep_graph(name, order="kco")
        def gweps(t):
            return stats["wedges"] / max(t, 1e-12) / 1e9

        t_pkt = timeit(lambda: pkt(g), warmup=1, reps=2)
        res = pkt(g)
        out.append(row(f"table3/{name}/PKT", t_pkt,
                       f"GWeps={gweps(t_pkt):.4f};tmax={res.trussness.max()}"
                       f";sublevels={res.sublevels}"))

        if g.m <= WC_EDGE_CAP:
            t_wc = timeit(lambda: truss_wc(g), warmup=0, reps=1)
            ok = np.array_equal(truss_wc(g), res.trussness)
            out.append(row(f"table3/{name}/WC", t_wc,
                           f"speedup={t_wc / max(t_pkt, 1e-12):.1f}"
                           f";match={ok}"))
        else:
            out.append(f"table3/{name}/WC,DNF,edge_cap")

        if g.m <= ROS_EDGE_CAP:
            t_ros = timeit(lambda: truss_ros(g), warmup=0, reps=1)
            ok = np.array_equal(truss_ros(g), res.trussness)
            out.append(row(f"table3/{name}/Ros", t_ros,
                           f"speedup={t_ros / max(t_pkt, 1e-12):.1f}"
                           f";match={ok}"))
        else:
            out.append(f"table3/{name}/Ros,DNF,edge_cap")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
