"""Paper Table 2: impact of vertex ordering on triangle counting/support.

Columns mirrored: triangle-count time under k-core order (KCO) vs natural
(NAT), the ordering speedup, the oriented work estimate Σ d⁺(v)² under both
orders, the oblivious Σ d(v)², and the k-core + reorder preprocessing times.
"""

from __future__ import annotations

import time

from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.graphs.datasets import named_graph, GRAPH_SUITE
from repro.core.support import compute_support, build_support_table
from repro.core.kcore import kcore_park
from benchmarks.common import timeit, row


def run(suite=None) -> list[str]:
    """CSV rows: support-phase seconds per executor (paper Table 2)."""
    out = []
    for name in suite or GRAPH_SUITE:
        E = named_graph(name)
        n = int(E.max()) + 1

        t0 = time.perf_counter()
        g_nat = build_csr(E, n)
        kcore_park(g_nat)                      # parallel k-core (PKC)
        t_kcore = time.perf_counter() - t0

        t0 = time.perf_counter()
        perm = degeneracy_order(E, n)
        E_kco = relabel(E, perm)
        t_order = time.perf_counter() - t0

        g_kco = build_csr(E_kco, n)
        tab_nat = build_support_table(g_nat)
        tab_kco = build_support_table(g_kco)

        t_nat = timeit(lambda: compute_support(g_nat, tab_nat))
        t_kco = timeit(lambda: compute_support(g_kco, tab_kco))

        w_kco = g_kco.work_estimate_oriented()
        w_nat = g_nat.work_estimate_oriented()
        w_obl = g_nat.work_estimate_oblivious()
        derived = (f"speedup={t_nat / max(t_kco, 1e-12):.2f}"
                   f";work_ratio={w_nat / max(w_kco, 1):.2f}"
                   f";obl_ratio={w_obl / max(w_kco, 1):.2f}"
                   f";kcore_s={t_kcore:.3f};order_s={t_order:.3f}")
        out.append(row(f"table2/{name}/KCO", t_kco, derived))
        out.append(row(f"table2/{name}/NAT", t_nat, ""))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
