# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry: python -m benchmarks.run [--quick|--smoke]

  table2  — ordering impact on support computation      (paper Table 2)
  table3  — PKT vs WC vs Ros decomposition + GWeps      (paper Table 3)
  table4  — parallel scaling over host devices          (paper Table 4/Fig 5)
  fig4    — phase breakdown per peel mode               (paper Fig 4)
  fig6    — per-level time vs trussness distribution    (paper Fig 6)
  engine  — batched multi-graph throughput (graphs/sec)
  inc     — incremental update vs recompute speedup     (DESIGN.md §9)
  hier    — community-index build/query + label parity  (DESIGN.md §11)
  roofline— measured phase GB/s vs the host copy ceiling (§16)
  hillclimb— chunk-policy autotune sweep (feeds auto_chunk, §16)

``--smoke`` is the CI gate: a tiny RMAT graph decomposed by every
(peel mode × support mode) executor pair, Ros, and the numpy oracle;
agreement is asserted (exit 1 on mismatch) and a machine-readable
BENCH_smoke.json is written for workflow artifacts.
"""

import argparse
import json
import sys
import time


def smoke(out_path: str = "BENCH_smoke.json") -> int:
    """Tiny cross-engine agreement gate + timing snapshot. Returns exit code."""
    import numpy as np

    from repro.graphs.gen import rmat_edges
    from repro.graphs.csr import build_csr, relabel, degeneracy_order
    from repro.core import pkt, truss_ros, truss_numpy
    from repro.core.pkt import PEEL_MODES, align_to_input
    from repro.serve.truss_engine import TrussEngine

    E = rmat_edges(6, edge_factor=5, seed=0)
    n = int(E.max()) + 1
    E = relabel(E, degeneracy_order(E, n))
    g = build_csr(E, n)

    report = {"graph": "rmat-6-5", "n": g.n, "m": g.m, "modes": {}, "ok": True}
    ref = truss_numpy(g.El)
    report["t_max"] = int(ref.max(initial=2))

    def check(name, t):
        same = bool(np.array_equal(np.asarray(t, np.int64), ref))
        report["ok"] = report["ok"] and same
        return same

    from repro.core.support import SUPPORT_MODES

    for mode in PEEL_MODES:
        for support_mode in SUPPORT_MODES:
            t0 = time.perf_counter()
            res = pkt(g, mode=mode, support_mode=support_mode,
                      phase_timings=True)
            dt = time.perf_counter() - t0
            key = mode if support_mode == "jnp" \
                else f"{mode}+sup-{support_mode}"
            report["modes"][key] = {
                "seconds": dt, "agrees": check(f"pkt/{key}", res.trussness),
                "levels": res.levels, "sublevels": res.sublevels,
                "phases": {k: round(v, 6) for k, v in res.phases.items()},
            }

    # table_mode axis: host-built tables (the parity oracle) vs the default
    # device builders — phase breakdown shows where table-build time lives.
    # Both runs are warm (the executors compiled above), so the numbers
    # compare steady-state table construction, not jit compiles.
    res_np = pkt(g, table_mode="numpy", phase_timings=True)
    res_dev = pkt(g, table_mode="device", phase_timings=True)
    report["table_modes"] = {
        "device": {k: round(v, 6) for k, v in res_dev.phases.items()},
        "numpy": {k: round(v, 6) for k, v in res_np.phases.items()},
        "agrees": (check("pkt/table-numpy", res_np.trussness)
                   and check("pkt/table-device", res_dev.trussness)),
    }

    t0 = time.perf_counter()
    ros = truss_ros(g)
    report["ros"] = {"seconds": time.perf_counter() - t0,
                     "agrees": check("ros", ros)}

    # batched engine: the same graph plus a truncated copy, order-aligned
    # (engine results align to each submission's own row order, so the
    # g.El-ordered oracle is mapped back to E's rows for comparison)
    ref_rows = align_to_input(np.asarray(ref), g, E, n)
    eng = TrussEngine()
    fleet = [E, E[: max(1, g.m // 2)], E]
    outs = eng.map(fleet)
    eng_ok = (np.array_equal(outs[0], ref_rows)
              and np.array_equal(outs[2], ref_rows)
              and outs[1].shape[0] == fleet[1].shape[0])
    eng.map(fleet)  # second pass hits warm buckets → steady-state throughput
    report["engine"] = {"agrees": bool(eng_ok),
                        "graphs_per_sec": eng.throughput,
                        "buckets": len(eng.stats["buckets"])}
    report["ok"] = report["ok"] and eng_ok

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    if not report["ok"]:
        print("SMOKE FAILED: engine disagreement", file=sys.stderr)
        return 1
    return 0


def main() -> None:
    """CLI entry: run the selected benches, print/write the CSV rows."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small graph suite only")
    ap.add_argument("--smoke", action="store_true",
                    help="CI agreement gate on a tiny graph; writes "
                         "BENCH_smoke.json and exits nonzero on mismatch")
    ap.add_argument("--smoke-out", default="BENCH_smoke.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    args = ap.parse_args()

    if args.smoke:
        raise SystemExit(smoke(args.smoke_out))

    from repro.graphs.datasets import GRAPH_SUITE
    suite = GRAPH_SUITE[:5] if args.quick else GRAPH_SUITE
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (table2_support, table3_decomp, table4_parallel,
                            fig4_phases, fig6_levels, engine_bench, inc_bench,
                            hier_bench, roofline, hillclimb)
    benches = {
        "table2": lambda: table2_support.run(suite),
        "table3": lambda: table3_decomp.run(suite),
        "table4": lambda: table4_parallel.run(
            suite=("rmat-small", "ba-small") if args.quick
            else ("rmat-small", "ba-small", "er-small"),
            device_counts=(1, 2, 4) if args.quick else (1, 2, 4, 8)),
        "fig4": lambda: fig4_phases.run(suite),
        "fig6": lambda: fig6_levels.run(),
        "engine": lambda: engine_bench.run(
            n_graphs=12 if args.quick else 24),
        "roofline": lambda: roofline.run(
            ("ba-small",) if args.quick else None),
        "hillclimb": lambda: hillclimb.rows(quick=args.quick),
        "inc": lambda: inc_bench.rows(quick=args.quick),
        "hier": lambda: hier_bench.rows(quick=args.quick),
    }
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:  # report, keep going
            print(f"{name},ERROR,{type(e).__name__}:{e}", file=sys.stderr)


if __name__ == '__main__':
    main()
