# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry: python -m benchmarks.run [--quick]

  table2  — ordering impact on support computation      (paper Table 2)
  table3  — PKT vs WC vs Ros decomposition + GWeps      (paper Table 3)
  table4  — parallel scaling over host devices          (paper Table 4/Fig 5)
  fig4    — phase breakdown                             (paper Fig 4)
  fig6    — per-level time vs trussness distribution    (paper Fig 6)
  roofline— LM arch × shape roofline terms from dry-run (deliverable g)
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small graph suite only")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    args = ap.parse_args()

    from repro.graphs.datasets import GRAPH_SUITE
    suite = GRAPH_SUITE[:5] if args.quick else GRAPH_SUITE
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (table2_support, table3_decomp, table4_parallel,
                            fig4_phases, fig6_levels, roofline)
    benches = {
        "table2": lambda: table2_support.run(suite),
        "table3": lambda: table3_decomp.run(suite),
        "table4": lambda: table4_parallel.run(
            suite=("rmat-small", "ba-small") if args.quick
            else ("rmat-small", "ba-small", "er-small"),
            device_counts=(1, 2, 4) if args.quick else (1, 2, 4, 8)),
        "fig4": lambda: fig4_phases.run(suite),
        "fig6": lambda: fig6_levels.run(),
        "roofline": lambda: roofline.run(),
    }
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:  # report, keep going
            print(f"{name},ERROR,{type(e).__name__}:{e}", file=sys.stderr)


if __name__ == '__main__':
    main()
