# Retracing-budget gate: python -m benchmarks.retrace_bench [--smoke]
"""Counts jit compile-cache growth at the hot dispatch sites while a
canonical workload runs, and fails (exit 1) when any site exceeds its
budget from ``[tool.trusslint.retrace]`` in pyproject.toml.

The pow2 size-class bucketing contract (DESIGN.md §10/§14) promises a
*bounded* number of XLA compiles per site: one per distinct size class,
never one per graph.  A regression that leaks a dynamic value into a
traced shape (trusslint J002's runtime twin) shows up here as cache
growth on the warm wave — so the warm wave must add exactly zero
compiles on the batch-flush sites.

Sites (name -> jitted callable):
  engine_flush      serve.truss_engine._batched_truss_dev   (device tables)
  engine_flush_host serve.truss_engine._batched_truss       (host tables)
  peel_loop         core.pkt._peel_segment_jit   during full decompositions
  support_build     core.support._support_device_jit
  region_peel       core.pkt._peel_segment_jit   during handle updates

Writes BENCH_retrace.json for workflow artifacts / README linkage.
"""

import argparse
import importlib
import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _scramble(E, seed):
    """Relabel vertices with a seeded permutation: same size class,
    different content — the engine must *not* recompile for it."""
    import numpy as np

    rng = np.random.default_rng(seed)
    perm = rng.permutation(int(E.max()) + 1)
    return perm[E]


def _wave(eng, classes, seed):
    tickets = [eng.submit(_scramble(E, 7 * seed + i))
               for i, E in enumerate(classes)
               for _ in range(2)]
    eng.flush()
    for t in tickets:
        eng.result(t)


def run(out_path: str = "BENCH_retrace.json") -> int:
    """Replay the workload, count compiles, gate against the budgets."""
    import numpy as np

    from repro.analysis import RetraceGuard
    from repro.analysis.config import load_config
    # repro.core re-exports a `pkt` *function*, which shadows the
    # submodule on `import repro.core.pkt as ...`; go through importlib
    pkt_mod = importlib.import_module("repro.core.pkt")
    support_mod = importlib.import_module("repro.core.support")
    truss_inc = importlib.import_module("repro.core.truss_inc")
    from repro.graphs.csr import build_csr, edges_from_arrays
    from repro.graphs.gen import ring_of_cliques_edges
    from repro.serve import truss_engine as te

    budgets = dict(load_config(ROOT).retrace_budgets)
    report = {"ok": True, "sites": {}, "warm_waves": {}, "budgets": budgets}
    t0 = time.perf_counter()

    # deterministic generators: the edge count is a function of the
    # parameters alone, so every scramble of a class lands in the same
    # pow2 bucket (same SizeClass, same stacked batch shape)
    class_a = ring_of_cliques_edges(4, 6)
    class_b = ring_of_cliques_edges(8, 8)
    classes = [class_a, class_b]

    def gate(guard):
        for name, entry in guard.report().items():
            report["sites"][name] = entry
            report["ok"] = report["ok"] and entry["ok"]

    def engine_phase(site, fn, seed0, **eng_kw):
        # cold wave: one executable per size class, gated by the budget;
        # warm wave (same classes, new labels, fresh engine) must hit
        # the jit cache every time — its compile delta is gated at zero
        guard = RetraceGuard(budgets=budgets)
        guard.track(site, fn)
        with guard:
            _wave(te.TrussEngine(**eng_kw), classes, seed=seed0)
        cold_report = guard.report()
        with guard:
            _wave(te.TrussEngine(**eng_kw), classes, seed=seed0 + 1)
        warm_n = guard.compiles(site)
        for name, entry in cold_report.items():
            report["sites"][name] = entry
            report["ok"] = report["ok"] and entry["ok"]
        gate_warm_ok = warm_n == 0
        report["warm_waves"][site] = {"compiles": warm_n,
                                      "ok": gate_warm_ok}
        report["ok"] = report["ok"] and gate_warm_ok

    # -- engine flush: device tables, then the host-built parity path
    engine_phase("engine_flush", te._batched_truss_dev, seed0=0)
    engine_phase("engine_flush_host", te._batched_truss, seed0=2,
                 table_mode="numpy")

    # -- direct pkt(): segmented peel + device support-table build.
    # Two classes cold, then the same graphs again — the repeat pass is
    # covered by the same window; its compiles must already be cached,
    # so the total equals the cold-pass compile count
    graphs = []
    for E in classes:
        g_edges = edges_from_arrays(E[:, 0], E[:, 1])
        graphs.append(build_csr(g_edges, int(g_edges.max()) + 1))
    guardp = RetraceGuard(budgets=budgets)
    guardp.track("peel_loop", pkt_mod._peel_segment_jit)
    guardp.track("support_build", support_mod._support_device_jit)
    with guardp:
        for g in graphs:
            pkt_mod.pkt(g, table_mode="device")
        for g in graphs:
            pkt_mod.pkt(g, table_mode="device")
    gate(guardp)

    # -- incremental update stream: each batch repairs a live region
    # through peel_live_subset -> _peel_segment_jit.  host_peel_max=0
    # forces every region onto the masked device re-peel (the engine's
    # default routes smoke-sized regions to the host path); local_frac=1
    # keeps repairs local so the region path is what actually runs.
    # Region sizes vary per batch but the pow2 compaction keeps the
    # compile count bounded
    inc = truss_inc.IncrementalTruss(class_b, host_peel_max=0,
                                     local_frac=1.0)
    n_b = int(class_b.max()) + 1
    rng = np.random.default_rng(42)
    guardu = RetraceGuard(budgets=budgets)
    guardu.track("region_peel", pkt_mod._peel_segment_jit)
    with guardu:
        for _ in range(4):
            uv = rng.integers(0, n_b, size=(6, 2))
            uv = uv[uv[:, 0] != uv[:, 1]]
            inc.update(add_edges=uv)
    gate(guardu)

    report["seconds"] = round(time.perf_counter() - t0, 3)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    status = "ok" if report["ok"] else "RETRACE BUDGET EXCEEDED"
    for name, entry in sorted(report["sites"].items()):
        print(f"retrace,{name},{entry['compiles']},budget={entry['budget']}")
    for name, entry in sorted(report["warm_waves"].items()):
        print(f"retrace,{name}.warm,{entry['compiles']},budget=0")
    print(f"retrace,total_seconds,{report['seconds']},{status}")
    return 0 if report["ok"] else 1


def main(argv=None) -> int:
    """CLI entry: write BENCH_retrace.json and exit nonzero over budget."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI alias: the workload is already smoke-sized")
    ap.add_argument("--out", default="BENCH_retrace.json")
    args = ap.parse_args(argv)
    del args.smoke
    return run(args.out)


if __name__ == "__main__":
    sys.exit(main())
