"""Shared benchmark helpers: timing, graph suite preparation."""

from __future__ import annotations

import time

from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.graphs.datasets import named_graph


def timeit(fn, *, warmup: int = 1, reps: int = 3) -> float:
    """Best-of-reps wall seconds, after warmup (excludes jit compile)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def prep_graph(name: str, *, order: str = "kco"):
    """named graph → (CSRGraph, stats dict). order: kco | natural."""
    E = named_graph(name)
    n = int(E.max()) + 1 if E.size else 0
    if order == "kco":
        E = relabel(E, degeneracy_order(E, n))
    g = build_csr(E, n)
    stats = {
        "name": name, "n": g.n, "m": g.m,
        "wedges": g.wedge_count(),
        "work_oriented": g.work_estimate_oriented(),
        "work_oblivious": g.work_estimate_oblivious(),
    }
    return g, stats


def row(name: str, seconds: float, derived: str = "") -> str:
    """CSV row in the harness format: name,us_per_call,derived."""
    return f"{name},{seconds * 1e6:.1f},{derived}"
