"""Paper Table 4 + Fig. 5: parallel scaling of the distributed PKT.

XLA host devices are the stand-in for cores: each device count runs in a
subprocess (device count locks at jax init). The measured quantity is the
full decomposition wall time of `pkt_dist` (table-sharded, psum-combined),
mirroring the paper's 1→24-core relative-speedup figure.
"""

from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import row

_CHILD = r"""
import os, sys, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import numpy as np, jax
from repro.graphs.datasets import named_graph
from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.core.pkt_dist import pkt_dist
name = sys.argv[2]
E = named_graph(name)
n = int(E.max()) + 1
E = relabel(E, degeneracy_order(E, n))
g = build_csr(E, n)
t = pkt_dist(g, chunk=1 << 12)            # warmup+compile
t0 = time.perf_counter()
t = pkt_dist(g, chunk=1 << 12)
dt = time.perf_counter() - t0
print(f"RESULT {dt:.4f} {g.wedge_count()}")
"""


def run(suite=("rmat-small", "ba-small", "er-small"),
        device_counts=(1, 2, 4, 8)) -> list[str]:
    """CSV rows: serial-vs-vmapped scaling proxy (paper Table 4)."""
    out = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    for name in suite:
        base = None
        for d in device_counts:
            p = subprocess.run(
                [sys.executable, "-c", _CHILD, str(d), name],
                capture_output=True, text=True, env=env, timeout=900)
            if p.returncode != 0:
                out.append(f"table4/{name}/p{d},ERROR,{p.stderr[-120:]}")
                continue
            line = [l for l in p.stdout.splitlines()
                    if l.startswith("RESULT")][0]
            dt, wedges = float(line.split()[1]), int(line.split()[2])
            base = base or dt
            out.append(row(
                f"table4/{name}/p{d}", dt,
                f"speedup={base / dt:.2f};GWeps={wedges / dt / 1e9:.4f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
