"""Sustained-QPS serving benchmark: latency vs offered load, parity-gated.

The ROADMAP's "millions of users" claim needs a number behind it
(DESIGN.md §12): this bench replays mixed 90/9/1 query/update/open traffic
through the async :class:`~repro.serve.scheduler.TrussScheduler` at a sweep
of offered QPS points and reports p50/p99 latency per request kind.  Every
run is **parity-gated**: the same request schedule is replayed through a
synchronous ``TrussEngine`` and every async result must be bitwise-equal —
query rows, post-churn trussness per handle, and opened-handle trussness.
A mismatch exits nonzero, which is the CI bench-trend gate.

Traffic shape: a fixed pool of open handles takes trussness queries (90 %)
and churn updates (9 %, toggling a reserved extra-edge pool so queried rows
always exist in both replays); 1 % of requests open fresh same-size-class
graphs.  Offered load is paced deterministically (request i enqueues at
``i / qps``); latency is future-completion minus enqueue.

Output: ``BENCH_serve.json`` rows per offered-QPS point.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def build_fleet(n_handles: int, n_extras: int, seed: int):
    """Handle-pool graphs plus, per handle, a disjoint extra-edge churn pool."""
    from repro.graphs.gen import erdos_renyi_edges

    graphs, extras = [], []
    for i in range(n_handles):
        E = erdos_renyi_edges(64, 8.0, seed=seed + i)
        present = {(int(u), int(v)) for u, v in E}
        rng = np.random.default_rng(seed + 1000 + i)
        pool = []
        while len(pool) < n_extras:
            u, v = int(rng.integers(0, 64)), int(rng.integers(0, 64))
            if u == v:
                continue
            e = (min(u, v), max(u, v))
            if e not in present:
                present.add(e)
                pool.append(e)
        graphs.append(E)
        extras.append(pool)
    return graphs, extras


def make_workload(graphs, extras, n_requests: int, seed: int,
                  mix=(0.90, 0.09, 0.01)):
    """A deterministic mixed request schedule (same for async and sync).

    Updates toggle extra-pool edges (tracking presence at generation time),
    so the schedule is valid — removals always hit present edges — and
    queries only touch the never-removed base rows.
    """
    from repro.graphs.gen import erdos_renyi_edges

    rng = np.random.default_rng(seed)
    present = [set() for _ in graphs]
    ops, n_open = [], 0
    for _ in range(n_requests):
        r = rng.random()
        hid = int(rng.integers(0, len(graphs)))
        if r < mix[0]:
            rows = graphs[hid][
                rng.integers(0, graphs[hid].shape[0], size=8)]
            ops.append(("query", hid, rows))
        elif r < mix[0] + mix[1]:
            picks = rng.choice(len(extras[hid]),
                               size=min(4, len(extras[hid])), replace=False)
            add = [extras[hid][j] for j in picks
                   if extras[hid][j] not in present[hid]]
            rem = [extras[hid][j] for j in picks
                   if extras[hid][j] in present[hid]]
            present[hid] |= set(add)
            present[hid] -= set(rem)
            ops.append(("update", hid,
                        np.array(add or np.zeros((0, 2)), np.int64),
                        np.array(rem or np.zeros((0, 2)), np.int64)))
        else:
            ops.append(("open", erdos_renyi_edges(
                64, 8.0, seed=seed + 5000 + n_open)))
            n_open += 1
    return ops


def replay_async(sched, graphs, ops, qps: float):
    """Pace ``ops`` through the scheduler at ``qps``; returns measurements."""
    handles = [sched.open_async(g).result(timeout=600) for g in graphs]
    lat = []          # (op index, kind, seconds) — appended on completion
    futs = []
    t_start = time.perf_counter()
    for i, op in enumerate(ops):
        target = t_start + i / qps
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        kind = op[0]
        t_enq = time.perf_counter()
        if kind == "query":
            f = sched.query_async(handles[op[1]], op[2])
        elif kind == "update":
            f = sched.update_async(handles[op[1]], add_edges=op[2],
                                   remove_edges=op[3])
        else:
            f = sched.open_async(op[1])
        f.add_done_callback(
            lambda f, i=i, k=kind, t=t_enq:
            lat.append((i, k, time.perf_counter() - t)))
        futs.append((i, kind, f))
    results = {i: f.result(timeout=600) for i, _, f in futs}
    duration = time.perf_counter() - t_start
    return handles, results, lat, duration


def replay_sync(engine, graphs, ops):
    """The synchronous oracle: same schedule, same order, caller-thread."""
    handles = [engine.open(g) for g in graphs]
    t0 = time.perf_counter()
    results = {}
    for i, op in enumerate(ops):
        kind = op[0]
        if kind == "query":
            results[i] = handles[op[1]].query(op[2])
        elif kind == "update":
            results[i] = engine.update(handles[op[1]], add_edges=op[2],
                                       remove_edges=op[3])
        else:
            results[i] = engine.open(op[1])
    return handles, results, time.perf_counter() - t0


def check_parity(ops, a_handles, a_results, s_handles, s_results) -> bool:
    """Every async result bitwise-equal to the synchronous engine's."""
    ok = True
    for i, op in enumerate(ops):
        if op[0] == "query":
            ok = ok and np.array_equal(a_results[i], s_results[i])
        elif op[0] == "open":
            ok = ok and np.array_equal(a_results[i].trussness,
                                       s_results[i].trussness)
    for ha, hs in zip(a_handles, s_handles):
        ok = ok and np.array_equal(ha.trussness, hs.trussness)
        ok = ok and np.array_equal(ha.edges, hs.edges)
    return bool(ok)


def _percentiles(lat, kind=None):
    ms = [1e3 * s for _, k, s in lat if kind is None or k == kind]
    if not ms:
        return None
    return {"n": len(ms),
            "p50_ms": float(np.percentile(ms, 50)),
            "p99_ms": float(np.percentile(ms, 99)),
            "mean_ms": float(np.mean(ms)),
            "max_ms": float(np.max(ms))}


def run(qps_points=(50.0, 200.0, 800.0), n_requests: int = 240,
        n_handles: int = 3, n_extras: int = 24, seed: int = 0,
        out_path: str = "BENCH_serve.json") -> int:
    """The bench: one latency row per offered-QPS point, parity-gated."""
    from repro.serve.scheduler import TrussScheduler
    from repro.serve.truss_engine import TrussEngine

    graphs, extras = build_fleet(n_handles, n_extras, seed)
    report = {"bench": "serve-scheduler", "mix": {"query": 0.90,
              "update": 0.09, "open": 0.01},
              "n_handles": n_handles, "m_per_graph": int(graphs[0].shape[0]),
              "rows": [], "ok": True}

    # warmup: pay the open/update/query compiles outside the timed window
    warm = TrussEngine()
    wh = warm.open(graphs[0])
    warm.update(wh, add_edges=np.array([extras[0][0]], np.int64))
    wh.query(graphs[0][:4])

    for qps in qps_points:
        ops = make_workload(graphs, extras, n_requests, seed)
        sched = TrussScheduler(max_batch=16, max_delay_ms=2.0,
                               max_queue=1 << 20, max_inflight=1 << 20)
        a_handles, a_results, lat, duration = replay_async(
            sched, graphs, ops, qps)
        sched_stats = sched.stats()
        sched.close()

        s_engine = TrussEngine()
        s_handles, s_results, sync_seconds = replay_sync(
            s_engine, graphs, ops)
        parity = check_parity(ops, a_handles, a_results,
                              s_handles, s_results)
        report["ok"] = report["ok"] and parity
        row = {
            "offered_qps": qps,
            "achieved_qps": n_requests / duration,
            "duration_seconds": duration,
            "sync_replay_seconds": sync_seconds,
            "n_requests": n_requests,
            "shed": sched_stats["counters"]["shed"],
            "dispatches": sched_stats["counters"]["dispatches"],
            "coalesced_updates": sched_stats["counters"]["coalesced_updates"],
            "latency": {k: _percentiles(lat, None if k == "all" else k)
                        for k in ("all", "query", "update", "open")},
            "stages": sched_stats["stages"],
            "parity": parity,
        }
        report["rows"].append(row)

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    if not report["ok"]:
        print("SERVE BENCH FAILED: async/sync parity regression",
              file=sys.stderr)
        return 1
    return 0


def rows(quick: bool = True) -> list[str]:
    """benchmarks/run.py adapter: CSV rows from a quick in-memory run."""
    import io
    from contextlib import redirect_stdout

    from benchmarks.common import row

    buf = io.StringIO()
    path = "BENCH_serve.json"
    with redirect_stdout(buf):
        code = run(qps_points=(100.0,) if quick else (50.0, 200.0),
                   n_requests=120 if quick else 240, out_path=path)
    with open(path) as f:
        rep = json.load(f)
    out = []
    for r in rep["rows"]:
        q = r["latency"]["query"] or {}
        out.append(row(
            f"serve/qps-{r['offered_qps']:.0f}",
            q.get("mean_ms", 0.0) / 1e3,
            f"p50={q.get('p50_ms', 0):.2f}ms;p99={q.get('p99_ms', 0):.2f}ms"
            f";achieved={r['achieved_qps']:.0f}qps"
            f";parity={int(r['parity'])};exit={code}"))
    return out


def main() -> None:
    """CLI entry: ``--smoke`` is the CI parity gate on a small schedule."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small QPS point, quick parity gate (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--qps", type=float, nargs="*", default=None,
                    help="override the offered-QPS sweep")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(run(qps_points=tuple(args.qps or (150.0,)),
                             n_requests=120, n_handles=2, seed=args.seed,
                             out_path=args.out))
    raise SystemExit(run(qps_points=tuple(args.qps or (50.0, 200.0, 800.0)),
                         seed=args.seed, out_path=args.out))


if __name__ == "__main__":
    main()
