"""Paper Fig. 4: breakdown of PKT execution among phases.

Phases mirrored: support computation / SCAN+processing (peel) — plus the
wedge-table construction our shape-static SPMD adaptation adds (DESIGN.md
§7.3), reported honestly as its own phase.
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import support as support_mod
from repro.core.pkt import _pkt_peel_jit, _pad_tables
from repro.graphs.datasets import GRAPH_SUITE
from benchmarks.common import prep_graph, timeit, row


def run(suite=None) -> list[str]:
    out = []
    for name in suite or GRAPH_SUITE:
        g, stats = prep_graph(name, order="kco")

        t0 = time.perf_counter()
        stab = support_mod.build_support_table(g)
        ptab = support_mod.build_peel_table(g)
        t_tables = time.perf_counter() - t0

        t_support = timeit(lambda: support_mod.compute_support(g, stab))
        S0 = support_mod.compute_support(g, stab)

        chunk = min(1 << 14, max(1, ptab.size))
        tabs = _pad_tables(ptab, g.m, chunk)
        n_chunks = tabs.e1.shape[0] // chunk
        N, Eid, S0j = jnp.asarray(g.N), jnp.asarray(g.Eid), jnp.asarray(S0)
        iters = support_mod._search_iters(g)

        def peel():
            S, a, b = _pkt_peel_jit(N, Eid, S0j, tabs, m=g.m, chunk=chunk,
                                    n_chunks=n_chunks, iters=iters,
                                    dense=False)
            S.block_until_ready()

        t_peel = timeit(peel, warmup=1, reps=2)
        tot = t_tables + t_support + t_peel
        out.append(row(
            f"fig4/{name}", tot,
            f"support%={100 * t_support / tot:.1f}"
            f";peel%={100 * t_peel / tot:.1f}"
            f";tables%={100 * t_tables / tot:.1f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
