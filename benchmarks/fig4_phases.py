"""Paper Fig. 4: breakdown of PKT execution among phases, per execution mode.

Phases mirrored: support computation / SCAN+processing (peel) — plus the
wedge-table construction our shape-static SPMD adaptation adds (DESIGN.md
§7.3), reported honestly as its own phase.

Both phases now carry their own mode axis: support is timed per support
executor (jnp / pallas, ``core/support.py`` vs ``kernels/support.py``) and
peel per peel executor (dense / chunked / pallas), and a row is emitted for
every (support_mode, peel_mode) combination so the support-vs-peel split
exposes where each pipeline's time goes.  On non-TPU backends the Pallas
kernels run in *interpret* mode, which is orders of magnitude slower than
compiled XLA — so pallas rows are only emitted for graphs whose wedge table
fits ``PALLAS_MAX_WEDGES`` (those rows are about lowering coverage and shape
behaviour, not competitive time; on a TPU runner the cap is ignored).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import support as support_mod
from repro.core.pkt import _pkt_peel_jit, prepare_peel
from repro.graphs.datasets import GRAPH_SUITE
from benchmarks.common import prep_graph, timeit, row

#: interpret-mode pallas is only timed below this wedge-table size on CPU
PALLAS_MAX_WEDGES = 1 << 16

MODES = ("dense", "chunked", "pallas")
SUPPORT_MODES = support_mod.SUPPORT_MODES


def run(suite=None, modes=MODES, support_modes=SUPPORT_MODES) -> list[str]:
    """CSV rows: per-phase seconds for every executor pair on the suite."""
    on_tpu = jax.default_backend() == "tpu"
    out = []
    for name in suite or GRAPH_SUITE:
        g, stats = prep_graph(name, order="kco")

        t0 = time.perf_counter()
        stab = support_mod.build_support_table(g)
        ptab = support_mod.build_peel_table(g)
        t_tables = time.perf_counter() - t0

        t_support = {}
        for smode in support_modes:
            if smode == "pallas" and not on_tpu \
                    and stab.size > PALLAS_MAX_WEDGES:
                continue
            t_support[smode] = timeit(
                lambda: support_mod.compute_support(g, stab, mode=smode))
        S0 = support_mod.compute_support(g, stab)

        tabs, chunk, n_chunks = prepare_peel(ptab, g.m, None)   # tuned/auto chunk policy
        N, Eid = jnp.asarray(g.N), jnp.asarray(g.Eid)
        iters = support_mod._search_iters(g)

        t_peel = {}
        for pmode in modes:
            if pmode == "pallas" and not on_tpu \
                    and ptab.size > PALLAS_MAX_WEDGES:
                continue

            def peel():
                # fresh S0 upload per call: _pkt_peel_jit donates its S0
                S, _, _ = _pkt_peel_jit(N, Eid, jnp.asarray(S0), tabs,
                                        m=g.m, chunk=chunk,
                                        n_chunks=n_chunks, iters=iters,
                                        mode=pmode, interpret=not on_tpu)
                S.block_until_ready()

            t_peel[pmode] = timeit(peel, warmup=1, reps=2)

        for smode, t_sup in t_support.items():
            for pmode, t_p in t_peel.items():
                tot = t_tables + t_sup + t_p
                out.append(row(
                    f"fig4/{name}/sup-{smode}+peel-{pmode}", tot,
                    f"support%={100 * t_sup / tot:.1f}"
                    f";peel%={100 * t_p / tot:.1f}"
                    f";tables%={100 * t_tables / tot:.1f}"
                    f";support_us={t_sup * 1e6:.1f}"
                    f";peel_us={t_p * 1e6:.1f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
