"""Paper Fig. 6: trussness distribution vs per-level peel time.

The paper's claim: parallel time correlates with the wedge work, not t_max —
50% of uk-2002's time sits below trussness 24 although t_max = 944. We
reproduce the analysis: cumulative edge fraction and cumulative peel-time
fraction by level, using a python-level loop over levels around a jitted
single-level peel (levels stay bulk-synchronous inside)."""

from __future__ import annotations

import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import support as support_mod
from repro.core.pkt import prepare_peel, _SENTINEL_S
from benchmarks.common import prep_graph, row


@functools.partial(jax.jit, static_argnames=("m", "chunk", "n_chunks",
                                             "iters"))
def _one_level(N, Eid, S_ext, processed, tabs, *, m, chunk, n_chunks, iters):
    """Peel one full level (all sub-levels); returns updated state + level."""
    two_m = N.shape[0]
    l = jnp.min(jnp.where(processed, _SENTINEL_S, S_ext))
    inCurr = (~processed) & (S_ext == l)

    def chunk_contrib(c, dec, S_ext, processed, inCurr):
        base = c * chunk
        e1 = jax.lax.dynamic_slice(tabs.e1, (base,), (chunk,))
        cand = jax.lax.dynamic_slice(tabs.cand_slot, (base,), (chunk,))
        lo = jax.lax.dynamic_slice(tabs.lo, (base,), (chunk,))
        hi = jax.lax.dynamic_slice(tabs.hi, (base,), (chunk,))
        in1 = inCurr[e1]
        w = N[cand]
        idx = support_mod.ranged_searchsorted(N, w, lo, hi, iters)
        safe = jnp.minimum(idx, two_m - 1)
        hit = (idx < hi) & (N[safe] == w)
        e2, e3 = Eid[cand], Eid[safe]
        valid = in1 & hit & ~processed[e2] & ~processed[e3]
        dec2 = valid & (S_ext[e2] > l) & ((~inCurr[e3]) | (e1 < e3))
        dec3 = valid & (S_ext[e3] > l) & ((~inCurr[e2]) | (e1 < e2))
        dec = dec.at[jnp.where(dec2, e2, m)].add(dec2.astype(jnp.int32))
        dec = dec.at[jnp.where(dec3, e3, m)].add(dec3.astype(jnp.int32))
        return dec

    def sub_body(st):
        S_ext, processed, inC, subs = st
        curr_edges = inC[:m] & tabs.has_entries
        delta = jnp.zeros((n_chunks + 1,), jnp.int32)
        delta = delta.at[jnp.where(curr_edges, tabs.c_start, n_chunks)].add(
            curr_edges.astype(jnp.int32))
        delta = delta.at[jnp.where(curr_edges, tabs.c_end + 1, n_chunks)].add(
            -curr_edges.astype(jnp.int32))
        active = jnp.cumsum(delta[:n_chunks]) > 0
        n_act = jnp.sum(active.astype(jnp.int32))
        (ids,) = jnp.nonzero(active, size=n_chunks, fill_value=n_chunks - 1)

        def wbody(s):
            i, dec = s
            return i + 1, chunk_contrib(ids[i], dec, S_ext, processed, inC)

        _, dec = jax.lax.while_loop(lambda s: s[0] < n_act, wbody,
                                    (jnp.int32(0),
                                     jnp.zeros((m + 1,), jnp.int32)))
        S_ext = jnp.where((~processed) & (~inC) & (dec > 0),
                          jnp.maximum(S_ext - dec, l), S_ext)
        processed = processed | inC
        inC = (~processed) & (S_ext == l)
        inC = inC.at[m].set(False)
        return S_ext, processed, inC, subs + 1

    S_ext, processed, _, subs = jax.lax.while_loop(
        lambda st: jnp.any(st[2]), sub_body,
        (S_ext, processed, inCurr, jnp.int32(0)))
    return S_ext, processed, l, subs


def run(suite=("rmat-small", "cliques-small", "ba-small")) -> list[str]:
    """CSV rows: per-level frontier widths + sub-level counts (Fig. 6)."""
    out = []
    for name in suite:
        g, stats = prep_graph(name, order="kco")
        stab = support_mod.build_support_table(g)
        ptab = support_mod.build_peel_table(g)
        S0 = support_mod.compute_support(g, stab)
        tabs, chunk, n_chunks = prepare_peel(ptab, g.m, None)   # tuned/auto chunk policy
        N, Eid = jnp.asarray(g.N), jnp.asarray(g.Eid)
        iters = support_mod._search_iters(g)

        S_ext = jnp.concatenate([jnp.asarray(S0),
                                 jnp.full((1,), _SENTINEL_S)])
        processed = jnp.zeros((g.m + 1,), jnp.bool_).at[g.m].set(True)
        times, levels, counts = [], [], []
        while int(jnp.sum(processed)) < g.m + 1:
            t0 = time.perf_counter()
            S_ext, processed, l, subs = _one_level(
                N, Eid, S_ext, processed, tabs, m=g.m, chunk=chunk,
                n_chunks=n_chunks, iters=iters)
            S_ext.block_until_ready()
            times.append(time.perf_counter() - t0)
            levels.append(int(l))
        t = np.asarray(S_ext[:g.m]) + 2
        total = sum(times)
        ct = np.cumsum(times) / max(total, 1e-12)
        # level below which 50% / 90% of time is spent
        lv = np.asarray(levels) + 2
        t50 = int(lv[np.searchsorted(ct, 0.5)])
        t90 = int(lv[np.searchsorted(ct, 0.9)])
        e50 = int(np.quantile(t, 0.5))
        e90 = int(np.quantile(t, 0.9))
        out.append(row(
            f"fig6/{name}", total,
            f"tmax={int(t.max())};edge_t50={e50};edge_t90={e90}"
            f";time_t50={t50};time_t90={t90};levels={len(levels)}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
