"""Incremental maintenance vs full recompute across churn batch sizes.

The streaming serving story (DESIGN.md §9): a decomposed graph absorbs
rolling-window edge churn.  For each churn fraction, a persistent
``IncrementalTruss`` handle applies ``remove k existing + add k absent``
batches (edge count preserved, so the full-recompute jit stays warm and the
comparison is steady-state vs steady-state) and is timed against a warm
from-scratch ``truss_pkt`` on the same final graph.  Every measured batch
ends with a parity check against the from-scratch result — a mismatch
fails the run (exit 1), which is the CI bench-trend gate.

Output: ``BENCH_inc.json`` rows per (graph, churn): update seconds, full
seconds, speedup, affected-region sizes, local/full repair counts.

  PYTHONPATH=src python -m benchmarks.inc_bench [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _bench_graph(name: str, fracs, batches: int, rng) -> dict:
    from repro.core.pkt import truss_pkt
    from repro.core.truss_inc import IncrementalTruss
    from repro.graphs.datasets import named_graph
    from repro.launch.truss import churn_batch

    E = named_graph(name)
    n = int(E.max()) + 1
    t0 = time.perf_counter()
    inc = IncrementalTruss(E)
    t_open = time.perf_counter() - t0
    # a second open hits the now-warm compiles: the difference attributes
    # the first-compile cost, and ``open_phases`` (recorded by the pkt
    # pipeline) splits the rest into table-build / support / peel — with
    # device-side construction the table phase is device work, not host
    t0 = time.perf_counter()
    IncrementalTruss(E)
    t_open_warm = time.perf_counter() - t0
    out = {"graph": name, "n": n, "m": inc.m, "open_seconds": t_open,
           "open_warm_seconds": t_open_warm,
           "open_compile_seconds": max(0.0, t_open - t_open_warm),
           "open_phases": {k: round(v, 6)
                           for k, v in inc.open_phases.items()},
           "rows": [], "parity_ok": True}

    for frac in fracs:
        # warmup batch: pays the local-peel jit compiles for this shape class
        add, rm = churn_batch(inc.edges, n, frac, rng)
        inc.update(add_edges=add, remove_edges=rm)

        times, affected, local, full = [], [], 0, 0
        for _ in range(batches):
            add, rm = churn_batch(inc.edges, n, frac, rng)
            t0 = time.perf_counter()
            st = inc.update(add_edges=add, remove_edges=rm)
            times.append(time.perf_counter() - t0)
            affected.append(st.affected)
            local += st.mode == "local"
            full += st.mode == "full"

        # warm full recompute on the same final graph (same m by design)
        cur = inc.edges
        truss_pkt(cur)
        t0 = time.perf_counter()
        ref = truss_pkt(cur)
        t_full = time.perf_counter() - t0

        parity = bool(np.array_equal(inc.trussness, ref))
        out["parity_ok"] = out["parity_ok"] and parity
        t_upd = float(np.mean(times))
        out["rows"].append({
            "churn_frac": frac,
            "batch_edges": int(max(1, round(frac * inc.m))),
            "update_seconds": t_upd,
            "full_seconds": t_full,
            "speedup": t_full / t_upd if t_upd > 0 else float("inf"),
            "affected_mean": float(np.mean(affected)),
            "local": local, "full": full,
            "parity": parity,
        })
    return out


def run(graphs=("ba-small", "er-small", "rmat-small"),
        fracs=(0.001, 0.01), batches: int = 3, seed: int = 0,
        out_path: str = "BENCH_inc.json") -> int:
    rng = np.random.default_rng(seed)
    report = {"bench": "incremental-maintenance", "graphs": [], "ok": True}
    for name in graphs:
        g = _bench_graph(name, fracs, batches, rng)
        report["graphs"].append(g)
        report["ok"] = report["ok"] and g["parity_ok"]
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    if not report["ok"]:
        print("INC BENCH FAILED: incremental/recompute parity regression",
              file=sys.stderr)
        return 1
    return 0


def rows(quick: bool = True) -> list[str]:
    """benchmarks/run.py adapter: CSV rows from a quick in-memory run."""
    from benchmarks.common import row

    rng = np.random.default_rng(0)
    out = []
    for name in ("ba-small",) if quick else ("ba-small", "rmat-small"):
        g = _bench_graph(name, (0.001, 0.01), 2, rng)
        for r in g["rows"]:
            out.append(row(
                f"inc/{name}/churn-{r['churn_frac']}", r["update_seconds"],
                f"speedup={r['speedup']:.2f}x;affected={r['affected_mean']:.0f}"
                f";local={r['local']};full={r['full']}"
                f";parity={int(r['parity'])}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph, quick churn sweep (the CI gate)")
    ap.add_argument("--out", default="BENCH_inc.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(run(graphs=("ba-small",), fracs=(0.001, 0.01),
                             batches=2, seed=args.seed, out_path=args.out))
    raise SystemExit(run(seed=args.seed, out_path=args.out))


if __name__ == "__main__":
    main()
