"""Incremental maintenance vs full recompute across churn and batch size.

The streaming serving story (DESIGN.md §9, §13): a decomposed graph absorbs
rolling edge churn.  Two workload shapes are measured:

* **churn** — for each churn fraction, persistent ``IncrementalTruss``
  handles apply ``remove k existing + add k random absent`` batches (edge
  count preserved, so the full-recompute jit stays warm and the comparison
  is steady-state vs steady-state).
* **window** — a sliding-window stream: edges arrive in a fixed shuffled
  order, the handle opens on the oldest ``window`` edges, and each batch
  slides the window by ``step`` (evict the ``step`` oldest, admit the
  ``step`` newest).  The ``step`` sweep is the batch-size axis: it locates
  the point where one merged-region repair (§13) overtakes per-edge
  repairs.

Every workload drives **two** handles in lockstep — ``insert_mode="batched"``
(the default single merged-region repair) and ``insert_mode="sequential"``
(the per-edge oracle) — and times a warm from-scratch ``truss_pkt`` on the
same final graph.  Every measured batch ends with a three-way bitwise
parity check (batched ≡ sequential ≡ from-scratch); a mismatch fails the
run (exit 1), which is the CI bench-trend gate.

Output: ``BENCH_inc.json`` rows per (graph, churn) and (graph, step):
batched/sequential/full seconds, both speedups, affected-region sizes,
local/full repair counts per mode.

  PYTHONPATH=src python -m benchmarks.inc_bench [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _open_pair(E):
    """Open batched + sequential handles on ``E``; time both opens."""
    from repro.core.truss_inc import IncrementalTruss

    t0 = time.perf_counter()
    inc = IncrementalTruss(E)
    t_open = time.perf_counter() - t0
    # the second open hits the now-warm compiles: the difference attributes
    # the first-compile cost, and ``open_phases`` (recorded by the pkt
    # pipeline) splits the rest into table-build / support / peel — with
    # device-side construction the table phase is device work, not host
    t0 = time.perf_counter()
    seq = IncrementalTruss(E, insert_mode="sequential")
    t_open_warm = time.perf_counter() - t0
    return inc, seq, t_open, t_open_warm


def _measure(inc, seq, batches) -> dict:
    """Apply each (add, rm) batch to both handles; time and parity-check.

    ``batches`` may be a lazy generator reading ``inc.edges``: each element
    is produced after the previous batch has been applied, so generated
    churn always targets the current lockstep state.
    """
    from repro.core.pkt import truss_pkt

    t_bat, t_seq, affected = [], [], []
    counts = {"batched": {"local": 0, "full": 0},
              "sequential": {"local": 0, "full": 0}}
    parity = True
    for add, rm in batches:
        t0 = time.perf_counter()
        st_b = inc.update(add_edges=add, remove_edges=rm)
        t_bat.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        st_s = seq.update(add_edges=add, remove_edges=rm)
        t_seq.append(time.perf_counter() - t0)
        affected.append(st_b.affected)
        for st, key in ((st_b, "batched"), (st_s, "sequential")):
            if st.mode in counts[key]:
                counts[key][st.mode] += 1
        parity = parity and bool(
            np.array_equal(inc.edges, seq.edges)
            and np.array_equal(inc.trussness, seq.trussness))

    # warm full recompute on the shared final graph
    cur = inc.edges
    truss_pkt(cur)
    t0 = time.perf_counter()
    ref = truss_pkt(cur)
    t_full = time.perf_counter() - t0
    parity = parity and bool(np.array_equal(inc.trussness, ref))

    upd = float(np.mean(t_bat))
    sq = float(np.mean(t_seq))
    return {
        "update_seconds": upd,
        "sequential_seconds": sq,
        "full_seconds": t_full,
        "speedup": t_full / upd if upd > 0 else float("inf"),
        "speedup_vs_sequential": sq / upd if upd > 0 else float("inf"),
        "affected_mean": float(np.mean(affected)),
        "local": counts["batched"]["local"], "full": counts["batched"]["full"],
        "seq_local": counts["sequential"]["local"],
        "seq_full": counts["sequential"]["full"],
        "parity": parity,
    }


def _bench_graph(name: str, fracs, batches: int, rng) -> dict:
    """Random-churn workload: preserved edge count, churn-fraction sweep."""
    from repro.graphs.datasets import named_graph
    from repro.launch.truss import churn_batch

    E = named_graph(name)
    n = int(E.max()) + 1
    inc, seq, t_open, t_open_warm = _open_pair(E)
    out = {"graph": name, "workload": "churn", "n": n, "m": inc.m,
           "open_seconds": t_open,
           "open_warm_seconds": t_open_warm,
           "open_compile_seconds": max(0.0, t_open - t_open_warm),
           "open_phases": {k: round(v, 6)
                           for k, v in inc.open_phases.items()},
           "rows": [], "parity_ok": True}

    for frac in fracs:
        # warmup batch: pays the local-peel jit compiles for this shape class
        add, rm = churn_batch(inc.edges, n, frac, rng)
        inc.update(add_edges=add, remove_edges=rm)
        seq.update(add_edges=add, remove_edges=rm)

        # lazy generator: each batch is drawn from the advanced state
        gen = (churn_batch(inc.edges, n, frac, rng) for _ in range(batches))
        res = _measure(inc, seq, gen)
        out["parity_ok"] = out["parity_ok"] and res["parity"]
        out["rows"].append({
            "churn_frac": frac,
            "batch_edges": int(max(1, round(frac * inc.m))),
            **res,
        })
    return out


def _bench_window(name: str, steps, batches: int, rng) -> dict:
    """Sliding-window workload: evict oldest ``step``, admit newest ``step``.

    The ``steps`` sweep is the batch-size axis at (roughly) constant graph
    size: larger steps amortise one merged-region repair over more inserted
    edges, which is exactly the §13 batched-path win.
    """
    from repro.graphs.datasets import named_graph

    E = named_graph(name)
    n = int(E.max()) + 1
    m = E.shape[0]
    order = rng.permutation(m)
    window = int(0.75 * m)
    out = {"graph": name, "workload": "window", "n": n, "m": window,
           "rows": [], "parity_ok": True}

    for step in steps:
        # every step restarts the stream from the same arrival order
        cur = E[order[:window]]
        inc, seq, _, _ = _open_pair(cur)
        lo, hi = 0, window
        todo = []
        for _ in range(batches + 1):        # +1: warmup slide
            if hi + step > m:
                break
            todo.append((E[order[hi:hi + step]], E[order[lo:lo + step]]))
            lo, hi = lo + step, hi + step
        if len(todo) < 2:
            continue
        inc.update(add_edges=todo[0][0], remove_edges=todo[0][1])  # warmup
        seq.update(add_edges=todo[0][0], remove_edges=todo[0][1])
        res = _measure(inc, seq, todo[1:])
        out["parity_ok"] = out["parity_ok"] and res["parity"]
        out["rows"].append({"step": int(step), "batch_edges": int(step),
                            **res})
    return out


def run(graphs=("ba-small", "er-small", "rmat-small"),
        fracs=(0.001, 0.01), batches: int = 3, seed: int = 0,
        window_graphs=("ba-small",), steps=(4, 16, 64),
        out_path: str = "BENCH_inc.json") -> int:
    """Run the incremental-update bench suite and write BENCH_inc.json."""
    rng = np.random.default_rng(seed)
    report = {"bench": "incremental-maintenance", "graphs": [],
              "windows": [], "ok": True}
    for name in graphs:
        g = _bench_graph(name, fracs, batches, rng)
        report["graphs"].append(g)
        report["ok"] = report["ok"] and g["parity_ok"]
    for name in window_graphs:
        w = _bench_window(name, steps, batches, rng)
        report["windows"].append(w)
        report["ok"] = report["ok"] and w["parity_ok"]
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    if not report["ok"]:
        print("INC BENCH FAILED: batched/sequential/recompute parity "
              "regression", file=sys.stderr)
        return 1
    return 0


def rows(quick: bool = True) -> list[str]:
    """benchmarks/run.py adapter: CSV rows from a quick in-memory run."""
    from benchmarks.common import row

    rng = np.random.default_rng(0)
    out = []
    for name in ("ba-small",) if quick else ("ba-small", "rmat-small"):
        g = _bench_graph(name, (0.001, 0.01), 2, rng)
        for r in g["rows"]:
            out.append(row(
                f"inc/{name}/churn-{r['churn_frac']}", r["update_seconds"],
                f"speedup={r['speedup']:.2f}x"
                f";vs_seq={r['speedup_vs_sequential']:.2f}x"
                f";affected={r['affected_mean']:.0f}"
                f";local={r['local']};full={r['full']}"
                f";parity={int(r['parity'])}"))
        w = _bench_window(name, (16,), 2, rng)
        for r in w["rows"]:
            out.append(row(
                f"inc/{name}/window-{r['step']}", r["update_seconds"],
                f"speedup={r['speedup']:.2f}x"
                f";vs_seq={r['speedup_vs_sequential']:.2f}x"
                f";parity={int(r['parity'])}"))
    return out


def main() -> None:
    """CLI entry: full suite, or --smoke for the CI gate."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph, quick churn sweep (the CI gate)")
    ap.add_argument("--out", default="BENCH_inc.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(run(graphs=("ba-small",), fracs=(0.001, 0.01),
                             batches=2, window_graphs=("ba-small",),
                             steps=(16,), seed=args.seed, out_path=args.out))
    raise SystemExit(run(seed=args.seed, out_path=args.out))


if __name__ == "__main__":
    main()
