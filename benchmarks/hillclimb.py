"""Chunk-policy autotuner: measure, then let ``auto_chunk`` consume the result.

``kernels.wedge_common.auto_chunk`` picks the wedge-table chunk size (one
Pallas grid step / one chunk-skipping unit) whenever the caller passes
``chunk=None``.  Its recorded-defaults formula (split the table into
``AUTO_CHUNK_TARGET`` chunks, clamp to the VMEM band) is a heuristic; this
bench closes the loop by *measuring*: for every benchmark graph it sweeps the
pow2 chunk candidates over the real executors, scores each candidate by its
normalized warm decomposition time summed across the executor pairs that
consume the chunk (chunked/jnp — the serving default; ``--kernels`` adds
pallas/pallas on TPU hosts, where its timings are real rather than
interpret-mode emulation), and records the winner per pow2
peel-table-size bucket.

The emitted table (``--emit``, default
``src/repro/kernels/tuned_chunks.json``) is exactly what ``auto_chunk``
loads at first use: ``{"format": 1, "buckets": {log2(table bucket): chunk}}``.
Buckets the sweep never measured fall back to the formula, so a partial
tuning run is always safe, and deleting the file reverts the whole policy to
the recorded defaults.

Usage::

    PYTHONPATH=src:. python benchmarks/hillclimb.py            # sweep, print
    PYTHONPATH=src:. python benchmarks/hillclimb.py --emit     # + write table
    PYTHONPATH=src:. python benchmarks/hillclimb.py --smoke    # 1 graph, CI
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import prep_graph, timeit

from repro.core import support as support_mod
from repro.core.pkt import pkt
from repro.kernels import wedge_common

#: default graph suite: one per size regime the serving fleet actually sees
GRAPHS = ("ba-small", "er-small", "rmat-small")

#: executor pairs whose hot loop the chunk size shapes (peel_mode,
#: support_mode); scores are normalized within each pair so no single
#: executor's absolute speed dominates the vote.  The default sweeps the
#: serving path only: on CPU the Pallas pair runs in *interpret mode*,
#: whose timings reflect the emulator rather than any accelerator, so it
#: is opt-in (``--kernels`` / ``kernel_pair=True``) for TPU hosts.
PAIRS = (("chunked", "jnp"),)
KERNEL_PAIR = ("pallas", "pallas")


def chunk_candidates(table_size: int) -> list[int]:
    """Pow2 candidates from the auto-chunk band that fit the table."""
    pad = wedge_common.next_pow2(max(1, table_size))
    hi = min(wedge_common.AUTO_CHUNK_MAX, pad)
    c = wedge_common.AUTO_CHUNK_MIN
    out = []
    while c <= hi:
        out.append(c)
        c <<= 1
    return out or [pad]


def sweep_graph(name: str, *, reps: int = 3, pairs=PAIRS) -> dict:
    """Time every (chunk candidate × executor pair) on one graph.

    Returns ``{"name", "bucket", "table_size", "chunks": {chunk: score},
    "best": chunk}`` where score is the sum over executor pairs of the
    candidate's warm time divided by the pair's best candidate time (1.0 =
    won that pair outright).
    """
    g, _ = prep_graph(name)
    ptab = support_mod.build_peel_table(g)
    pad = wedge_common.next_pow2(max(1, ptab.size))
    cands = chunk_candidates(ptab.size)
    times: dict[int, dict[int, float]] = {c: {} for c in cands}
    for pi, (mode, smode) in enumerate(pairs):
        for c in cands:
            times[c][pi] = timeit(
                lambda c=c, mode=mode, smode=smode: pkt(
                    g, chunk=c, mode=mode, support_mode=smode),
                reps=reps)
    scores: dict[int, float] = {}
    for pi in range(len(pairs)):
        best = min(times[c][pi] for c in cands)
        for c in cands:
            scores[c] = scores.get(c, 0.0) + times[c][pi] / max(best, 1e-12)
    best_chunk = min(cands, key=lambda c: scores[c])
    return {"name": name, "bucket": pad.bit_length() - 1,
            "table_size": int(ptab.size),
            "chunks": {str(c): round(scores[c], 4) for c in cands},
            "best": int(best_chunk)}


def tune(graphs=GRAPHS, *, reps: int = 3, kernel_pair: bool = False) -> dict:
    """Sweep the suite and vote per bucket (lowest summed score wins)."""
    pairs = PAIRS + ((KERNEL_PAIR,) if kernel_pair else ())
    sweeps = [sweep_graph(name, reps=reps, pairs=pairs) for name in graphs]
    votes: dict[int, dict[int, float]] = {}
    for sw in sweeps:
        b = votes.setdefault(sw["bucket"], {})
        for c_str, score in sw["chunks"].items():
            c = int(c_str)
            b[c] = b.get(c, 0.0) + score
    buckets = {str(b): int(min(cands, key=lambda c: cands[c]))
               for b, cands in votes.items()}
    return {"format": 1, "source": "benchmarks/hillclimb.py",
            "graphs": list(graphs), "buckets": buckets, "sweeps": sweeps}


def run(graphs=GRAPHS, *, reps: int = 3, kernel_pair: bool = False,
        emit_path: str | None = None) -> dict:
    """Bench-harness adapter: tune, optionally emit, return the table doc."""
    doc = tune(graphs, reps=reps, kernel_pair=kernel_pair)
    if emit_path:
        with open(emit_path, "w") as f:
            json.dump({k: doc[k] for k in
                       ("format", "source", "graphs", "buckets")}, f,
                      indent=1, sort_keys=True)
            f.write("\n")
        wedge_common.reload_tuned_chunks()
    return doc


def rows(quick: bool = False) -> list[str]:
    """CSV rows for benchmarks/run.py (no file emission)."""
    doc = tune(GRAPHS[:1] if quick else GRAPHS, reps=2 if quick else 3)
    out = []
    for sw in doc["sweeps"]:
        out.append(f"hillclimb/{sw['name']},bucket=2^{sw['bucket']},"
                   f"best_chunk={sw['best']}")
    return out


def main() -> None:
    """CLI: sweep chunk candidates, print scores, optionally emit the table."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--graphs", nargs="*", default=list(GRAPHS))
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="single graph, 2 reps (CI)")
    ap.add_argument("--kernels", action="store_true",
                    help="also sweep the pallas/pallas pair (TPU hosts; "
                         "interpret-mode timings are emulator noise)")
    ap.add_argument("--emit", nargs="?", const=str(
        wedge_common.TUNED_CHUNKS_PATH), default=None, metavar="PATH",
        help="write the tuned table (default: the path auto_chunk loads)")
    args = ap.parse_args()
    graphs = args.graphs[:1] if args.smoke else args.graphs
    reps = 2 if args.smoke else args.reps
    doc = run(graphs, reps=reps, kernel_pair=args.kernels,
              emit_path=args.emit)
    for sw in doc["sweeps"]:
        print(f"{sw['name']}: table={sw['table_size']} "
              f"bucket=2^{sw['bucket']} best_chunk={sw['best']} "
              f"scores={sw['chunks']}")
    print(f"buckets: {doc['buckets']}"
          + (f" -> {args.emit}" if args.emit else " (dry run; use --emit)"))


if __name__ == "__main__":
    main()
