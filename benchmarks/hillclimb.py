"""Hillclimb harness: lower one (arch × shape) cell with config overrides and
print the three roofline terms + per-kind collective breakdown.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen3_8b \
      --shape train_4k --mb 4 --set remat=block --set kv_chunk=2048

Used for the §Perf iterations; every run prints a one-line record that goes
into EXPERIMENTS.md.
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses

import jax

from repro.launch.mesh import make_production_mesh
from repro.configs import get_config
from repro.launch.dryrun import cost_cell, lower_cell
from benchmarks.roofline import PEAK_FLOPS, HBM_BW, ICI_BW, CHIPS, model_flops


def parse_override(s: str):
    k, _, v = s.partition("=")
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    if v == "None":
        return k, None
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mb", type=int, default=1)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg overrides key=value")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="override single-pod mesh, e.g. 64,4 (data,model)")
    ap.add_argument("--mem", action="store_true",
                    help="also run the prod (scanned) pass for memory")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.mesh_shape:
        d, m = (int(x) for x in args.mesh_shape.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = make_production_mesh(multi_pod=args.multipod)
    cfg = get_config(args.arch)
    overrides = dict(parse_override(s) for s in args.set)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    rec = cost_cell(cfg, args.shape, mesh, microbatches=args.mb)
    compute_s = rec["flops"] / PEAK_FLOPS
    memory_s = rec["bytes_accessed"] / HBM_BW
    coll_s = rec["collectives"]["total_bytes"] / ICI_BW
    bound = max(compute_s, memory_s, coll_s)
    mf = model_flops(args.arch, args.shape)
    frac = (mf / CHIPS / PEAK_FLOPS) / max(bound, 1e-12)
    print(f"[{args.tag}] {args.arch}/{args.shape} mb={args.mb} "
          f"{' '.join(args.set)}")
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda t: t[1])[0]
    print(f"  compute {compute_s:.3f}s  memory {memory_s:.3f}s  "
          f"collective {coll_s:.3f}s  -> dominant {dominant}"
          f"  roofline_frac {frac:.4f}")
    for k, v in rec["collectives"].items():
        if isinstance(v, dict) and v["bytes"]:
            print(f"    {k:20s} {v['bytes'] / 1e9:9.2f} GB")
    if args.mem:
        p = lower_cell(cfg, args.shape, mesh, microbatches=args.mb)
        print(f"  prod mem: temp {p['temp_bytes'] / 2**30:.2f} GiB + args "
              f"{p['arg_bytes'] / 2**30:.2f} GiB "
              f"(fits={p['temp_bytes'] + p['arg_bytes'] <= 15.5 * 2**30})")


if __name__ == "__main__":
    main()
