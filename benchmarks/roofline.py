"""Roofline analysis (assignment deliverable g).

Reads artifacts/dryrun/*.json and derives, per (arch × shape) on the
single-pod mesh:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s        (bf16 MXU)
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI_bw

FLOPs/bytes come from the *cost-mode* records (unrolled scans — exact;
prod-mode numbers hide while-loop bodies), per-device post-SPMD. Collective
bytes use the ring-model convention in launch/dryrun.parse_collectives.

MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (+attention/cache terms noted) —
the useful-work yardstick; ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes
remat and padding waste.
"""

from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
CHIPS = 256                  # single-pod roofline mesh

ART = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "artifacts", "dryrun"))


def _load(arch, shape, mesh, mode):
    p = os.path.join(ART, f"{arch}__{shape}__{mesh}__{mode}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def model_flops(arch: str, shape: str) -> float:
    """Analytic useful FLOPs per step (global, forward+backward for train)."""
    from repro.configs import get_config, SHAPES
    cfg = get_config(arch)
    seq, gbs, kind = SHAPES[shape]
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq * gbs
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * gbs
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    from repro.models.model import n_attn_apps
    flops = 2.0 * n_active * gbs
    na = n_attn_apps(cfg)
    if na:
        flops += 4.0 * gbs * na * cfg.n_heads * cfg.head_dim * seq
    return flops


def cell_terms(arch: str, shape: str) -> dict | None:
    cost = _load(arch, shape, "pod", "cost")
    prod = _load(arch, shape, "pod", "prod")
    if not cost or cost.get("skipped") or cost.get("error"):
        return None
    compute_s = cost["flops"] / PEAK_FLOPS
    memory_s = cost["bytes_accessed"] / HBM_BW
    coll_s = cost["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    hlo_total = cost["flops"] * CHIPS
    bound = max(compute_s, memory_s, coll_s)
    return {
        "arch": arch, "shape": shape,
        **{k: float(v) for k, v in terms.items()},
        "dominant": dom.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_total,
        "useful_ratio": mf / max(hlo_total, 1e-9),
        # fraction of roofline-limited time that is useful compute
        "roofline_fraction": (mf / CHIPS / PEAK_FLOPS) / max(bound, 1e-12),
        "mem_gib": ((prod or {}).get("temp_bytes", 0)
                    + (prod or {}).get("arg_bytes", 0)) / 2**30,
        "fits": (prod or {}).get("fits_hbm"),
        "microbatches": (prod or {}).get("microbatches"),
    }


def full_table() -> list[dict]:
    from repro.configs import ARCHS, SHAPES, cell_is_valid
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = cell_is_valid(arch, shape)
            if not ok:
                rows.append({"arch": arch, "shape": shape, "skipped": why})
                continue
            r = cell_terms(arch, shape)
            rows.append(r or {"arch": arch, "shape": shape,
                              "skipped": "missing artifact"})
    return rows


def markdown_table(rows=None) -> str:
    rows = rows or full_table()
    hdr = ("| arch | shape | compute(s) | memory(s) | collective(s) | "
           "dominant | useful ratio | roofline frac | mem GiB (mb) |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | {r['skipped'][:42]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | "
            f"{r['mem_gib']:.1f} ({r['microbatches']}) |")
    return "\n".join(lines)


def run(suite=None) -> list[str]:
    out = []
    for r in full_table():
        if r.get("skipped"):
            out.append(f"roofline/{r['arch']}/{r['shape']},SKIP,"
                       f"{r['skipped'][:60]}")
            continue
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append(
            f"roofline/{r['arch']}/{r['shape']},{bound * 1e6:.1f},"
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}"
            f";useful={r['useful_ratio']:.2f}")
    return out


if __name__ == "__main__":
    print(markdown_table())
