"""Truss roofline: measured phase throughput against a measured memory ceiling.

The PKT hot loops are integer gather/scatter over wedge tables — no MXU
FLOPs to speak of — so the meaningful roofline axis is *bytes moved per
second* against the machine's achievable memory bandwidth.  Hardcoding a
peak would lie on every host this runs on, so the ceiling is measured: a
numpy copy triad over an out-of-cache buffer (``stream_bandwidth``).

Per graph this bench derives an analytic traffic model from the decomposition
the executor actually ran:

  support bytes = table_scan + probe_gathers            (one scan, AM4)
  peel bytes    = sublevels × (table_scan + probe_gathers + state)
  table_scan    = 4 arrays × 4 B per wedge entry
  probe_gathers = (1 + iters) × 4 B per entry   (candidate + binary search)
  state         = 5 × (m+1) × 4 B per sub-level  (S/processed/inCurr + dec
                  accumulator read+write — the fused-kernel layout, §16)

and divides it by the warm phase wall time from ``pkt(...,
phase_timings=True)``.  The peel model charges every sub-level a full table
scan — exact for ``dense``/``pallas`` (grids are static), an upper bound for
``chunked`` (chunk skipping moves less) — so ``frac`` is the fraction of the
measured copy ceiling the executor sustains under that model.  Numbers well
below 1.0 locate dispatch overhead / latency-bound sub-levels (deep, narrow
frontiers), not bandwidth saturation.

Usage::

    PYTHONPATH=src:. python benchmarks/roofline.py            # markdown table
    PYTHONPATH=src:. python benchmarks/roofline.py --smoke    # 1 graph, CI
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import prep_graph

from repro.core import support as support_mod
from repro.core.pkt import pkt

#: graph suite (same regimes as hillclimb's, so the tuned chunks apply)
GRAPHS = ("ba-small", "er-small", "rmat-small")

#: executor pairs to profile: (peel_mode, support_mode)
PAIRS = (("chunked", "jnp"), ("dense", "jnp"), ("pallas", "pallas"))


def stream_bandwidth(mib: int = 256, reps: int = 3) -> float:
    """Measured host copy bandwidth in B/s (numpy out-of-cache triad)."""
    n = mib * (1 << 20) // 8
    a = np.ones(n)
    b = np.empty_like(a)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.copyto(b, a)
        best = min(best, time.perf_counter() - t0)
    return 16.0 * n / best          # 8 B read + 8 B write per element


def _iters(g) -> int:
    return support_mod._search_iters(g)


def graph_terms(name: str, *, reps: int = 3) -> list[dict]:
    """Measured warm phase times + modeled traffic for every executor pair."""
    g, _ = prep_graph(name)
    stab = support_mod.build_support_table(g)
    ptab = support_mod.build_peel_table(g)
    iters = _iters(g)
    entry_bytes = 4 * 4 + (1 + iters) * 4
    state_bytes = 5 * (g.m + 1) * 4
    rows = []
    for mode, smode in PAIRS:
        def run_once(mode=mode, smode=smode):
            return pkt(g, mode=mode, support_mode=smode, phase_timings=True)
        run_once()                                  # warm (compile)
        best = None
        for _ in range(reps):
            r = run_once()
            if best is None or (r.phases["support"] + r.phases["peel"]
                                < best.phases["support"]
                                + best.phases["peel"]):
                best = r
        sup_bytes = stab.size * entry_bytes
        peel_bytes = best.sublevels * (ptab.size * entry_bytes + state_bytes)
        rows.append({
            "graph": name, "mode": mode, "support_mode": smode,
            "m": g.m, "sublevels": int(best.sublevels),
            "support_seconds": best.phases["support"],
            "peel_seconds": best.phases["peel"],
            "support_bytes": int(sup_bytes),
            "peel_bytes": int(peel_bytes),
            "support_gbps": sup_bytes / max(best.phases["support"], 1e-12)
            / 1e9,
            "peel_gbps": peel_bytes / max(best.phases["peel"], 1e-12) / 1e9,
        })
    return rows


def full_table(graphs=GRAPHS, *, reps: int = 3) -> dict:
    """Roofline rows for the whole suite against the measured ceiling."""
    bw = stream_bandwidth()
    rows = []
    for name in graphs:
        for r in graph_terms(name, reps=reps):
            r["peel_frac"] = r["peel_gbps"] * 1e9 / bw
            r["support_frac"] = r["support_gbps"] * 1e9 / bw
            rows.append(r)
    return {"stream_gbps": bw / 1e9, "rows": rows}


def markdown_table(doc=None) -> str:
    """Render a full_table() doc as a markdown table."""
    doc = doc or full_table()
    lines = [f"measured copy ceiling: {doc['stream_gbps']:.1f} GB/s", "",
             "| graph | peel/support | subs | support GB/s (frac) | "
             "peel GB/s (frac) |",
             "|---|---|---|---|---|"]
    for r in doc["rows"]:
        lines.append(
            f"| {r['graph']} | {r['mode']}/{r['support_mode']} | "
            f"{r['sublevels']} | "
            f"{r['support_gbps']:.2f} ({r['support_frac']:.3f}) | "
            f"{r['peel_gbps']:.2f} ({r['peel_frac']:.3f}) |")
    return "\n".join(lines)


def run(suite=None) -> list[str]:
    """CSV rows for benchmarks/run.py."""
    doc = full_table(suite or GRAPHS)
    out = [f"roofline/stream,{0.0:.1f},ceiling={doc['stream_gbps']:.1f}GBps"]
    for r in doc["rows"]:
        out.append(
            f"roofline/{r['graph']}/{r['mode']}-{r['support_mode']},"
            f"{(r['support_seconds'] + r['peel_seconds']) * 1e6:.1f},"
            f"peel={r['peel_gbps']:.2f}GBps;frac={r['peel_frac']:.3f}")
    return out


def main() -> None:
    """CLI entry: print the roofline table (--smoke: first graph only)."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--graphs", nargs="*", default=list(GRAPHS))
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    graphs = args.graphs[:1] if args.smoke else args.graphs
    print(markdown_table(full_table(graphs, reps=args.reps)))


if __name__ == "__main__":
    main()
