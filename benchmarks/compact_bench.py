"""Live-edge compaction + device table build: wall time and open-path cost.

The PR-4 perf axes (DESIGN.md §10) measured head-to-head on real graphs:

  * **compaction on vs off** — warm end-to-end ``pkt`` wall time with the
    default threshold against ``compact_frac=None``, plus the phase
    breakdown (table-build / support / peel / compaction) for each;
  * **device vs numpy table build** — the cold *open* cost (first call:
    table build + first compile) and the warm cost per table mode, showing
    the table-build phase moved off the host.

Every measured configuration is parity-checked bitwise against the others —
a mismatch exits nonzero, which is the CI bench-trend gate.  Output:
``BENCH_compact.json``.

  PYTHONPATH=src python -m benchmarks.compact_bench [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _timed_pkt(g, reps: int = 2, **kw):
    """(cold_seconds, warm_seconds, last result) for pkt(g, **kw)."""
    from repro.core.pkt import pkt

    t0 = time.perf_counter()
    res = pkt(g, phase_timings=True, **kw)
    cold = time.perf_counter() - t0
    cold_phases = dict(res.phases)
    warm = []
    for _ in range(reps):
        t0 = time.perf_counter()
        res = pkt(g, phase_timings=True, **kw)
        warm.append(time.perf_counter() - t0)
    return cold, cold_phases, float(np.mean(warm)), res


def _bench_graph(name: str) -> dict:
    from repro.graphs.csr import build_csr, degeneracy_order, relabel
    from repro.graphs.datasets import named_graph

    E = named_graph(name)
    n = int(E.max()) + 1
    E = relabel(E, degeneracy_order(E, n))
    g = build_csr(E, n)
    out = {"graph": name, "n": g.n, "m": g.m, "modes": {}, "parity_ok": True}

    runs = {
        "compact_on": dict(table_mode="device"),          # default threshold
        "compact_off": dict(table_mode="device", compact_frac=None),
        "numpy_tables": dict(table_mode="numpy", compact_frac=None),
    }
    ref = None
    for key, kw in runs.items():
        cold, cold_phases, warm, res = _timed_pkt(g, **kw)
        out["modes"][key] = {
            "open_seconds": cold,
            "open_phases": {k: round(v, 6) for k, v in cold_phases.items()},
            "warm_seconds": warm,
            "warm_phases": {k: round(v, 6)
                            for k, v in (res.phases or {}).items()},
            "compactions": res.compactions,
            "levels": res.levels,
            "sublevels": res.sublevels,
        }
        if ref is None:
            ref = res.trussness
        else:
            same = bool(np.array_equal(res.trussness, ref))
            out["modes"][key]["agrees"] = same
            out["parity_ok"] = out["parity_ok"] and same
    on = out["modes"]["compact_on"]
    off = out["modes"]["compact_off"]
    host = out["modes"]["numpy_tables"]
    # like-for-like: compaction on/off share the device table mode, and the
    # table-mode pair shares compact_frac=None.  Cold ``open_seconds`` per
    # mode stay raw — on CPU they are dominated by first-compile cost, which
    # the phase split attributes (see also inc_bench's open_compile split).
    out["speedup_warm_compact"] = off["warm_seconds"] / on["warm_seconds"] \
        if on["warm_seconds"] > 0 else float("inf")
    out["speedup_warm_device_tables"] = \
        host["warm_seconds"] / off["warm_seconds"] \
        if off["warm_seconds"] > 0 else float("inf")
    return out


def run(graphs=("ba-small", "rmat-small", "er-small", "cliques-small",
                "ba-medium"),
        out_path: str = "BENCH_compact.json") -> int:
    """Run the compaction/device-table bench suite and write the snapshot."""
    report = {"bench": "compaction+device-tables", "graphs": [], "ok": True}
    for name in graphs:
        gr = _bench_graph(name)
        report["graphs"].append(gr)
        report["ok"] = report["ok"] and gr["parity_ok"]
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    if not report["ok"]:
        print("COMPACT BENCH FAILED: compaction/table-mode parity regression",
              file=sys.stderr)
        return 1
    return 0


def main() -> None:
    """CLI entry: full suite, or --smoke for the CI gate."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graphs only (the CI gate)")
    ap.add_argument("--out", default="BENCH_compact.json")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(run(graphs=("ba-small", "rmat-small"),
                             out_path=args.out))
    raise SystemExit(run(out_path=args.out))


if __name__ == "__main__":
    main()
