"""Batched-engine throughput: many small graphs/sec, serial vs batched.

The serving workload the ROADMAP targets: a stream of modest graphs (ego
nets, rolling windows).  Serial = one ``truss_pkt`` call per graph (each
distinct shape recompiles, then dispatches one-at-a-time).  Batched = the
``TrussEngine`` bucketing the stream into pow2 size classes and vmapping one
compiled pipeline per class.  Both are measured post-warmup (compiles paid),
so the gap isolates dispatch/batching efficiency.

The batched rows carry a support-executor column: one row per support mode
(jnp vs the Pallas kernel), so the kernel-vs-jnp cost of the support phase
is visible per stream.  Off-TPU the kernel rows run in interpret mode —
expect them slower there; on a TPU runner they are the competitive path.
"""

from __future__ import annotations

import numpy as np

from repro.core.pkt import truss_pkt
from repro.graphs.gen import (erdos_renyi_edges, ring_of_cliques_edges,
                              rmat_edges)
from repro.serve.truss_engine import TrussEngine
from benchmarks.common import timeit, row


def _fleet(n_graphs: int, seed: int = 0) -> list[np.ndarray]:
    """A mixed-shape, mixed-size stream of small graphs."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_graphs):
        kind = i % 3
        if kind == 0:
            out.append(erdos_renyi_edges(
                int(rng.integers(24, 80)), avg_degree=8.0, seed=seed + i))
        elif kind == 1:
            out.append(ring_of_cliques_edges(
                int(rng.integers(3, 6)), int(rng.integers(4, 8))))
        else:
            out.append(rmat_edges(6, edge_factor=4, seed=seed + i))
    return [e for e in out if e.size]


def run(n_graphs: int = 24, mode: str = "chunked", seed: int = 0,
        support_modes=("jnp", "pallas")) -> list[str]:
    """CSV rows: serial-vs-batched engine throughput per support mode."""
    graphs = _fleet(n_graphs, seed)

    def serial():
        for e in graphs:
            truss_pkt(e, mode=mode)

    t_serial = timeit(serial, warmup=1, reps=2)
    gps_serial = len(graphs) / t_serial
    out = [row(f"engine/serial/{mode}", t_serial,
               f"graphs={len(graphs)};graphs_per_sec={gps_serial:.2f}")]

    for smode in support_modes:
        # warmup pays per-bucket compiles (cached in jax's global jit cache);
        # the timed pass on a fresh engine measures steady-state dispatch
        TrussEngine(mode=mode, support_mode=smode).map(graphs)

        def batched():
            TrussEngine(mode=mode, support_mode=smode).map(graphs)

        t_batched = timeit(batched, warmup=0, reps=2)
        gps_batched = len(graphs) / t_batched
        out.append(row(
            f"engine/batched/{mode}/sup-{smode}", t_batched,
            f"graphs={len(graphs)};graphs_per_sec={gps_batched:.2f}"
            f";speedup={t_serial / t_batched:.2f}x"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
