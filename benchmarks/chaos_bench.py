"""Chaos benchmark: availability/goodput/latency under injected faults.

DESIGN.md §15's measurement: mixed query/update/communities/open traffic
replays through the async :class:`~repro.serve.scheduler.TrussScheduler`
while a seeded :class:`~repro.testing.chaos.FaultPlan` injects transient
raise-faults at every dispatch site (engine flush, region re-peel,
support build, hierarchy flood) at a swept rate — plus state corruption
at the region site at a quarter of that rate, exercising the
quarantine-and-rebuild heal path.  Per fault rate the bench reports
availability, goodput, retry/heal/ladder counters, and p50/p99 latency.

Every row is **correctness-gated**: the same schedule replays through a
fault-free synchronous ``TrussEngine`` applying exactly the updates that
committed async (failed updates never commit — batch-scoped commit — so
the masked replay reconstructs the same state), and every *completed*
async result must be bitwise-equal.  Under chaos a request may fail with
a typed error; it must never succeed with a wrong answer.  The CI gates:

* zero incorrect completed results at every fault rate, and
* at injected rates <= 10 %, availability >= 99 % for requests that were
  not themselves killed by an injected fault (collateral failures —
  quarantine fallout, shed — count against this; typed
  ``InjectedFault`` exhaustion does not).

Output: ``BENCH_chaos.json`` rows per fault-rate point.

  PYTHONPATH=src python -m benchmarks.chaos_bench [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.serve_bench import build_fleet

#: gate thresholds (ISSUE 9 acceptance criteria)
GATE_RATE = 0.10
GATE_AVAILABILITY = 0.99
COMMUNITY_K = 3


def make_workload(graphs, extras, n_requests: int, seed: int,
                  mix=(0.60, 0.15, 0.10, 0.075, 0.075)):
    """Deterministic query/update/submit/communities/open schedule.

    Same shape as ``serve_bench.make_workload`` plus submit and
    communities fractions, so every dispatch site — flush (submits),
    region (updates), support (opens), hierarchy (communities) — sees
    chaos traffic.  Presence tracking keeps removals valid in the
    fault-free replay; when an async update fails its removal simply
    never commits, and the masked sync replay skips it identically.
    """
    from repro.graphs.gen import erdos_renyi_edges

    rng = np.random.default_rng(seed)
    present = [set() for _ in graphs]
    ops, n_open = [], 0
    for _ in range(n_requests):
        r = rng.random()
        hid = int(rng.integers(0, len(graphs)))
        if r < mix[0]:
            rows_ = graphs[hid][
                rng.integers(0, graphs[hid].shape[0], size=8)]
            ops.append(("query", hid, rows_))
        elif r < sum(mix[:2]):
            picks = rng.choice(len(extras[hid]),
                               size=min(4, len(extras[hid])), replace=False)
            add = [extras[hid][j] for j in picks
                   if extras[hid][j] not in present[hid]]
            rem = [extras[hid][j] for j in picks
                   if extras[hid][j] in present[hid]]
            present[hid] |= set(add)
            present[hid] -= set(rem)
            ops.append(("update", hid,
                        np.array(add or np.zeros((0, 2)), np.int64),
                        np.array(rem or np.zeros((0, 2)), np.int64)))
        elif r < sum(mix[:3]):
            ops.append(("submit", erdos_renyi_edges(
                64, 8.0, seed=seed + 9000 + n_open)))
            n_open += 1
        elif r < sum(mix[:4]):
            ops.append(("communities", hid, COMMUNITY_K))
        else:
            ops.append(("open", erdos_renyi_edges(
                64, 8.0, seed=seed + 5000 + n_open)))
            n_open += 1
    return ops


def build_plan(rate: float, seed: int):
    """Raise-faults at ``rate`` on every site + region corruption at rate/4."""
    from repro.testing.chaos import DISPATCH_SITES, FaultPlan

    plan = FaultPlan.uniform(rate, sites=DISPATCH_SITES, seed=seed)
    if rate > 0:
        plan.add("region", mode="corrupt", rate=rate / 4.0)
    return plan


def replay_chaos(sched, graphs, ops, plan):
    """Drive ``ops`` through the scheduler under ``plan``; classify outcomes.

    The handle fleet opens before the plan activates (a fleet that failed
    to open measures nothing).  Each request outcome is one of ``ok``
    (result delivered), ``injected`` (typed ``InjectedFault`` after
    retries exhausted — the fault killed this request), or ``failed``
    (any other typed error: collateral).
    """
    from repro.testing.chaos import InjectedFault

    handles = [sched.open_async(g, local_frac=1.0).result(timeout=600)
               for g in graphs]
    lat, futs = [], []
    t_start = time.perf_counter()
    with plan:
        for i, op in enumerate(ops):
            kind = op[0]
            t_enq = time.perf_counter()
            if kind == "query":
                f = sched.query_async(handles[op[1]], op[2])
            elif kind == "update":
                f = sched.update_async(handles[op[1]], add_edges=op[2],
                                       remove_edges=op[3])
            elif kind == "submit":
                f = sched.submit_async(op[1])
            elif kind == "communities":
                f = sched.communities_async(handles[op[1]], op[2])
            else:
                f = sched.open_async(op[1], local_frac=1.0)
            f.add_done_callback(
                lambda f, i=i, k=kind, t=t_enq:
                lat.append((i, k, time.perf_counter() - t)))
            futs.append((i, kind, f))
        outcomes = {}
        for i, _, f in futs:
            try:
                outcomes[i] = ("ok", f.result(timeout=600))
            except InjectedFault as ex:
                outcomes[i] = ("injected", ex)
            except Exception as ex:  # noqa: BLE001 — typed errors classified
                outcomes[i] = ("failed", ex)
    duration = time.perf_counter() - t_start
    return handles, outcomes, lat, duration


def replay_sync_masked(engine, graphs, ops, outcomes):
    """Fault-free oracle applying exactly the ops that completed async.

    Failed async updates never committed (the repair is batch-scoped), so
    skipping them reconstructs the identical per-handle edge history the
    async run ended with; queries then observe the same prefix of
    committed updates FIFO order promises.
    """
    handles = [engine.open(g, local_frac=1.0) for g in graphs]
    results = {}
    for i, op in enumerate(ops):
        if outcomes[i][0] != "ok":
            continue
        kind = op[0]
        if kind == "query":
            results[i] = handles[op[1]].query(op[2])
        elif kind == "update":
            results[i] = engine.update(handles[op[1]], add_edges=op[2],
                                       remove_edges=op[3])
        elif kind == "submit":
            results[i] = engine.result(engine.submit(op[1]))
        elif kind == "communities":
            results[i] = handles[op[1]].communities(op[2])
        else:
            results[i] = engine.open(op[1], local_frac=1.0)
    return handles, results


def check_parity(ops, a_handles, outcomes, s_handles, s_results) -> bool:
    """Every completed async result bitwise-equal to the fault-free oracle."""
    ok = True
    for i, op in enumerate(ops):
        if outcomes[i][0] != "ok":
            continue
        a = outcomes[i][1]
        if op[0] in ("query", "submit"):
            ok = ok and np.array_equal(a, s_results[i])
        elif op[0] == "communities":
            ok = ok and len(a) == len(s_results[i]) and all(
                np.array_equal(x, y) for x, y in zip(a, s_results[i]))
        elif op[0] == "open":
            ok = ok and np.array_equal(a.trussness, s_results[i].trussness)
    for ha, hs in zip(a_handles, s_handles):
        ok = ok and np.array_equal(ha.edges, hs.edges)
        ok = ok and np.array_equal(ha.trussness, hs.trussness)
    return bool(ok)


def _percentiles(lat, kind=None):
    ms = [1e3 * s for _, k, s in lat if kind is None or k == kind]
    if not ms:
        return None
    return {"n": len(ms),
            "p50_ms": float(np.percentile(ms, 50)),
            "p99_ms": float(np.percentile(ms, 99)),
            "mean_ms": float(np.mean(ms)),
            "max_ms": float(np.max(ms))}


def run(rates=(0.0, 0.05, 0.10, 0.20), n_requests: int = 240,
        n_handles: int = 3, n_extras: int = 24, seed: int = 0,
        out_path: str = "BENCH_chaos.json") -> int:
    """One row per injected fault rate; correctness- and availability-gated."""
    from repro.serve.resilience import RetryPolicy
    from repro.serve.scheduler import TrussScheduler
    from repro.serve.truss_engine import TrussEngine

    graphs, extras = build_fleet(n_handles, n_extras, seed)
    report = {"bench": "chaos-serving",
              "mix": {"query": 0.60, "update": 0.15, "submit": 0.10,
                      "communities": 0.075, "open": 0.075},
              "n_handles": n_handles, "m_per_graph": int(graphs[0].shape[0]),
              "gate": {"max_rate": GATE_RATE,
                       "availability": GATE_AVAILABILITY},
              "rows": [], "ok": True}

    # warmup: pay open/update/query/communities compiles outside the sweep
    warm = TrussEngine()
    wh = warm.open(graphs[0], local_frac=1.0)
    warm.update(wh, add_edges=np.array([extras[0][0]], np.int64))
    wh.query(graphs[0][:4])
    wh.communities(COMMUNITY_K)

    for rate in rates:
        ops = make_workload(graphs, extras, n_requests, seed)
        sched = TrussScheduler(
            max_batch=16, max_delay_ms=2.0,
            max_queue=1 << 20, max_inflight=1 << 20,
            retry=RetryPolicy(max_retries=2, base_delay_s=0.001,
                              max_delay_s=0.004))
        plan = build_plan(rate, seed + 99)
        a_handles, outcomes, lat, duration = replay_chaos(
            sched, graphs, ops, plan)
        sched_stats = sched.stats()
        sched.close()

        s_engine = TrussEngine()
        s_handles, s_results = replay_sync_masked(
            s_engine, graphs, ops, outcomes)
        parity = check_parity(ops, a_handles, outcomes,
                              s_handles, s_results)

        n_ok = sum(1 for v in outcomes.values() if v[0] == "ok")
        n_injected = sum(1 for v in outcomes.values() if v[0] == "injected")
        n_failed = sum(1 for v in outcomes.values() if v[0] == "failed")
        non_injected = max(1, n_requests - n_injected)
        availability = n_ok / n_requests
        availability_non_injected = n_ok / non_injected

        row = {
            "fault_rate": rate,
            "n_requests": n_requests,
            "completed": n_ok,
            "failed_injected": n_injected,
            "failed_collateral": n_failed,
            "availability": availability,
            "availability_non_injected": availability_non_injected,
            "goodput_qps": n_ok / duration,
            "duration_seconds": duration,
            "fault_point_calls": plan.stats()["calls"],
            "injected": plan.stats()["injected"],
            "retries": sched_stats["counters"]["retries"],
            "heals": sched_stats["counters"]["heals"],
            "heal_failures": sched_stats["counters"]["heal_failures"],
            "resilience": sched_stats["resilience"],
            "latency": {k: _percentiles(lat, None if k == "all" else k)
                        for k in ("all", "query", "update", "submit",
                                  "communities", "open")},
            "parity": parity,
        }
        gated = rate <= GATE_RATE
        row["gate_ok"] = bool(parity and (
            not gated
            or availability_non_injected >= GATE_AVAILABILITY))
        report["ok"] = report["ok"] and row["gate_ok"]
        report["rows"].append(row)

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    if not report["ok"]:
        print("CHAOS BENCH FAILED: parity or availability gate",
              file=sys.stderr)
        return 1
    return 0


def rows(quick: bool = True) -> list[str]:
    """benchmarks/run.py adapter: CSV rows from a quick in-memory run."""
    import io
    from contextlib import redirect_stdout

    from benchmarks.common import row

    buf = io.StringIO()
    path = "BENCH_chaos.json"
    with redirect_stdout(buf):
        code = run(rates=(0.10,) if quick else (0.0, 0.10),
                   n_requests=120 if quick else 240, n_handles=2,
                   out_path=path)
    with open(path) as f:
        rep = json.load(f)
    out = []
    for r in rep["rows"]:
        q = r["latency"]["all"] or {}
        out.append(row(
            f"chaos/rate-{r['fault_rate']:.2f}",
            q.get("mean_ms", 0.0) / 1e3,
            f"avail={r['availability']:.3f}"
            f";goodput={r['goodput_qps']:.0f}qps"
            f";retries={r['retries']};heals={r['heals']}"
            f";parity={int(r['parity'])};exit={code}"))
    return out


def main() -> None:
    """CLI entry: ``--smoke`` is the CI gate at the 10 % fault rate."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one 10%% fault-rate point, quick gate (CI)")
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rates", type=float, nargs="*", default=None,
                    help="override the fault-rate sweep")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(run(rates=tuple(args.rates or (0.10,)),
                             n_requests=120, n_handles=2, seed=args.seed,
                             out_path=args.out))
    raise SystemExit(run(rates=tuple(args.rates or (0.0, 0.05, 0.10, 0.20)),
                         seed=args.seed, out_path=args.out))


if __name__ == "__main__":
    main()
