"""Peel-phase Pallas kernel: bitwise parity with the chunked/dense executors
and the numpy oracle, on random and adversarial graphs; plus the chunk-clamp
regression for tiny graphs."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.graphs.csr import build_csr, edges_from_arrays
from repro.graphs.gen import ring_of_cliques_edges, rmat_edges
from repro.core.pkt import pkt, prepare_peel, PEEL_MODES
from repro.core import support as support_mod
from repro.core.ref import truss_numpy


def _er_edges(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


def _star_edges(k=12):
    """Hub + k spokes: zero triangles, every edge trussness 2."""
    return np.stack([np.zeros(k, np.int64), np.arange(1, k + 1)], axis=1)


def _disconnected_edges():
    """Clique ⊔ path ⊔ isolated triangle ⊔ single edge."""
    parts = [
        [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)],   # K4
        [(10, 11), (11, 12), (12, 13)],                     # path
        [(20, 21), (20, 22), (21, 22)],                     # triangle
        [(30, 31)],                                         # lone edge
    ]
    e = np.array([p for part in parts for p in part], dtype=np.int64)
    return e


ADVERSARIAL = {
    "star": _star_edges(),
    "clique": edges_from_arrays(*np.nonzero(np.triu(np.ones((8, 8)), 1)), 8),
    "disconnected": _disconnected_edges(),
    "ring_of_cliques": ring_of_cliques_edges(4, 6),
    "rmat": rmat_edges(6, edge_factor=5, seed=9),
}


# ---------------------------------------------------------------- parity ----

@pytest.mark.parametrize("seed", range(5))
def test_pallas_parity_random(seed):
    E = _er_edges(12 + 8 * seed, 0.15 + 0.08 * seed, 100 + seed)
    if E.size == 0:
        return
    g = build_csr(E)
    ref = truss_numpy(g.El)
    chunked = pkt(g, mode="chunked")
    pallas = pkt(g, mode="pallas")
    # bitwise-equal across every field of the result, and oracle-correct
    assert np.array_equal(pallas.trussness, chunked.trussness)
    assert np.array_equal(pallas.support, chunked.support)
    assert (pallas.levels, pallas.sublevels) == \
        (chunked.levels, chunked.sublevels)
    assert np.array_equal(pallas.trussness, ref)


@pytest.mark.parametrize("name", sorted(ADVERSARIAL))
def test_pallas_parity_adversarial(name):
    g = build_csr(ADVERSARIAL[name])
    ref = truss_numpy(g.El)
    for chunk in (8, 1 << 14):
        chunked = pkt(g, mode="chunked", chunk=chunk).trussness
        pallas = pkt(g, mode="pallas", chunk=chunk).trussness
        assert np.array_equal(pallas, chunked), (name, chunk)
        assert np.array_equal(pallas, ref), (name, chunk)


def test_all_modes_agree_multi_chunk():
    g = build_csr(_er_edges(40, 0.3, 7))
    ref = truss_numpy(g.El)
    for mode in PEEL_MODES:
        for chunk in (16, 128):
            assert np.array_equal(pkt(g, mode=mode, chunk=chunk).trussness,
                                  ref), (mode, chunk)


def test_invalid_mode_rejected():
    g = build_csr(np.array([[0, 1]], np.int64))
    with pytest.raises(ValueError, match="mode"):
        pkt(g, mode="warp")


# ------------------------------------------------- chunk-clamp regression ----

@pytest.mark.parametrize("edges", [
    np.array([[0, 1]], np.int64),                     # m == 1
    np.array([[0, 1], [1, 2]], np.int64),             # m == 2, no triangle
    np.array([[0, 1], [0, 2], [1, 2]], np.int64),     # smallest triangle
])
@pytest.mark.parametrize("chunk", [1, 3, 1 << 20])
def test_tiny_graph_huge_chunk(edges, chunk):
    """chunk >> table size must clamp, not produce n_chunks == 0."""
    g = build_csr(edges)
    ref = truss_numpy(g.El)
    for mode in PEEL_MODES:
        assert np.array_equal(pkt(g, mode=mode, chunk=chunk).trussness, ref), \
            (mode, chunk)


def test_prepare_peel_always_one_chunk():
    g = build_csr(np.array([[0, 1], [1, 2]], np.int64))
    ptab = support_mod.build_peel_table(g)
    for chunk in (1, ptab.size, ptab.size + 1, 1 << 20):
        tabs, c, n_chunks = prepare_peel(ptab, g.m, chunk)
        assert n_chunks >= 1
        assert c >= 1
        assert tabs.e1.shape[0] == n_chunks * c


def test_prepare_peel_empty_graph_explicit():
    """m == 0: the explicit early-exit yields one all-padding chunk."""
    from repro.core.pkt import chunk_ranges

    g = build_csr(np.zeros((0, 2), np.int64))
    ptab = support_mod.build_peel_table(g)
    assert ptab.size == 0
    tabs, chunk, n_chunks = prepare_peel(ptab, g.m, 1 << 14)
    assert (chunk, n_chunks) == (1, 1)
    assert np.asarray(tabs.e1).tolist() == [g.m]          # anchor sentinel
    assert np.asarray(tabs.hi).tolist() == [0]            # empty probe range
    assert tabs.c_start.shape == (0,) and tabs.has_entries.shape == (0,)
    # chunk_ranges itself: empty offset array, with and without m_out
    has, cs, ce = chunk_ranges(np.zeros(1, np.int64), 4)
    assert has.shape == cs.shape == ce.shape == (0,)
    has, cs, ce = chunk_ranges(np.zeros(1, np.int64), 4, m_out=5)
    assert not has.any() and (cs == 0).all() and (ce == 0).all()


def test_prepare_peel_entryless_support_table():
    """A triangle-free orientation (star) has an *empty* support table; the
    early-exit must produce inert tables, and both support executors must
    return all-zero support."""
    g = build_csr(_star_edges())
    stab = support_mod.build_support_table(g)
    assert stab.size == 0
    tabs, chunk, n_chunks = prepare_peel(stab, g.m, 8)
    assert (chunk, n_chunks) == (1, 1)
    assert not np.asarray(tabs.has_entries).any()
    for mode in ("jnp", "pallas"):
        S = support_mod.compute_support(g, stab, mode=mode)
        assert S.shape == (g.m,) and (S == 0).all(), mode


@pytest.mark.parametrize("mode", PEEL_MODES)
def test_triangle_free_graph_all_modes(mode):
    """Triangle-free graphs peel in one level; no executor may choke on the
    all-zero support vector."""
    for edges in (_star_edges(5),
                  np.array([[0, 1], [1, 2], [2, 3], [3, 4]], np.int64)):
        g = build_csr(edges)
        res = pkt(g, mode=mode)
        assert (res.trussness == 2).all()
        assert (res.support == 0).all()


# ------------------------------------------------------- kernel lowering ----

def test_peel_kernel_compiles_interpret():
    """The CI lowering gate: jit + interpret-mode pallas_call end-to-end."""
    from repro.kernels.peel import peel_decrements

    g = build_csr(ring_of_cliques_edges(3, 4))
    ptab = support_mod.build_peel_table(g)
    tabs, chunk, n_chunks = prepare_peel(ptab, g.m, 16)
    m = g.m
    S0 = support_mod.compute_support(g)
    S_ext = jnp.concatenate([jnp.asarray(S0),
                             jnp.full((1,), 1 << 30, jnp.int32)])
    processed = jnp.zeros((m + 1,), jnp.int32).at[m].set(1)
    l = int(S0.min())
    inCurr = ((processed == 0) & (S_ext == l)).astype(jnp.int32)
    dec = peel_decrements(
        jnp.ones((n_chunks,), jnp.int32), jnp.full((1,), l, jnp.int32),
        tabs.e1, tabs.cand_slot, tabs.lo, tabs.hi,
        jnp.asarray(g.N), jnp.asarray(g.Eid),
        S_ext, processed, inCurr,
        chunk=chunk, n_chunks=n_chunks,
        iters=support_mod._search_iters(g), m=m, interpret=True)
    dec = np.asarray(dec)
    assert dec.shape == (m + 1,)
    # decrements only land on live edges above the frontier level
    live = np.asarray(S_ext[:m]) > l
    assert (dec[:m][~live] == 0).all()
