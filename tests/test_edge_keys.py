"""edge_keys int64-overflow hardening (ISSUE 8 satellite, trusslint J003).

All tests are synthetic: they exercise the packing arithmetic at the
n ≈ 2^31 boundary with a handful of edges, never allocating a graph.
"""

import numpy as np
import pytest

from repro.graphs.csr import MAX_PACK_N, edge_keys, edges_from_arrays


def test_packing_is_exact_at_n_2_31_with_int32_inputs():
    # int32 inputs at n = 2^31: the multiply must widen *before* it
    # runs, otherwise lo * n wraps at 2^31 and keys collide
    n = 1 << 31
    lo = np.array([0, 1, (1 << 31) - 2], dtype=np.int32)
    hi = np.array([1, 2, (1 << 31) - 1], dtype=np.int32)
    keys = edge_keys(lo, hi, n)
    assert keys.dtype == np.int64
    expected = [int(a) * n + int(b) for a, b in zip(lo, hi)]
    assert keys.tolist() == expected
    # round trip: unpacking recovers the endpoints exactly
    assert (keys // n).tolist() == lo.tolist()
    assert (keys % n).tolist() == hi.tolist()


def test_packing_is_exact_at_the_max_pack_boundary():
    n = MAX_PACK_N  # the largest legal pack space: n*n - 1 < 2**63
    lo = np.array([n - 2], dtype=np.int64)
    hi = np.array([n - 1], dtype=np.int64)
    key = int(edge_keys(lo, hi, n)[0])
    assert key == (n - 2) * n + (n - 1)  # python-int oracle, no wrap
    assert key > 0
    assert (n - 1) * n + (n - 1) <= np.iinfo(np.int64).max


def test_pack_space_beyond_the_bound_raises():
    lo = np.array([0], dtype=np.int64)
    hi = np.array([1], dtype=np.int64)
    with pytest.raises(ValueError, match="overflows int64"):
        edge_keys(lo, hi, MAX_PACK_N + 1)


def test_ids_outside_the_pack_space_raise():
    n = 100
    with pytest.raises(ValueError, match="vertex ids must lie in"):
        edge_keys(np.array([0]), np.array([100]), n)  # hi == n
    with pytest.raises(ValueError, match="vertex ids must lie in"):
        edge_keys(np.array([-1]), np.array([5]), n)


def test_empty_input_passes_any_bound():
    empty = np.zeros(0, dtype=np.int64)
    assert edge_keys(empty, empty, MAX_PACK_N).shape == (0,)


def test_edges_from_arrays_rejects_overflowing_id_space():
    # one edge whose endpoint pushes n past MAX_PACK_N: the packing
    # used to wrap silently here (raw lo * n + hi); it must raise now
    src = np.array([0], dtype=np.int64)
    dst = np.array([MAX_PACK_N], dtype=np.int64)
    with pytest.raises(ValueError, match="overflows int64"):
        edges_from_arrays(src, dst)


def test_edges_from_arrays_still_canonicalizes_small_inputs():
    E = edges_from_arrays(np.array([2, 1, 2, 3]), np.array([1, 2, 1, 3]))
    # dedup + u < v canonical form + self-loop (3,3) dropped
    assert E.tolist() == [[1, 2]]
