"""Equivalence tests for §Perf levers: every optimization must be exact (or
within mixed-precision tolerance) vs its baseline formulation."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import jax.random as jr

from repro.configs import reduced_config
from repro.models.model import init_params, forward
from repro.models.attention import blocked_attention
from repro.train.step import TrainState, train_step
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.launch.dryrun import parse_collectives


def test_flat_kv_attention_equivalence():
    key = jr.PRNGKey(0)
    B, Sq, Hkv, G, Dh = 2, 24, 4, 3, 16
    q = jr.normal(key, (B, Sq, Hkv, G, Dh))
    k = jr.normal(jr.fold_in(key, 1), (B, Sq, Hkv, Dh))
    v = jr.normal(jr.fold_in(key, 2), (B, Sq, Hkv, Dh))
    a = blocked_attention(q, k, v, causal=True, q_offset=0, kv_chunk=8,
                          flat_kv=False)
    b = blocked_attention(q, k, v, causal=True, q_offset=0, kv_chunk=8,
                          flat_kv=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_flat_kv_model_equivalence():
    cfg = dataclasses.replace(reduced_config("qwen3_8b"),
                              compute_dtype="float32")
    cfgF = dataclasses.replace(cfg, attn_flat_kv=True)
    params = init_params(cfg, jr.PRNGKey(1))
    toks = jr.randint(jr.PRNGKey(2), (2, 16), 0, cfg.vocab)
    l1, _, _ = forward(params, cfg, {"tokens": toks})
    l2, _, _ = forward(params, cfgF, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


def test_cast_params_once_equivalence():
    """bf16-once vs per-use casting: same loss, same (bf16-rounded) step."""
    cfg = reduced_config("smollm_135m")  # bf16 compute
    params = init_params(cfg, jr.PRNGKey(3))
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt=adamw_init(params))
    opt_cfg = AdamWConfig(lr=1e-3)
    batch = {
        "tokens": jr.randint(jr.PRNGKey(4), (4, 32), 0, cfg.vocab),
        "labels": jr.randint(jr.PRNGKey(5), (4, 32), 0, cfg.vocab),
    }
    s1, m1 = train_step(state, batch, cfg, opt_cfg, cast_params_once=True)
    s2, m2 = train_step(state, batch, cfg, opt_cfg, cast_params_once=False)
    assert abs(float(m1["ce"]) - float(m2["ce"])) < 1e-5
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s1.params, s2.params)))
    assert err < 1e-5, err


def test_bf16_param_dtype_trains():
    cfg = dataclasses.replace(reduced_config("smollm_135m"),
                              param_dtype="bfloat16")
    params = init_params(cfg, jr.PRNGKey(6))
    assert params["embed"].dtype == jnp.bfloat16
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt=adamw_init(params))
    assert jax.tree.leaves(state.opt["m"])[0].dtype == jnp.float32
    opt_cfg = AdamWConfig(lr=1e-3)
    batch = {
        "tokens": jr.randint(jr.PRNGKey(7), (4, 32), 0, cfg.vocab),
        "labels": jr.randint(jr.PRNGKey(8), (4, 32), 0, cfg.vocab),
    }
    s, m = train_step(state, batch, cfg, opt_cfg)
    assert np.isfinite(float(m["ce"]))
    assert jax.tree.leaves(s.params)[0].dtype == jnp.bfloat16


def test_parse_collectives():
    hlo = """
  %ag = f32[128,256]{1,0} all-gather(f32[8,256]{1,0} %x), replica_groups=...
  %ar.1 = bf16[64]{0} all-reduce(bf16[64]{0} %y), to_apply=%add
  %t = (f32[32]{0}, f32[32]{0}) all-reduce-start(f32[32]{0} %a, f32[32]{0} %b)
  %d = f32[32]{0} all-reduce-done((f32[32],f32[32]) %t)
  %rs = f32[4,8]{1,0} reduce-scatter(f32[64,8]{1,0} %z), dimensions={0}
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 128 * 256 * 4
    assert out["all-reduce"]["count"] == 2
    # ar.1: 2x result; tuple start: 2x both results; done line skipped
    assert out["all-reduce"]["bytes"] == 2 * 64 * 2 + 2 * (32 * 4 * 2)
    assert out["reduce-scatter"]["bytes"] == 64 * 8 * 4  # operand bytes


def test_hybrid_python_unroll_cost_visibility():
    """The unrolled hybrid path must not contain lax.cond (cost analysis
    sums both branches — measured 6× overcount)."""
    cfg = dataclasses.replace(reduced_config("zamba2_7b"),
                              compute_dtype="float32", unroll_scans=True)
    params = init_params(cfg, jr.PRNGKey(9))
    toks = jr.randint(jr.PRNGKey(10), (1, 8), 0, cfg.vocab)
    jaxpr = jax.make_jaxpr(
        lambda p, b: forward(p, cfg, b)[0])(params, {"tokens": toks})
    prims = {e.primitive.name for e in jaxpr.eqns}
    assert "cond" not in prims, prims
