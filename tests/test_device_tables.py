"""Device-side wedge-table construction (DESIGN.md §10): the jitted XLA
builders must reproduce the host numpy builders row-for-row, and every
pipeline that consumes them (support, pkt, engine, dist) must be bitwise
identical across ``table_mode`` ∈ {numpy, device}.

Runs under real ``hypothesis`` and under the deterministic fallback shim
(``repro/testing/hypothesis_fallback.py``) — same contract as
``tests/test_parity_matrix.py``.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import support as support_mod
from repro.core.pkt import pkt
from repro.graphs.csr import build_csr, edges_from_arrays
from repro.graphs.gen import (barabasi_albert_edges, erdos_renyi_edges,
                              ring_of_cliques_edges, rmat_edges)
from repro.kernels.wedge_common import next_pow2


def _star(k):
    return np.stack([np.zeros(k, np.int64), np.arange(1, k + 1)], axis=1)


#: adversarial shapes: empty graph, triangle-free (star has an *empty*
#: oriented support table, the path an empty-range-heavy one), raw
#: multi-edge/self-loop/swapped input (canonicalized like production entry
#: points), plus dense and skewed standards
ADVERSARIAL = {
    "empty": np.zeros((0, 2), np.int64),
    "single_edge": np.array([[0, 1]], np.int64),
    "star": _star(9),
    "path": np.array([[0, 1], [1, 2], [2, 3], [3, 4]], np.int64),
    "multi_edge_input": np.array(
        [[0, 1], [1, 0], [0, 1], [2, 2], [1, 2], [0, 2], [3, 3], [2, 3]],
        np.int64),
    "clique": edges_from_arrays(*np.nonzero(np.triu(np.ones((7, 7)), 1)), 7),
    "ring_of_cliques": ring_of_cliques_edges(4, 5),
    "rmat": rmat_edges(6, edge_factor=5, seed=3),
}


def _graph(raw):
    E = edges_from_arrays(raw[:, 0], raw[:, 1]) if raw.size else raw
    return build_csr(E)


def _assert_tables_equal(g):
    """Device builders reproduce the numpy builders bit-for-bit, with inert
    sentinel padding beyond the real entries."""
    stab = support_mod.build_support_table(g)
    ptab = support_mod.build_peel_table(g)
    assert support_mod.support_table_size(g) == stab.size
    assert support_mod.peel_table_size(g) == ptab.size
    if g.m == 0:
        return
    dev = g.device_arrays()

    sp = next_pow2(max(1, stab.size))
    e1, cand, lo, hi, off = support_mod._build_support_table_dev(
        dev["El"][:, 0], dev["El"][:, 1], dev["Es"], dev["Eo"],
        jnp.int32(g.m), m=g.m, size=sp)
    k = stab.size
    assert np.array_equal(np.asarray(e1)[:k], stab.e1)
    assert np.array_equal(np.asarray(cand)[:k], stab.cand_slot)
    assert np.array_equal(np.asarray(lo)[:k], stab.lo)
    assert np.array_equal(np.asarray(hi)[:k], stab.hi)
    assert np.array_equal(np.asarray(off), stab.off)
    assert (np.asarray(e1)[k:] == g.m).all()          # anchor sentinel
    assert (np.asarray(lo)[k:] == np.asarray(hi)[k:]).all()  # empty range

    pp = next_pow2(max(1, ptab.size))
    chunk = max(1, min(64, pp))
    e1, cand, lo, hi, off, c_start, c_end, has = \
        support_mod._build_peel_table_dev(
            dev["El"][:, 0], dev["El"][:, 1], dev["Es"], jnp.int32(g.m),
            m=g.m, size=pp, chunk=chunk)
    k = ptab.size
    assert np.array_equal(np.asarray(e1)[:k], ptab.e1)
    assert np.array_equal(np.asarray(cand)[:k], ptab.cand_slot)
    assert np.array_equal(np.asarray(lo)[:k], ptab.lo)
    assert np.array_equal(np.asarray(hi)[:k], ptab.hi)
    assert np.array_equal(np.asarray(off), ptab.off)
    assert (np.asarray(e1)[k:] == g.m).all()
    # chunk-range metadata matches the host bookkeeping
    from repro.core.pkt import chunk_ranges

    h_has, h_cs, h_ce = chunk_ranges(ptab.off, chunk)
    assert np.array_equal(np.asarray(has), h_has)
    assert np.array_equal(np.asarray(c_start)[h_has], h_cs[h_has])
    assert np.array_equal(np.asarray(c_end)[h_has], h_ce[h_has])


@pytest.mark.parametrize("name", sorted(ADVERSARIAL))
def test_builders_equal_adversarial(name):
    _assert_tables_equal(_graph(ADVERSARIAL[name]))


@st.composite
def raw_graph(draw):
    kind = draw(st.sampled_from(["er", "powerlaw", "noisy"]))
    seed = draw(st.integers(min_value=0, max_value=9999))
    if kind == "er":
        n = draw(st.integers(min_value=4, max_value=26))
        return erdos_renyi_edges(
            n, avg_degree=float(draw(st.integers(min_value=2, max_value=8))),
            seed=seed)
    if kind == "powerlaw":
        return barabasi_albert_edges(
            draw(st.integers(min_value=6, max_value=22)),
            m_attach=draw(st.integers(min_value=2, max_value=4)), seed=seed)
    n = draw(st.integers(min_value=3, max_value=14))
    k = draw(st.integers(min_value=1, max_value=40))
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, n, k), rng.integers(0, n, k)],
                    axis=1).astype(np.int64)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(raw_graph())
def test_builders_equal_random(raw):
    g = _graph(raw)
    if g.m == 0:
        return
    _assert_tables_equal(g)


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(raw_graph())
def test_support_table_mode_parity(raw):
    g = _graph(raw)
    if g.m == 0:
        return
    base = support_mod.compute_support(g, table_mode="numpy")
    for mode in support_mod.SUPPORT_MODES:
        S = support_mod.compute_support(g, mode=mode, table_mode="device")
        assert np.array_equal(S, base), mode
        assert S.dtype == base.dtype


def test_pkt_table_mode_parity_full_result():
    for raw in (ring_of_cliques_edges(3, 5), rmat_edges(6, edge_factor=4,
                                                        seed=7)):
        g = _graph(raw)
        a = pkt(g, table_mode="numpy")
        b = pkt(g, table_mode="device")
        assert np.array_equal(a.trussness, b.trussness)
        assert np.array_equal(a.support, b.support)
        assert (a.levels, a.sublevels) == (b.levels, b.sublevels)


def test_device_arrays_cached_per_graph():
    g = _graph(ring_of_cliques_edges(3, 4))
    d1 = g.device_arrays()
    d2 = g.device_arrays()
    assert d1 is d2
    assert d1["N"] is d2["N"]
    assert set(d1) == {"N", "Eid", "Es", "Eo", "El"}
    assert np.array_equal(np.asarray(d1["N"]), g.N)


def test_invalid_table_mode_rejected():
    g = _graph(np.array([[0, 1]], np.int64))
    with pytest.raises(ValueError, match="table_mode"):
        pkt(g, table_mode="gpu")
    with pytest.raises(ValueError, match="table_mode"):
        support_mod.compute_support(g, table_mode="gpu")
    from repro.serve.truss_engine import TrussEngine

    with pytest.raises(ValueError, match="table_mode"):
        TrussEngine(table_mode="gpu")


def test_prebuilt_table_forces_numpy_path():
    """Passing a prebuilt host table keeps the legacy path (the table is
    honored, not silently rebuilt on device)."""
    g = _graph(ring_of_cliques_edges(3, 4))
    stab = support_mod.build_support_table(g)
    ptab = support_mod.build_peel_table(g)
    res = pkt(g, support_table=stab, peel_table=ptab)
    assert np.array_equal(res.trussness, pkt(g).trussness)
