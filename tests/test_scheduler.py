"""Async scheduler: parity, coalescing, dispatch policy, failure paths."""

import time

import numpy as np
import pytest

from repro.core.pkt import truss_pkt
from repro.graphs.csr import edges_from_arrays
from repro.serve.scheduler import Overloaded, TrussScheduler
from repro.serve.truss_engine import TrussEngine


def _er_edges(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


def _expected(edges):
    e = np.asarray(edges, np.int64)
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    n = int(e.max()) + 1
    uniq = np.unique(lo * n + hi)
    E = np.stack([uniq // n, uniq % n], axis=1)
    t = truss_pkt(E)
    return t[np.searchsorted(uniq, lo * n + hi)]


# ------------------------------------------------------------------ parity --


def test_submit_async_parity_mixed_sizes():
    """Async trussness is bitwise-equal to the synchronous reference."""
    fleet = [_er_edges(12, 0.4, 0), _er_edges(30, 0.25, 1),
             _er_edges(12, 0.4, 2), np.array([[0, 1], [1, 2]], np.int64)]
    with TrussScheduler(max_batch=4, max_delay_ms=1.0) as sched:
        futs = [sched.submit_async(e) for e in fleet]
        for e, f in zip(fleet, futs):
            assert np.array_equal(f.result(timeout=120), _expected(e))


def test_open_query_communities_async():
    e = _er_edges(16, 0.4, 3)
    with TrussScheduler(max_batch=4, max_delay_ms=1.0) as sched:
        h = sched.open_async(e).result(timeout=120)
        q = sched.query_async(h, e[:5]).result(timeout=120)
        assert np.array_equal(q, _expected(e)[:5])
        kmax = int(max(2, q.max()))
        comms = sched.communities_async(h, kmax).result(timeout=120)
        direct = h.communities(kmax)
        assert len(comms) == len(direct)
        for got, want in zip(comms, direct):
            assert np.array_equal(got, want)


# -------------------------------------------------------- update coalescing --


def test_update_coalescing_same_handle():
    """Consecutive updates on one handle merge into one composed repair."""
    e = _er_edges(16, 0.35, 4)
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0)
    h = sched.engine.open(e)
    a1 = np.array([[0, 9], [1, 10]], np.int64)
    a2 = np.array([[2, 11]], np.int64)
    f1 = sched.update_async(h, add_edges=a1)
    f2 = sched.update_async(h, add_edges=a2)
    fq = sched.query_async(h, e[:4])
    sched.start()
    st1, st2 = f1.result(timeout=120), f2.result(timeout=120)
    q = fq.result(timeout=120)
    sched.close()
    assert st1 is st2
    assert st1.coalesced == 2
    # state equals applying both batches, and the query observed it
    full = np.concatenate([e, a1, a2])
    assert np.array_equal(h.query(e[:4]), _expected(full)[:4])
    assert np.array_equal(q, _expected(full)[:4])
    assert sched.stats()["counters"]["coalesced_updates"] == 1


def test_coalesced_insert_then_delete_not_resurrected():
    """An edge inserted in one queued batch and deleted in a later one must
    not survive the composed repair — and must not be resurrected through
    the batched insertion region seed (§13)."""
    e = _er_edges(16, 0.35, 21)
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0)
    h = sched.engine.open(e)
    ghost = np.array([[0, 17]], np.int64)     # vertex 17 > n: surely absent
    k1 = np.array([[1, 18]], np.int64)
    k2 = np.array([[2, 19]], np.int64)
    f1 = sched.update_async(h, add_edges=np.concatenate([ghost, k1]))
    f2 = sched.update_async(h, add_edges=k2, remove_edges=ghost)
    sched.start()
    st1, st2 = f1.result(timeout=120), f2.result(timeout=120)
    sched.close()
    assert st1 is st2 and st1.coalesced == 2
    # the scheduler's composed output lands on the batched insertion path
    assert st1.insert_mode == "batched"
    cur = {(int(u), int(v)) for u, v in h.edges}
    assert (0, 17) not in cur                 # not resurrected
    assert {(1, 18), (2, 19)} <= cur
    assert np.array_equal(h.trussness, truss_pkt(h.edges))


def test_query_is_barrier_between_updates():
    """A query splits the update run: it observes exactly its FIFO prefix."""
    e = _er_edges(16, 0.35, 5)
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0)
    h = sched.engine.open(e)
    a1 = np.array([[0, 9]], np.int64)
    a2 = np.array([[1, 10]], np.int64)
    f1 = sched.update_async(h, add_edges=a1)
    fq = sched.query_async(h, e[:4])
    f2 = sched.update_async(h, add_edges=a2)
    sched.start()
    st1, st2 = f1.result(timeout=120), f2.result(timeout=120)
    q = fq.result(timeout=120)
    sched.close()
    assert st1 is not st2
    assert st1.coalesced == 1 and st2.coalesced == 1
    # the barrier query saw a1 but not a2
    assert np.array_equal(q, _expected(np.concatenate([e, a1]))[:4])
    assert np.array_equal(h.query(e[:4]),
                          _expected(np.concatenate([e, a1, a2]))[:4])


# --------------------------------------------------------- dispatch policy --


def test_full_bucket_dispatches_before_deadline():
    """max_batch requests of one size class release without the delay."""
    with TrussScheduler(max_batch=2, max_delay_ms=60_000.0) as sched:
        e1, e2 = _er_edges(14, 0.4, 6), _er_edges(14, 0.4, 7)
        f1, f2 = sched.submit_async(e1), sched.submit_async(e2)
        assert np.array_equal(f1.result(timeout=120), _expected(e1))
        assert np.array_equal(f2.result(timeout=120), _expected(e2))
        assert sched.stats()["counters"]["dispatches"] >= 1


def test_deadline_dispatches_partial_bucket():
    """A non-full bucket still dispatches once its oldest hits max_delay."""
    with TrussScheduler(max_batch=64, max_delay_ms=30.0) as sched:
        fleet = [_er_edges(14, 0.4, s) for s in (8, 9, 10)]
        futs = [sched.submit_async(e) for e in fleet]
        for e, f in zip(fleet, futs):
            assert np.array_equal(f.result(timeout=120), _expected(e))
        st = sched.stats()
        assert st["counters"]["dispatches"] >= 1
        assert st["buckets_waiting"] == {}


# ------------------------------------------------------- admission control --


def test_queue_depth_shedding():
    """Admissions beyond max_queue shed with Overloaded, typed and counted."""
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0,
                           max_queue=2)
    e = _er_edges(12, 0.4, 11)
    f1, f2 = sched.submit_async(e), sched.submit_async(e)
    with pytest.raises(Overloaded, match="queue depth"):
        sched.submit_async(e)
    assert sched.stats()["counters"]["shed"] == 1
    sched.start()
    assert np.array_equal(f1.result(timeout=120), _expected(e))
    assert np.array_equal(f2.result(timeout=120), _expected(e))
    # capacity freed: the retry admits
    f3 = sched.submit_async(e)
    assert np.array_equal(f3.result(timeout=120), _expected(e))
    sched.close()


def test_per_tenant_inflight_shedding():
    """One tenant at max_inflight sheds; other tenants still admit."""
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0,
                           max_inflight=1)
    e = _er_edges(12, 0.4, 12)
    f1 = sched.submit_async(e, tenant="a")
    with pytest.raises(Overloaded, match="tenant 'a'"):
        sched.submit_async(e, tenant="a")
    f2 = sched.submit_async(e, tenant="b")
    sched.start()
    assert np.array_equal(f1.result(timeout=120), _expected(e))
    assert np.array_equal(f2.result(timeout=120), _expected(e))
    sched.close()
    assert sched.stats()["inflight"] == {}


# ------------------------------------------------------------ error typing --


def test_handle_type_and_closed_errors():
    """Non-handle targets TypeError; closed handles ValueError, synchronously."""
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0)
    e = _er_edges(12, 0.4, 13)
    h = sched.engine.open(e)
    with pytest.raises(TypeError, match="TrussHandle"):
        sched.query_async(7, e[:2])     # a ticket int is not a handle
    sched.engine.close(h)
    with pytest.raises(ValueError, match="closed"):
        sched.update_async(h, add_edges=np.array([[0, 9]], np.int64))
    with pytest.raises(ValueError, match="closed"):
        sched.communities_async(h, 3)
    sched.start()
    sched.close()


def test_engine_validation_error_lands_on_future():
    """Bad payloads admit, then the engine's ValueError rides the future."""
    with TrussScheduler(max_batch=4, max_delay_ms=1.0) as sched:
        f = sched.submit_async(np.array([[-1, 2]], np.int64))
        with pytest.raises(ValueError):
            f.result(timeout=120)
        assert sched.stats()["counters"]["errors"] == 1


def test_closed_scheduler_rejects_and_close_is_idempotent():
    sched = TrussScheduler(max_batch=4, max_delay_ms=1.0)
    sched.close()
    sched.close()       # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit_async(np.array([[0, 1]], np.int64))


def test_close_without_drain_cancels_queued():
    """close(drain=False) rejects waiting work with typed Cancelled."""
    from repro.serve import Cancelled

    sched = TrussScheduler(max_batch=64, max_delay_ms=60_000.0)
    e = _er_edges(14, 0.4, 14)
    f1, f2 = sched.submit_async(e), sched.submit_async(e)
    # let the loop route them into a bucket that can never fill
    deadline = time.perf_counter() + 30
    while (sched.stats()["buckets_waiting"] == {}
           and time.perf_counter() < deadline):
        time.sleep(0.005)
    sched.close(drain=False)
    for f in (f1, f2):
        assert f.done() and not f.cancelled()   # resolved, typed
        with pytest.raises(Cancelled):
            f.result(timeout=0)
    # the error carries kind and queue position for caller-side retry logic
    exc = f1.exception(timeout=0)
    assert exc.kind == "submit" and isinstance(exc.position, int)
    st = sched.stats()
    assert st["counters"]["cancelled"] == 2
    assert st["depth"] == 0
    assert sched.engine._pending == []      # tickets discarded, not leaked


def test_bad_constructor_args():
    with pytest.raises(ValueError):
        TrussScheduler(max_batch=0)
    with pytest.raises(ValueError):
        TrussScheduler(max_delay_ms=-1.0)
    with pytest.raises(ValueError):
        TrussScheduler(max_queue=0)
    with pytest.raises(ValueError):
        TrussScheduler(max_inflight=0)
    with pytest.raises(ValueError):
        TrussScheduler(TrussEngine(), mode="device")   # engine + kwargs


def test_stats_shape():
    """stats() is JSON-safe and carries every stage and counter."""
    import json

    with TrussScheduler(max_batch=2, max_delay_ms=1.0) as sched:
        e = _er_edges(12, 0.4, 15)
        sched.submit_async(e).result(timeout=120)
        st = sched.stats()
    json.dumps(st)      # must not raise
    for stage in ("queue_wait", "build", "dispatch", "readback",
                  "open", "repair", "query"):
        assert {"count", "seconds", "max_seconds"} <= set(st["stages"][stage])
    assert st["counters"]["submit"] == 1
    assert st["counters"]["done"] == 1
    assert "engine" in st
