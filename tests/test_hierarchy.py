"""Truss community hierarchy (DESIGN.md §11): device label-propagation vs
host union-find parity, nesting invariants, and index survival across
``engine.update`` — all against a brute-force triangle-BFS oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graphs.csr import build_csr, edges_from_arrays
from repro.graphs.datasets import k4_edges, paper_fig1_edges, path_edges
from repro.graphs.gen import ring_of_cliques_edges
from repro.core.pkt import PEEL_MODES
from repro.core.hierarchy import HIER_MODES, TrussHierarchy, \
    hierarchy_from_graph
from repro.core.truss_inc import IncrementalTruss
from repro.serve.truss_engine import TrussEngine

SETTINGS = dict(max_examples=10, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


def _er_edges(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


def _brute_labels(T, tri, k):
    """Third implementation: python BFS over the active triangle adjacency.

    Deliberately structure-free (dict-of-sets + queue) so it shares nothing
    with either production builder."""
    m = T.shape[0]
    labels = np.full(m, -1, np.int64)
    adj = {e: set() for e in range(m)}
    for row in tri:
        if T[row].min() >= k:
            a, b, c = (int(x) for x in row)
            adj[a] |= {b, c}
            adj[b] |= {a, c}
            adj[c] |= {a, b}
    seen = set()
    for e in range(m):
        if T[e] < k or e in seen:
            continue
        queue, comp = [e], {e}
        while queue:
            x = queue.pop()
            for y in adj[x]:
                if y not in comp:
                    comp.add(y)
                    queue.append(y)
        seen |= comp
        for x in comp:
            labels[x] = e          # ascending scan: e is the component min
    return labels


def _hier_pair(inc):
    dev = TrussHierarchy(inc.trussness, inc.triangles, mode="device")
    host = TrussHierarchy(inc.trussness, inc.triangles, mode="host")
    return dev, host


def _assert_full_parity(inc, ctx=None):
    dev, host = _hier_pair(inc)
    dev.build_all()
    host.build_all()
    for k in dev.levels:
        ld, lh = dev.level_labels(k), host.level_labels(k)
        assert np.array_equal(ld, lh), (ctx, k)
        assert np.array_equal(
            ld, _brute_labels(inc.trussness, inc.triangles, k)), (ctx, k)
    return dev


# --------------------------------------------------------------- parity -----

def test_parity_random_graphs():
    for seed, (n, p) in enumerate([(12, 0.5), (20, 0.3), (28, 0.2)]):
        E = _er_edges(n, p, seed)
        if E.shape[0] == 0:
            continue
        _assert_full_parity(IncrementalTruss(E), seed)


@pytest.mark.parametrize("edges_fn", [
    paper_fig1_edges, k4_edges, lambda: path_edges(6),
    lambda: ring_of_cliques_edges(4, 5),
    lambda: np.array([[0, 1]], np.int64),
])
def test_parity_adversarial(edges_fn):
    _assert_full_parity(IncrementalTruss(edges_fn()))


@pytest.mark.parametrize("mode", PEEL_MODES)
@pytest.mark.parametrize("hier_mode", HIER_MODES)
def test_parity_across_executor_modes(mode, hier_mode):
    """The index is identical whatever executor decomposed the graph and
    whichever builder labels it."""
    eng = TrussEngine(mode=mode, hier_mode=hier_mode)
    h = eng.open(ring_of_cliques_edges(3, 5))
    hier = h.hierarchy()
    assert hier.mode == hier_mode
    ref = _assert_full_parity(h._inc, (mode, hier_mode))
    for k in ref.levels:
        assert np.array_equal(hier.level_labels(k), ref.level_labels(k))


def test_host_out_of_order_level_requests():
    """Regression: the shared top-down union-find must not leak coarser
    unions into a later request for a finer (higher-k) level."""
    E = ring_of_cliques_edges(4, 5)
    inc = IncrementalTruss(E)
    h = TrussHierarchy(inc.trussness, inc.triangles, mode="host")
    l2 = h.level_labels(2)           # advances the shared state to k=2
    l5 = h.level_labels(5)           # above the frontier: fresh union-find
    assert np.array_equal(l5, _brute_labels(inc.trussness, inc.triangles, 5))
    assert np.array_equal(l2, _brute_labels(inc.trussness, inc.triangles, 2))


def test_device_lazy_equals_sweep():
    """build_all's warm-started level sweep must be bitwise-identical to the
    same levels built lazily in sweep order, and the convergence pre-check
    must actually skip some dispatches (every level flooding from scratch
    was the BENCH_hier pathology)."""
    inc = IncrementalTruss(_er_edges(24, 0.3, 3))
    sweep = TrussHierarchy(inc.trussness, inc.triangles).build_all()
    lazy = TrussHierarchy(inc.trussness, inc.triangles)
    for k in sorted(lazy.levels, reverse=True):   # warm-start path
        assert np.array_equal(lazy.level_labels(k), sweep.level_labels(k))
    n_levels = len(sweep.levels)
    built = (sweep.stats["device_levels"] + sweep.stats["converged_levels"]
             + sweep.stats["seeded_levels"])
    assert built == n_levels


def test_sweep_skips_converged_levels():
    """On a clique every triangle sits at the top level, so only k_max does
    any flood work — its tiny active set closes in host seed rounds — and
    every coarser level is provably converged and must skip (bitwise-
    identically — checked against the brute oracle)."""
    n = 6
    E = edges_from_arrays(*np.nonzero(np.triu(np.ones((n, n)), 1)), n)
    inc = IncrementalTruss(E)
    h = TrussHierarchy(inc.trussness, inc.triangles).build_all()
    assert h.k_max == n
    assert h.stats["device_levels"] == 0          # 20 rows: host-seeded
    assert h.stats["seeded_levels"] == 1          # only k_max floods
    assert h.stats["converged_levels"] == n - 2   # k = 2 .. k_max-1 skip
    for k in h.levels:
        assert np.array_equal(
            h.level_labels(k),
            _brute_labels(inc.trussness, inc.triangles, k)), k


def test_forced_device_flood_matches_host(monkeypatch):
    """With the host-seeding cutoff disabled every level must take the real
    device flood dispatch and still match the host oracle bitwise — keeps
    ``_labelprop`` covered now that small active sets close on the host."""
    import repro.core.hierarchy as hier_mod

    monkeypatch.setattr(hier_mod, "_SEED_ROWS_MAX", 0)
    inc = IncrementalTruss(_er_edges(24, 0.3, 3))
    dev = TrussHierarchy(inc.trussness, inc.triangles, mode="device")
    dev.build_all()
    assert dev.stats["device_levels"] > 0
    assert dev.stats["seeded_levels"] == 0
    host = TrussHierarchy(inc.trussness, inc.triangles, mode="host")
    for k in dev.levels:
        assert np.array_equal(dev.level_labels(k), host.level_labels(k)), k


def test_device_cold_out_of_order_requests():
    """A lazy request with no finer level built (no warm start) must still
    produce canonical labels — the pre-check may only skip when it can
    prove convergence."""
    inc = IncrementalTruss(_er_edges(24, 0.3, 3))
    h = TrussHierarchy(inc.trussness, inc.triangles)
    for k in sorted(h.levels):                    # coldest-first order
        assert np.array_equal(
            h.level_labels(k),
            _brute_labels(inc.trussness, inc.triangles, k)), k


# ------------------------------------------------------------- structure ----

def test_nesting_and_parent_links():
    """Level-k communities refine level-(k-1): every community maps into
    exactly one parent, and all its edges share that parent's label."""
    inc = IncrementalTruss(_er_edges(26, 0.35, 7))
    hier = TrussHierarchy(inc.trussness, inc.triangles).build_all()
    for k in hier.levels:
        if k == 2:
            reps, parents = hier.parents(2)
            assert np.array_equal(reps, parents)
            continue
        lk, lcoarse = hier.level_labels(k), hier.level_labels(k - 1)
        live = lk >= 0
        assert (lcoarse[live] >= 0).all()        # live at k => live at k-1
        # the coarse label is constant across each fine community
        assert np.array_equal(lcoarse[live], lcoarse[lk[live]])
        reps, parents = hier.parents(k)
        assert np.array_equal(parents, lcoarse[reps])


def test_triangle_free_edges_are_singletons():
    inc = IncrementalTruss(path_edges(7))
    hier = TrussHierarchy(inc.trussness, inc.triangles).build_all()
    assert hier.k_max == 2
    comms = hier.communities(2)
    assert len(comms) == inc.m
    assert all(c.shape == (1,) for c in comms)


def test_empty_and_out_of_range_levels():
    inc = IncrementalTruss(np.zeros((0, 2), np.int64))
    hier = TrussHierarchy(inc.trussness, inc.triangles)
    assert list(hier.levels) == []
    assert hier.communities(2) == []
    inc = IncrementalTruss(k4_edges())
    hier = TrussHierarchy(inc.trussness, inc.triangles)
    assert hier.communities(1) == []              # k < 2: nothing is labeled
    assert hier.level_labels(1).tolist() == [-1] * inc.m
    assert hier.communities(hier.k_max + 1) == []
    assert hier.community_of(0, hier.k_max + 1).shape == (0,)
    assert hier.community_of(99, 2).shape == (0,)


def test_validation():
    with pytest.raises(ValueError, match="mode must be one of"):
        TrussHierarchy(np.zeros(0, np.int64), np.zeros((0, 3), np.int64),
                       mode="gpu")
    with pytest.raises(ValueError, match="beyond"):
        TrussHierarchy(np.array([2, 2], np.int64),
                       np.array([[0, 1, 7]], np.int64))
    with pytest.raises(ValueError, match="hier_mode"):
        TrussEngine(hier_mode="nope")
    with pytest.raises(ValueError, match="hier_mode"):
        IncrementalTruss(k4_edges(), hier_mode="nope")


def test_hierarchy_from_graph():
    E = paper_fig1_edges()
    inc = IncrementalTruss(E)
    g = build_csr(E.astype(np.int64))
    hier = hierarchy_from_graph(g, inc.trussness)
    ref = TrussHierarchy(inc.trussness, inc.triangles).build_all()
    for k in ref.levels:
        assert np.array_equal(hier.level_labels(k), ref.level_labels(k))


# -------------------------------------------------------------- serving -----

def test_handle_query_api():
    eng = TrussEngine()
    h = eng.open(ring_of_cliques_edges(4, 6))
    comms = h.communities(6)
    assert len(comms) == 4 and all(c.shape == (15, 2) for c in comms)
    # edge query: one clique; endpoint order / swap tolerated
    c = h.community((1, 0), 6)
    assert c.shape == (15, 2)
    # the community contains the queried edge
    assert ((c[:, 0] == 0) & (c[:, 1] == 1)).any()
    # vertex query: list of communities around the vertex
    vs = h.community(0, 6)
    assert [x.shape for x in vs] == [(15, 2)]
    # below-level edge: empty; absent edge: descriptive error
    t = h.query(np.array([[0, 1]]))[0]
    assert h.community((0, 1), int(t) + 1).shape[0] == 0
    with pytest.raises(ValueError, match="not present"):
        h.community((0, 9999), 3)
    # the index is cached on the handle until an update invalidates it
    assert h.hierarchy() is h.hierarchy()


def test_index_survives_local_update_bridge():
    """Deterministic remap case: deleting a trussness-2 bridge carries all
    higher levels by id translation and only dirties level 2."""
    eng = TrussEngine()
    h = eng.open(ring_of_cliques_edges(4, 6), local_frac=1.0)
    h.hierarchy().build_all()
    bridge = h.edges[int(np.argmin(h.trussness))]
    st = eng.update(h, remove_edges=bridge.reshape(1, 2))
    assert st.mode == "local"
    hier = h.hierarchy()
    assert hier.stats["remapped_levels"] >= hier.k_max - 2
    _assert_full_parity(h._inc, "bridge")
    fresh = TrussHierarchy(h._inc.trussness, h._inc.triangles,
                           mode="host").build_all()
    for k in fresh.levels:
        assert np.array_equal(hier.level_labels(k), fresh.level_labels(k))


@st.composite
def update_scripts(draw):
    n = draw(st.integers(6, 18))
    density = draw(st.floats(0.15, 0.5))
    seed = draw(st.integers(0, 2**31 - 1))
    E = _er_edges(n, density, seed)
    batches = []
    for _ in range(draw(st.integers(1, 3))):
        batches.append((draw(st.integers(0, 5)), draw(st.integers(0, 5))))
    return n, E, batches, seed


@given(script=update_scripts(),
       insert_mode=st.sampled_from(["sequential", "batched"]))
@settings(**SETTINGS)
def test_property_index_survives_updates(script, insert_mode):
    """After any insert/delete script — under either insertion repair
    strategy (§13) — the carried index is bitwise equal to a fresh rebuild
    of either mode (and to the brute oracle)."""
    n, E, batches, seed = script
    if E.shape[0] == 0:
        return
    eng = TrussEngine(insert_mode=insert_mode)
    h = eng.open(E, local_frac=1.0)
    h.hierarchy().build_all()
    rng = np.random.default_rng(seed + 1)
    for n_add, n_rm in batches:
        cur = h.edges
        m = cur.shape[0]
        rm = cur[rng.choice(m, size=min(n_rm, m), replace=False)] \
            if m else np.zeros((0, 2), np.int64)
        add = np.stack([rng.integers(0, n + 2, n_add),
                        rng.integers(0, n + 2, n_add)], axis=1)
        add = add[add[:, 0] != add[:, 1]]
        eng.update(h, add_edges=add, remove_edges=rm)
        if h.m == 0:
            continue
        hier = h.hierarchy()
        fresh = _assert_full_parity(h._inc, (n_add, n_rm))
        for k in fresh.levels:
            assert np.array_equal(hier.level_labels(k),
                                  fresh.level_labels(k)), k


def test_index_survives_batched_multi_insert():
    """A multi-insert batch repaired through the merged-region path (§13)
    carries the index: levels above k_hi remapped, the rest dirty-rebuilt —
    guaranteed deterministic coverage whichever property backend runs."""
    eng = TrussEngine(insert_mode="batched")
    h = eng.open(ring_of_cliques_edges(4, 5), local_frac=1.0)
    h.hierarchy().build_all()
    st_ = eng.update(h, add_edges=np.array([[0, 7], [1, 11], [2, 16]],
                                           np.int64))
    assert st_.mode == "local" and st_.insert_mode == "batched"
    hier = h.hierarchy()
    fresh = _assert_full_parity(h._inc, "batched-insert")
    for k in fresh.levels:
        assert np.array_equal(hier.level_labels(k), fresh.level_labels(k)), k


@given(update_scripts())
@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_full_fallback_drops_index(script):
    """local_frac=0 forces full rebuilds; the index must come back fresh
    (never stale) through that path too."""
    n, E, batches, seed = script
    if E.shape[0] == 0:
        return
    eng = TrussEngine()
    h = eng.open(E, local_frac=0.0)
    h.hierarchy().build_all()
    rng = np.random.default_rng(seed + 1)
    for n_add, n_rm in batches:
        cur = h.edges
        m = cur.shape[0]
        rm = cur[rng.choice(m, size=min(n_rm, m), replace=False)] \
            if m else np.zeros((0, 2), np.int64)
        add = np.stack([rng.integers(0, n + 2, n_add),
                        rng.integers(0, n + 2, n_add)], axis=1)
        add = add[add[:, 0] != add[:, 1]]
        eng.update(h, add_edges=add, remove_edges=rm)
        if h.m:
            _assert_full_parity(h._inc, "full-fallback")
