import os
import sys

# Tests run on the single real CPU device (the dry-run and multi-device tests
# spawn subprocesses that set XLA_FLAGS themselves — per the assignment this
# must NOT be set globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Make `import repro` work whether or not PYTHONPATH=src was exported.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Property tests prefer real hypothesis; offline environments fall back to the
# deterministic N-example shim so the suite still collects and runs.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro.testing.hypothesis_fallback import install as _install_hyp

    _install_hyp()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_edges(rng, n_lo=5, n_hi=40, p_lo=0.05, p_hi=0.5):
    """Canonical random undirected simple graph edges."""
    from repro.graphs.csr import edges_from_arrays
    n = int(rng.integers(n_lo, n_hi))
    p = rng.uniform(p_lo, p_hi)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n), n
