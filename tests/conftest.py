import os
import sys

# Tests run on the single real CPU device (the dry-run and multi-device tests
# spawn subprocesses that set XLA_FLAGS themselves — per the assignment the
# device-count flag must NOT be set globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# XLA's CPU backend JIT-compiles each executable with a pool of parallel
# codegen threads.  Over a long suite (hundreds of compilations) the
# concurrent JIT eh-frame registration intermittently segfaults inside
# libgcc's unwinder (observed as nondeterministic mid-suite crashes under
# jax/_src/compiler.py backend_compile, on the seed as well as on later
# revisions).  Serializing codegen removes the race; it changes compile
# parallelism only — never device topology or numerics.  The multi-device
# subprocess tests overwrite XLA_FLAGS wholesale in their own environments,
# so this does not leak a device count into them.
_CODEGEN_FLAG = "--xla_cpu_parallel_codegen_split_count=1"
if _CODEGEN_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _CODEGEN_FLAG
    ).strip()

# Make `import repro` work whether or not PYTHONPATH=src was exported.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Property tests prefer real hypothesis; offline environments fall back to the
# deterministic N-example shim so the suite still collects and runs.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro.testing.hypothesis_fallback import install as _install_hyp

    _install_hyp()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_edges(rng, n_lo=5, n_hi=40, p_lo=0.05, p_hi=0.5):
    """Canonical random undirected simple graph edges."""
    from repro.graphs.csr import edges_from_arrays
    n = int(rng.integers(n_lo, n_hi))
    p = rng.uniform(p_lo, p_hi)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n), n
