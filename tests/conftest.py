import os

# Tests run on the single real CPU device (the dry-run and multi-device tests
# spawn subprocesses that set XLA_FLAGS themselves — per the assignment this
# must NOT be set globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_edges(rng, n_lo=5, n_hi=40, p_lo=0.05, p_hi=0.5):
    """Canonical random undirected simple graph edges."""
    from repro.graphs.csr import edges_from_arrays
    n = int(rng.integers(n_lo, n_hi))
    p = rng.uniform(p_lo, p_hi)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n), n
