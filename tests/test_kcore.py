"""k-core: ParK (JAX) vs Batagelj–Zaversnik (numpy oracle)."""

import numpy as np
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graphs.csr import build_csr, edges_from_arrays
from repro.graphs.gen import rmat_edges, ring_of_cliques_edges
from repro.core.kcore import kcore_numpy, kcore_park


def test_clique_ring_coreness():
    g = build_csr(ring_of_cliques_edges(4, 5))
    core = kcore_numpy(g)
    # clique vertices have coreness k-1 = 4
    assert (core == 4).all()
    assert np.array_equal(kcore_park(g), core)


def test_rmat_park_vs_bz():
    E = rmat_edges(8, edge_factor=8, seed=3)
    g = build_csr(E)
    assert np.array_equal(kcore_park(g), kcore_numpy(g))


@st.composite
def graphs(draw):
    n = draw(st.integers(4, 30))
    density = draw(st.floats(0.05, 0.5))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < density
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


@given(graphs())
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_park_equals_bz(E):
    if E.size == 0:
        return
    g = build_csr(E)
    core = kcore_numpy(g)
    assert np.array_equal(kcore_park(g), core)
    # coreness ≤ degree, and the max k-core is non-empty
    assert (core <= g.degrees).all()
