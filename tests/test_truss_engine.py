"""Batched multi-graph engine: order alignment, bucketing, mode parity."""

import numpy as np
import pytest

from repro.graphs.csr import edges_from_arrays
from repro.graphs.gen import ring_of_cliques_edges, rmat_edges
from repro.core.pkt import truss_pkt
from repro.serve.truss_engine import (TrussEngine, TrussHandle, truss_batched,
                                      _next_pow2)


def _er_edges(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


def _expected(edges):
    """Reference: truss_pkt on the unique canonical edges, per input row."""
    e = np.asarray(edges, np.int64)
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    n = int(e.max()) + 1
    uniq = np.unique(lo * n + hi)
    E = np.stack([uniq // n, uniq % n], axis=1)
    t = truss_pkt(E)
    return t[np.searchsorted(uniq, lo * n + hi)]


def _mixed_fleet():
    return [
        _er_edges(12, 0.4, 0),
        ring_of_cliques_edges(3, 5),
        np.array([[0, 1]], np.int64),                  # tiny: one edge
        _er_edges(36, 0.2, 1),
        rmat_edges(6, edge_factor=4, seed=2),
        np.array([[0, 1], [1, 2]], np.int64),          # tiny: path
        _er_edges(20, 0.35, 3),
    ]


def test_mixed_sizes_order_aligned():
    """The core contract: results align to submission order and row order,
    regardless of how submissions are bucketed and reordered internally."""
    fleet = _mixed_fleet()
    eng = TrussEngine()
    tickets = [eng.submit(e) for e in fleet]
    # resolve deliberately out of submission order
    for i in reversed(range(len(tickets))):
        got = eng.result(tickets[i])
        assert np.array_equal(got, _expected(fleet[i])), i
    assert eng.stats["graphs_done"] == len(fleet)


def test_bucket_reuse_same_class():
    """Graphs of one pow2 size class share a bucket (one compile, one batch)."""
    a = _er_edges(16, 0.3, 10)
    b = _er_edges(16, 0.3, 11)
    eng = TrussEngine()
    ka = eng._size_class(*_prep(eng, a))
    kb = eng._size_class(*_prep(eng, b))
    if ka == kb:  # identical class: one batched dispatch for both
        outs = eng.map([a, b])
        assert eng.stats["batches"] == 1
        assert np.array_equal(outs[0], _expected(a))
        assert np.array_equal(outs[1], _expected(b))


def _prep(eng, edges):
    from repro.graphs.csr import build_csr
    from repro.core import support as support_mod
    e = np.asarray(edges, np.int64)
    g = build_csr(e, int(e.max()) + 1)
    return g, support_mod.build_support_table(g), \
        support_mod.build_peel_table(g)


@pytest.mark.parametrize("mode", ["dense", "pallas"])
def test_engine_mode_parity(mode):
    fleet = [_er_edges(14, 0.35, 20), ring_of_cliques_edges(3, 4)]
    base = truss_batched(fleet, mode="chunked")
    got = truss_batched(fleet, mode=mode)
    for b, g_ in zip(base, got):
        assert np.array_equal(b, g_)


def test_engine_support_mode_parity():
    """The batched support kernel path agrees with the batched jnp path."""
    fleet = [_er_edges(14, 0.35, 21), ring_of_cliques_edges(3, 4),
             np.array([[0, 1], [1, 2]], np.int64)]
    base = truss_batched(fleet, support_mode="jnp")
    got = truss_batched(fleet, support_mode="pallas")
    for b, g_ in zip(base, got):
        assert np.array_equal(b, g_)


def test_engine_invalid_support_mode_rejected():
    with pytest.raises(ValueError, match="support_mode"):
        TrussEngine(support_mode="warp")


def test_row_alignment_swapped_and_duplicate_rows():
    """Input rows may be endpoint-swapped or duplicated; results align by row."""
    edges = np.array([[1, 0], [0, 1], [1, 2], [2, 1], [0, 2]], np.int64)
    out = TrussEngine().map([edges])[0]
    assert out.shape == (5,)
    assert (out == 3).all()  # one triangle: every row reports trussness 3


def test_empty_and_selfloop():
    eng = TrussEngine()
    t = eng.submit(np.zeros((0, 2), np.int64))
    assert eng.result(t).shape == (0,)
    with pytest.raises(ValueError, match="self-loop"):
        eng.submit(np.array([[3, 3]], np.int64))


def test_no_reorder_path():
    fleet = [_er_edges(18, 0.3, 30)]
    got = truss_batched(fleet, reorder=False)
    assert np.array_equal(got[0], _expected(fleet[0]))


def test_auto_flush_on_max_pending():
    fleet = [_er_edges(10, 0.4, s) for s in range(4)]
    eng = TrussEngine(max_pending=2)
    for e in fleet:
        eng.submit(e)
    # two auto-flushes happened; all results already materialized
    assert eng.stats["flushes"] == 2
    assert len(eng._pending) == 0


def test_next_pow2():
    assert [_next_pow2(x) for x in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]


# ------------------------------------------------------------ failure paths --

def test_oversized_graph_rejected():
    """Submissions beyond max_edges fail fast with an actionable error, and
    the engine stays serviceable afterwards."""
    eng = TrussEngine(max_edges=8)
    with pytest.raises(ValueError, match="too large.*max_edges=8"):
        eng.submit(_er_edges(20, 0.5, 0))
    assert eng.stats["graphs_done"] == 0 and not eng._pending
    t = eng.submit(np.array([[0, 1], [0, 2], [1, 2]], np.int64))
    assert (eng.result(t) == 3).all()
    # the limit counts *canonical* edges: duplicate/swapped rows collapse
    dup = np.array([[0, 1], [1, 0]] * 6, np.int64)
    t2 = TrussEngine(max_edges=1).submit(dup)
    assert t2 >= 0
    with pytest.raises(ValueError, match="max_edges"):
        TrussEngine(max_edges=0)


def test_out_of_order_result_pickup():
    """A later ticket may be redeemed first; earlier results stay intact and
    are served from the materialized store without a second flush."""
    eng = TrussEngine()
    fleet = [_er_edges(12, 0.4, 40), ring_of_cliques_edges(3, 4),
             _er_edges(30, 0.2, 41)]
    t0, t1, t2 = [eng.submit(e) for e in fleet]
    assert np.array_equal(eng.result(t2), _expected(fleet[2]))
    flushes = eng.stats["flushes"]
    assert np.array_equal(eng.result(t0), _expected(fleet[0]))
    assert np.array_equal(eng.result(t1), _expected(fleet[1]))
    assert eng.stats["flushes"] == flushes  # no extra flush needed


def test_submit_rejects_negative_and_huge_ids():
    """submit used to accept negative ids (corrupting the lo*n+hi key
    packing) and huge ids (overflowing the int32 CSR layout)."""
    eng = TrussEngine()
    with pytest.raises(ValueError, match="negative"):
        eng.submit(np.array([[-1, 2]], np.int64))
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(np.array([[0, 2**31]], np.int64))
    t = eng.submit(np.array([[0, 1], [0, 2], [1, 2]], np.int64))
    assert (eng.result(t) == 3).all()  # engine still serviceable


# ------------------------------------------------- handle lifecycle (§9) --

def test_handle_open_update_close():
    eng = TrussEngine()
    E = ring_of_cliques_edges(3, 5)
    h = eng.open(E)
    assert isinstance(h, TrussHandle)
    assert np.array_equal(h.trussness, truss_pkt(h.edges))
    st = eng.update(h, add_edges=np.array([[0, 2]]),
                    remove_edges=np.array([[0, 1]]))
    assert st.handle is h and st.mode in ("local", "full")
    assert np.array_equal(h.trussness, truss_pkt(h.edges))
    assert eng.stats["updates"] == 1
    assert eng.stats["updates_local"] + eng.stats["updates_full"] == 1
    eng.close(h)
    assert h.closed
    with pytest.raises(ValueError, match="closed"):
        eng.update(h, add_edges=np.array([[0, 3]]))
    eng.close(h)  # idempotent


def test_handle_sequence_matches_from_scratch():
    """A churned handle stays bitwise-equal to from-scratch pkt."""
    rng = np.random.default_rng(12)
    eng = TrussEngine()
    h = eng.open(_er_edges(22, 0.3, 50), local_frac=1.0)
    for _ in range(3):
        cur = h.edges
        rm = cur[rng.choice(cur.shape[0], size=2, replace=False)]
        add = np.stack([rng.integers(0, 24, 3), rng.integers(0, 24, 3)], 1)
        add = add[add[:, 0] != add[:, 1]]
        eng.update(h, add_edges=add, remove_edges=rm)
        assert np.array_equal(h.trussness, truss_pkt(h.edges))
    assert list(h.query(h.edges[:3])) == list(h.trussness[:3])


def test_ticket_promotion_to_handle():
    """update() accepts a still-pending ticket: it is consumed and promoted
    to a persistent handle carried in the returned stats."""
    eng = TrussEngine()
    E = _er_edges(14, 0.35, 60)
    t = eng.submit(E)
    st = eng.update(t, add_edges=np.array([[0, 13]]))
    h = st.handle
    assert isinstance(h, TrussHandle)
    assert np.array_equal(h.trussness, truss_pkt(h.edges))
    with pytest.raises(KeyError):        # ticket consumed by promotion
        eng.result(t)
    # a flushed/collected ticket cannot be promoted (graph released)
    t2 = eng.submit(E)
    eng.result(t2)
    with pytest.raises(KeyError, match="cannot be promoted"):
        eng.update(t2)


def test_duplicate_ticket_redemption():
    """Results are single-read: a second redemption (or an unknown ticket)
    raises KeyError rather than silently recomputing."""
    eng = TrussEngine()
    t = eng.submit(np.array([[0, 1], [0, 2], [1, 2]], np.int64))
    assert (eng.result(t) == 3).all()
    with pytest.raises(KeyError, match="already-collected"):
        eng.result(t)
    with pytest.raises(KeyError, match="unknown"):
        eng.result(10_000)


# ------------------------------------------------ scheduler hooks + flush --


def test_flush_only_selected_bucket():
    """flush(only=[key]) dispatches that bucket and leaves others pending."""
    small, big = _er_edges(12, 0.4, 30), _er_edges(40, 0.2, 31)
    eng = TrussEngine()
    ts, tb = eng.submit(small), eng.submit(big)
    ks, kb = eng.bucket_of(ts), eng.bucket_of(tb)
    assert ks is not None and kb is not None and ks != kb
    eng.flush(only=[ks])
    assert eng.bucket_of(ts) is None          # materialized
    assert eng.bucket_of(tb) == kb            # untouched
    assert np.array_equal(eng.result(ts), _expected(small))
    assert np.array_equal(eng.result(tb), _expected(big))
    # flush(only=[unknown key]) is a no-op
    eng.submit(small)
    eng.flush(only=[kb])
    assert eng.stats["graphs_done"] == 2


def test_bucket_of_and_discard():
    """discard releases a pending ticket; its result is gone for good."""
    e = _er_edges(12, 0.4, 32)
    eng = TrussEngine()
    t = eng.submit(e)
    assert eng.bucket_of(t) is not None
    eng.discard(t)
    assert eng.bucket_of(t) is None
    with pytest.raises(KeyError):
        eng.result(t)
    eng.discard(123456)                       # unknown: ignored
    # discard also drops an already-materialized result
    t2 = eng.submit(e)
    eng.flush()
    eng.discard(t2)
    with pytest.raises(KeyError):
        eng.result(t2)


def test_flush_failure_keeps_tickets_pending(monkeypatch):
    """The flush-ordering contract: a raising dispatch loses no tickets.

    Submissions whose bucket dispatch fails stay in the pending queue and
    remain redeemable once the fault clears (regression: flush() used to
    clear the queue *before* dispatching).
    """
    import repro.serve.truss_engine as te

    e1, e2 = _er_edges(12, 0.4, 33), _er_edges(12, 0.4, 34)
    eng = TrussEngine()
    t1, t2 = eng.submit(e1), eng.submit(e2)

    def boom(*a, **k):
        raise RuntimeError("injected dispatch failure")

    monkeypatch.setattr(te, "_batched_truss", boom)
    monkeypatch.setattr(te, "_batched_truss_dev", boom)
    with pytest.raises(RuntimeError, match="injected"):
        eng.flush()
    assert eng.bucket_of(t1) is not None      # still pending, not lost
    assert eng.bucket_of(t2) is not None
    monkeypatch.undo()
    assert np.array_equal(eng.result(t1), _expected(e1))
    assert np.array_equal(eng.result(t2), _expected(e2))


def test_promotion_observes_earlier_submits_in_flush():
    """A pending promotion and a same-bucket flush agree bitwise.

    Promoting ticket B must not disturb ticket A's pending result, and the
    promoted handle's trussness equals the batched flush of the same edges.
    """
    a, b = _er_edges(14, 0.4, 35), _er_edges(14, 0.4, 36)
    eng = TrussEngine()
    ta, tb = eng.submit(a), eng.submit(b)
    st = eng.update(tb)                       # promote B while A pending
    h = st.handle
    assert np.array_equal(eng.result(ta), _expected(a))   # flush after
    # the promotion's from-scratch decomposition matches the batched path
    sep = TrussEngine()
    assert np.array_equal(h.trussness, truss_pkt(h.edges))
    assert np.array_equal(sep.map([b])[0], _expected(b))


def test_engine_update_many_matches_sequential():
    """update_many(batches) is bitwise one-at-a-time, at one repair."""
    e = _er_edges(16, 0.35, 37)
    b1 = (np.array([[0, 9], [1, 10]], np.int64), None)
    b2 = (np.array([[2, 11]], np.int64), np.array([[0, 9]], np.int64))
    b3 = (None, np.array([[1, 10]], np.int64))

    eng = TrussEngine()
    h_seq = eng.open(e)
    for add, rem in (b1, b2, b3):
        eng.update(h_seq, add_edges=add, remove_edges=rem)
    h_one = eng.open(e)
    updates_before = eng.stats["updates"]
    st = eng.update_many(h_one, [b1, b2, b3])
    assert st.coalesced == 3
    assert st.handle is h_one
    assert eng.stats["updates"] == updates_before + 1
    assert np.array_equal(h_one.edges, h_seq.edges)
    assert np.array_equal(h_one.trussness, h_seq.trussness)
    assert np.array_equal(h_one.trussness, truss_pkt(h_one.edges))
