"""Chaos hardening: fault injection, retry/ladder, heal, deadlines, watchdog.

The fault-injection matrix drives every dispatch site (engine flush,
region re-peel, support build, hierarchy flood) through raise-once /
raise-twice / raise-until-exhausted / delay-past-deadline faults and
asserts the typed-error contract, the retry counters, ladder
demotion/re-promotion, and — throughout — bitwise parity of every
completed result with the fault-free reference.
"""

import time

import numpy as np
import pytest

from repro.core.pkt import truss_pkt
from repro.core.truss_inc import IntegrityError
from repro.graphs.csr import edges_from_arrays
from repro.serve import (Cancelled, DeadlineExceeded, Ladder, Overloaded,
                         RetryPolicy, TrussEngine, TrussScheduler, Wedged)
from repro.serve.resilience import run_with_resilience
from repro.testing.chaos import (DISPATCH_SITES, FaultPlan, InjectedFault,
                                 fault_point)


def _er_edges(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


def _expected(edges):
    e = np.asarray(edges, np.int64)
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    n = int(e.max()) + 1
    uniq = np.unique(lo * n + hi)
    E = np.stack([uniq // n, uniq % n], axis=1)
    t = truss_pkt(E)
    return t[np.searchsorted(uniq, lo * n + hi)]


_FAST = RetryPolicy(max_retries=2, base_delay_s=0.001, max_delay_s=0.002)


# ------------------------------------------------------- fault-plan harness --


def test_fault_plan_times_rules_fire_exactly_n_times():
    plan = FaultPlan().add("flush", times=2)
    with plan:
        for _ in range(2):
            with pytest.raises(InjectedFault) as ei:
                fault_point("flush", rung="pallas")
            assert ei.value.site == "flush" and ei.value.rung == "pallas"
        assert fault_point("flush") is None         # rule exhausted
    st = plan.stats()
    assert st["calls"]["flush"] == 3 and st["injected"]["flush"] == 2


def test_fault_plan_rate_rules_are_seed_deterministic():
    def fire_pattern(seed):
        plan = FaultPlan.uniform(0.3, sites=("region",), seed=seed)
        hits = []
        with plan:
            for _ in range(50):
                try:
                    fault_point("region")
                    hits.append(0)
                except InjectedFault:
                    hits.append(1)
        return hits
    assert fire_pattern(7) == fire_pattern(7)
    assert fire_pattern(7) != fire_pattern(8)
    assert 0 < sum(fire_pattern(7)) < 50


def test_fault_plan_rung_filter_and_modes():
    plan = (FaultPlan()
            .add("flush", rung="pallas", times=5)
            .add("support", mode="corrupt", times=1)
            .add("region", mode="delay", delay_s=0.05, times=1))
    with plan:
        assert fault_point("flush", rung="chunked") is None  # filtered out
        with pytest.raises(InjectedFault):
            fault_point("flush", rung="pallas")
        assert fault_point("support") == "corrupt"
        t0 = time.perf_counter()
        assert fault_point("region") is None        # delay mode: sleeps
        assert time.perf_counter() - t0 >= 0.04


def test_fault_plan_validation_and_exclusive_activation():
    with pytest.raises(ValueError, match="dispatch site"):
        FaultPlan().add("nonsense")
    with pytest.raises(ValueError, match="fault mode"):
        FaultPlan().add("flush", mode="explode")
    with pytest.raises(ValueError, match="rate"):
        FaultPlan().add("flush", rate=1.5)
    with FaultPlan():
        with pytest.raises(RuntimeError, match="already active"):
            FaultPlan().__enter__()
    assert fault_point("flush") is None             # deactivated on exit


def test_fault_point_is_noop_without_a_plan():
    for site in DISPATCH_SITES:
        assert fault_point(site, rung="anything") is None


# --------------------------------------------------- resilience primitives --


def test_retry_policy_backoff_is_deterministic_and_bounded():
    pol = RetryPolicy(max_retries=3, base_delay_s=0.002, max_delay_s=0.01)
    a = [pol.backoff("flush", i) for i in (1, 2, 3)]
    assert a == [pol.backoff("flush", i) for i in (1, 2, 3)]
    assert a[0] >= 0.002 and max(a) <= 0.01
    assert pol.backoff("flush", 1) != pol.backoff("region", 1)  # decorrelated
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)


def test_ladder_demotes_probes_and_repromotes():
    lad = Ladder(("fast", "slow"), demote_after=2, probe_after=2,
                 promote_after=2)
    lad.record_failure()
    assert lad.current() == "fast"                  # one failure: no demote
    lad.record_failure()
    assert lad.current() == "slow" and lad.demotions == 1
    lad.record_success()
    assert not lad.should_probe()
    lad.record_success()
    assert lad.should_probe() and lad.probe_rung() == "fast"
    lad.record_probe_failure()                      # stays demoted
    assert lad.current() == "slow"
    lad.record_success(), lad.record_success()
    lad.record_probe_success()
    lad.record_probe_success()                      # full probe streak
    assert lad.current() == "fast" and lad.promotions == 1
    assert lad.snapshot()["probe_failures"] == 1


def test_run_with_resilience_retries_transient_only():
    lad = Ladder(("a", "b"))
    calls = []

    def flaky(rungs):
        calls.append(rungs["x"])
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"
    out = run_with_resilience(flaky, ladders={"x": lad}, primary="x",
                              policy=_FAST)
    assert out == "ok" and len(calls) == 3
    assert lad.failures == 2 and lad.demotions == 1     # demoted to "b"
    assert calls == ["a", "a", "b"]

    def buggy(rungs):
        raise ValueError("permanent")
    with pytest.raises(ValueError):                 # no retry for caller bugs
        run_with_resilience(buggy, ladders={"x": Ladder(("a",))},
                            primary="x", policy=_FAST)

    def slow(rungs):
        time.sleep(0.02)
        raise RuntimeError("transient")
    with pytest.raises(DeadlineExceeded):
        run_with_resilience(slow, ladders={"x": Ladder(("a",))}, primary="x",
                            policy=_FAST,
                            deadline=time.perf_counter() + 0.03, kind="q")


# ----------------------------------------- invariant checks + self-healing --


def test_check_invariants_detects_corruption_and_rebuild_heals():
    e = _er_edges(16, 0.4, 9)
    h = TrussEngine().open(e)
    inc = h._inc
    assert inc.check_invariants(sample=1 << 20) == inc.m    # full sweep clean
    assert inc.check_invariants(sample=8, seed=3) == 8      # sampled form
    t_good = inc.T.copy()
    inc.T[0] += 1
    with pytest.raises(IntegrityError, match="invariant violation"):
        inc.check_invariants(sample=1 << 20)
    inc.rebuild()
    assert np.array_equal(inc.T, t_good)                    # healed exactly
    inc.S[2] += 3
    with pytest.raises(IntegrityError, match="support disagrees"):
        inc.check_invariants(sample=1 << 20)
    inc.rebuild()
    assert inc.verify()


# ------------------------------------------------- fault-injection matrix --
# site × {raise-once, raise-twice}: retried to a bitwise-correct result,
# with retry counters and ladder demotions visible in stats().


@pytest.mark.parametrize("times", [1, 2])
def test_flush_faults_are_retried_to_parity(times):
    e = _er_edges(14, 0.4, 20)
    want = _expected(e)
    with FaultPlan().add("flush", times=times):
        with TrussScheduler(max_batch=4, max_delay_ms=1.0,
                            retry=_FAST) as sched:
            out = sched.submit_async(e).result(timeout=120)
            st = sched.stats()
    assert np.array_equal(out, want)
    assert st["counters"]["retries"] == times
    assert st["resilience"]["flush"]["failures"] == times
    assert st["resilience"]["flush"]["demotions"] == (1 if times >= 2 else 0)


@pytest.mark.parametrize("times", [1, 2])
def test_region_faults_are_retried_to_parity(times):
    e = _er_edges(16, 0.35, 21)
    add = np.array([[0, 9], [1, 10]], np.int64)
    full = np.concatenate([e, add])
    want = _expected(full)
    with TrussScheduler(max_batch=4, max_delay_ms=1.0, retry=_FAST) as sched:
        h = sched.open_async(e, local_frac=1.0).result(timeout=120)
        with FaultPlan().add("region", times=times):
            stats = sched.update_async(h, add_edges=add).result(timeout=120)
            st = sched.stats()
        q = sched.query_async(h, full).result(timeout=120)
    assert stats is not None
    assert np.array_equal(q, want)
    assert st["counters"]["retries"] == times
    assert st["resilience"]["region"]["failures"] == times


@pytest.mark.parametrize("times", [1, 2])
def test_support_faults_are_retried_to_parity(times):
    e = _er_edges(14, 0.4, 22)
    want = _expected(e)
    with TrussScheduler(max_batch=4, max_delay_ms=1.0, retry=_FAST) as sched:
        with FaultPlan().add("support", times=times):
            h = sched.open_async(e).result(timeout=120)
            st = sched.stats()
        # a demoted open must hand back a handle on the engine's executors
        assert h._inc.support_mode == sched.engine.support_mode
        assert h._inc.table_mode == sched.engine.table_mode
        q = sched.query_async(h, e).result(timeout=120)
    assert np.array_equal(q, want)
    assert st["counters"]["retries"] == times
    assert st["resilience"]["support"]["failures"] == times


@pytest.mark.parametrize("times", [1, 2])
def test_hierarchy_faults_are_retried_to_parity(times):
    e = _er_edges(16, 0.4, 23)
    eng = TrussEngine()
    href = eng.open(e)
    kmax = int(max(2, href.trussness.max()))
    want = href.communities(kmax)
    with TrussScheduler(max_batch=4, max_delay_ms=1.0, retry=_FAST) as sched:
        h = sched.open_async(e).result(timeout=120)
        with FaultPlan().add("hierarchy", times=times):
            got = sched.communities_async(h, kmax).result(timeout=120)
            st = sched.stats()
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert np.array_equal(g, w)
    assert st["counters"]["retries"] == times
    assert st["resilience"]["hierarchy"]["failures"] == times


def test_exhausted_retries_surface_the_typed_injected_fault():
    e = _er_edges(14, 0.4, 24)
    with FaultPlan().add("flush", times=50):
        with TrussScheduler(max_batch=4, max_delay_ms=1.0,
                            retry=_FAST) as sched:
            f = sched.submit_async(e)
            with pytest.raises(InjectedFault) as ei:
                f.result(timeout=120)
            st = sched.stats()
    assert ei.value.site == "flush"
    assert st["counters"]["errors"] == 1
    # every rung was tried on the way down
    assert st["resilience"]["flush"]["demotions"] >= 1


def test_delay_fault_past_deadline_is_a_typed_deadline_error():
    e = _er_edges(14, 0.4, 25)
    with FaultPlan().add("flush", mode="delay", delay_s=0.2, times=1):
        with TrussScheduler(max_batch=4, max_delay_ms=1.0,
                            retry=_FAST) as sched:
            f = sched.submit_async(e, deadline_ms=60.0)
            with pytest.raises(DeadlineExceeded) as ei:
                f.result(timeout=120)
            st = sched.stats()
    assert ei.value.kind == "submit"
    assert st["counters"]["deadline_exceeded"] == 1


# --------------------------------------------- ladder demotion/re-promotion --


def test_pallas_failure_degrades_to_jnp_then_repromotes():
    """Acceptance: forced pallas failures demote to the jnp rung with
    identical outputs, then recovery probes re-promote to pallas."""
    e = _er_edges(14, 0.4, 26)
    want = _expected(e)
    plan = FaultPlan().add("flush", rung="pallas", times=2)
    with plan:
        with TrussScheduler(mode="pallas", interpret=True, max_batch=1,
                            max_delay_ms=0.0, retry=_FAST,
                            ladder={"demote_after": 2, "probe_after": 1,
                                    "promote_after": 1}) as sched:
            outs = [sched.submit_async(e).result(timeout=120)
                    for _ in range(3)]
            st = sched.stats()
    for out in outs:                # demoted and pallas results identical
        assert np.array_equal(out, want)
    flush = st["resilience"]["flush"]
    assert flush["rungs"][0] == "pallas+jnp"
    assert flush["failures"] == 2           # two forced pallas failures
    assert flush["demotions"] == 1          # -> chunked+jnp
    assert flush["probes"] == 1             # recovery probe on live traffic
    assert flush["promotions"] == 1         # back on pallas
    assert flush["rung"] == "pallas+jnp"
    assert plan.stats()["injected"]["flush"] == 2


# ----------------------------------------------------- handle self-healing --


def test_corrupt_injection_heals_via_quarantine_and_rebuild():
    e = _er_edges(16, 0.35, 27)
    add = np.array([[0, 9], [1, 10]], np.int64)
    full = np.concatenate([e, add])
    want = _expected(full)
    with TrussScheduler(max_batch=4, max_delay_ms=1.0, retry=_FAST) as sched:
        h = sched.open_async(e, local_frac=1.0).result(timeout=120)
        with FaultPlan().add("region", mode="corrupt", times=1):
            stats = sched.update_async(h, add_edges=add).result(timeout=120)
        q = sched.query_async(h, full).result(timeout=120)
        st = sched.stats()
    assert stats is not None                # the update future still resolved
    assert np.array_equal(q, want)
    assert st["counters"]["heals"] == 1
    assert st["counters"]["heal_failures"] == 0
    assert st["quarantined"] == []
    assert h._inc.verify()


def test_repeated_heal_failure_quarantines_then_next_request_recovers():
    e = _er_edges(16, 0.35, 28)
    a1 = np.array([[0, 9]], np.int64)
    a2 = np.array([[1, 10]], np.int64)
    with TrussScheduler(max_batch=4, max_delay_ms=1.0, retry=_FAST) as sched:
        h = sched.open_async(e, local_frac=1.0).result(timeout=120)
        with FaultPlan().add("region", mode="corrupt", times=50):
            f = sched.update_async(h, add_edges=a1)
            with pytest.raises(IntegrityError):
                f.result(timeout=120)       # heal kept failing: typed error
            st = sched.stats()
            assert st["counters"]["heal_failures"] >= 1
            assert st["quarantined"] == [h.hid]
        # faults gone: the next request triggers another rebuild and is
        # served — quarantined handles wait for recovery, not abandonment
        stats = sched.update_async(h, add_edges=a2).result(timeout=120)
        st = sched.stats()
    assert stats is not None
    assert st["quarantined"] == []
    assert st["counters"]["heals"] >= 2
    # a1 never committed (its future failed); state is e + a2 exactly
    full = np.concatenate([e, a2])
    assert np.array_equal(h.query(full), _expected(full))
    assert h._inc.verify()


# ------------------------------------------------------------- watchdog --


def test_watchdog_fails_outstanding_futures_with_wedged():
    e = _er_edges(12, 0.4, 29)
    with FaultPlan().add("flush", mode="delay", delay_s=1.5, times=1):
        sched = TrussScheduler(max_batch=1, max_delay_ms=0.0,
                               watchdog_s=0.2, retry=_FAST)
        f = sched.submit_async(e)
        with pytest.raises(Wedged, match="wedged"):
            f.result(timeout=30)
        with pytest.raises(Wedged):         # admission fails fast after trip
            sched.submit_async(e)
        st = sched.stats()
        sched.close()
    assert st["counters"]["watchdog_trips"] == 1
    assert st["wedged"] is not None and "stack" in st["wedged"]
    assert st["depth"] == 0


# ----------------------------------------------------- typed cancellation --


def test_close_never_started_drains_or_cancels_typed():
    e = _er_edges(12, 0.4, 30)
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0)
    f = sched.submit_async(e)
    sched.close(drain=True)                 # started just to drain
    assert np.array_equal(f.result(timeout=0), _expected(e))

    sched2 = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0)
    f2 = sched2.submit_async(e)
    sched2.close(drain=False)
    assert f2.done() and not f2.cancelled()
    with pytest.raises(Cancelled) as ei:
        f2.result(timeout=0)
    assert ei.value.kind == "submit" and ei.value.position == 0


def test_close_with_inflight_repair_leaves_no_future_unresolved():
    e = _er_edges(16, 0.35, 31)
    add = np.array([[0, 9], [1, 10]], np.int64)
    sched = TrussScheduler(max_batch=4, max_delay_ms=1.0, retry=_FAST)
    h = sched.open_async(e, local_frac=1.0).result(timeout=120)
    with FaultPlan().add("region", mode="delay", delay_s=0.4, times=1):
        fu = sched.update_async(h, add_edges=add)
        time.sleep(0.1)                     # the repair is now inflight
        fq = sched.query_async(h, e[:3])    # queued behind it
        sched.close(drain=False)
    assert fu.result(timeout=120) is not None   # inflight repair completed
    assert fq.done()
    with pytest.raises(Cancelled):
        fq.result(timeout=0)
    assert sched.engine._pending == []
    assert sched.stats()["depth"] == 0


# -------------------------------------------------- admission + deadlines --


def test_overloaded_carries_retry_after_hint():
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=2.0,
                           max_queue=1)
    e = _er_edges(12, 0.4, 32)
    f = sched.submit_async(e)
    with pytest.raises(Overloaded) as ei:
        sched.submit_async(e)
    assert ei.value.retry_after_ms is not None
    assert ei.value.retry_after_ms >= 2.0   # floored at max_delay_ms
    assert "retry after" in str(ei.value)
    sched.close(drain=False)
    assert f.done()


def test_deadline_rejects_pre_dispatch_with_typed_error():
    e = _er_edges(12, 0.4, 33)
    sched = TrussScheduler(start=False, max_batch=4, max_delay_ms=1.0,
                           deadline_ms=5.0)
    h = sched.engine.open(e)
    m0 = h.m
    fs = sched.submit_async(e)              # scheduler-default deadline
    fu = sched.update_async(h, add_edges=np.array([[0, 9]], np.int64),
                            deadline_ms=5.0)
    fq = sched.query_async(h, e[:2], deadline_ms=60_000.0)
    time.sleep(0.05)                        # both 5ms budgets expire queued
    sched.start()
    for f, kind in ((fs, "submit"), (fu, "update")):
        with pytest.raises(DeadlineExceeded) as ei:
            f.result(timeout=120)
        assert ei.value.kind == kind
    assert h.m == m0                        # the expired update never ran
    assert np.array_equal(fq.result(timeout=120), _expected(e)[:2])
    st = sched.stats()
    sched.close()
    assert st["counters"]["deadline_exceeded"] == 2


def test_resilience_argument_validation():
    with pytest.raises(ValueError):
        TrussScheduler(deadline_ms=0.0, start=False)
    with pytest.raises(ValueError):
        TrussScheduler(watchdog_s=-1.0, start=False)
    with pytest.raises(ValueError):
        TrussScheduler(invariant_sample=-1, start=False)
    sched = TrussScheduler(start=False)
    with pytest.raises(ValueError):
        sched.submit_async(np.array([[0, 1]], np.int64), deadline_ms=-5.0)
    sched.close(drain=False)


def test_stats_expose_resilience_state_json_safely():
    import json

    with TrussScheduler(max_batch=2, max_delay_ms=1.0) as sched:
        sched.submit_async(_er_edges(12, 0.4, 34)).result(timeout=120)
        st = sched.stats()
    json.dumps(st)
    assert set(st["resilience"]) == set(DISPATCH_SITES)
    for site in DISPATCH_SITES:
        snap = st["resilience"][site]
        assert {"rung", "rungs", "failures", "demotions", "promotions",
                "probes", "probe_failures"} <= set(snap)
        assert snap["rung"] == snap["rungs"][0]     # healthy: top rung
    assert st["quarantined"] == [] and st["wedged"] is None
    for c in ("retries", "deadline_exceeded", "heals", "heal_failures",
              "watchdog_trips"):
        assert st["counters"][c] == 0
    assert "heal" in st["stages"]
