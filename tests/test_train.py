"""Training substrate: loss descends, grad-accum equivalence, optimizer,
checkpoint roundtrip/restart, data determinism, straggler detection."""

import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import jax.random as jr

from repro.configs import reduced_config
from repro.models.model import init_params
from repro.train.step import TrainState, train_step
from repro.optim.adamw import AdamWConfig, adamw_init, lr_at_step
from repro.data.pipeline import SyntheticTokens, BinaryTokenFile, Prefetcher
from repro.checkpoint import save_checkpoint, restore_checkpoint, \
    latest_step, CheckpointManager
from repro.runtime.fault import StragglerMonitor, run_with_retries


def _tiny_state(arch="smollm_135m", seed=0):
    cfg = dataclasses.replace(reduced_config(arch), compute_dtype="float32")
    params = init_params(cfg, jr.PRNGKey(seed))
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt=adamw_init(params))
    return cfg, state


def _batch(cfg, step, B=4, S=32):
    src = SyntheticTokens(cfg.vocab, S, B, seed=7)
    b = src.batch_at(step)
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_descends():
    cfg, state = _tiny_state()
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(lambda s, b: train_step(s, b, cfg, opt_cfg))
    losses = []
    for i in range(12):
        state, m = step(state, _batch(cfg, i))
        losses.append(float(m["ce"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.1, losses


def test_grad_accum_equivalence():
    """microbatches=2 must match microbatches=1 on the same global batch."""
    cfg, state = _tiny_state()
    opt_cfg = AdamWConfig(lr=1e-3)
    batch = _batch(cfg, 0, B=4)
    s1, m1 = train_step(state, batch, cfg, opt_cfg, microbatches=1)
    s2, m2 = train_step(state, batch, cfg, opt_cfg, microbatches=2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 5e-5, sorted(
        jax.tree.leaves(d))[-3:]


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(lr_at_step(jnp.int32(0), cfg)) < 0.15
    peak = float(lr_at_step(jnp.int32(10), cfg))
    assert peak > 0.9
    end = float(lr_at_step(jnp.int32(109), cfg))
    assert abs(end - 0.1) < 0.02


def test_checkpoint_roundtrip(tmp_path):
    cfg, state = _tiny_state()
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 5, state)
    assert latest_step(d) == 5
    like = jax.eval_shape(lambda: state)
    step, restored = restore_checkpoint(d, like)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_async(tmp_path):
    cfg, state = _tiny_state()
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2)
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.full((4,), s)})
    mgr.wait()
    assert latest_step(d) == 3
    assert not os.path.exists(os.path.join(d, "step_1"))
    _, restored = mgr.restore_latest({"x": jax.ShapeDtypeStruct((4,),
                                                                jnp.float32)})
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.full((4,), 3.0))


def test_restart_resumes_identically(tmp_path):
    """Crash → restore → replay produces the same params as no-crash run
    (checkpoint + step-keyed data = deterministic recovery)."""
    opt_cfg = AdamWConfig(lr=1e-3)
    d = str(tmp_path / "ckpt")

    cfg, state = _tiny_state(seed=1)
    step = jax.jit(lambda s, b: train_step(s, b, cfg, opt_cfg))

    # uninterrupted 6 steps
    ref = state
    for i in range(6):
        ref, _ = step(ref, _batch(cfg, i))

    # interrupted: ckpt at 3, crash at 4, restore, replay
    st = state
    for i in range(3):
        st, _ = step(st, _batch(cfg, i))
    save_checkpoint(d, 3, st)
    del st  # "crash"
    _, st = restore_checkpoint(d, jax.eval_shape(lambda: state))
    for i in range(3, 6):
        st, _ = step(st, _batch(cfg, i))

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(st)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_data_determinism_and_sharding():
    src0 = SyntheticTokens(100, 16, 8, host_index=0, n_hosts=2, seed=1)
    src1 = SyntheticTokens(100, 16, 8, host_index=1, n_hosts=2, seed=1)
    a = src0.batch_at(3)
    b = src0.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    assert not np.array_equal(a["tokens"], src1.batch_at(3)["tokens"])
    assert a["tokens"].shape == (4, 16)  # 8 global / 2 hosts
    # next-token alignment
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_binary_token_file(tmp_path):
    path = str(tmp_path / "tokens.bin")
    np.arange(10000, dtype=np.uint16).tofile(path)
    src = BinaryTokenFile(path, vocab=50000, seq_len=32, global_batch=4)
    b0 = src.batch_at(0)
    assert b0["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])
    b1 = src.batch_at(1)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetcher():
    src = SyntheticTokens(100, 8, 2, seed=2)
    pf = Prefetcher(src, start_step=0, depth=2)
    try:
        for s in range(4):
            got = pf.get(s)
            np.testing.assert_array_equal(got["tokens"],
                                          src.batch_at(s)["tokens"])
    finally:
        pf.close()


def test_straggler_monitor():
    mon = StragglerMonitor(alpha=0.5, k=3.0, warmup_steps=2)
    flags = [mon.observe(i, t) for i, t in
             enumerate([1.0, 1.1, 0.9, 1.0, 9.0, 1.0])]
    assert flags == [False, False, False, False, True, False]
    assert len(mon.flagged) == 1 and mon.flagged[0][0] == 4


def test_run_with_retries_restores():
    calls = []
    state = {"resumed_from": None}

    def step_fn(step):
        calls.append(step)
        if step == 3 and state["resumed_from"] is None:
            raise RuntimeError("simulated node failure")

    def on_retry(step, exc):
        state["resumed_from"] = step
        return 2  # restart from checkpointed step 2

    run_with_retries(step_fn, start_step=0, end_step=5, on_retry=on_retry)
    assert state["resumed_from"] == 3
    assert calls == [0, 1, 2, 3, 2, 3, 4]
