"""Per-arch smoke tests (assignment deliverable f) + model-layer unit tests."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.random as jr

from repro.configs import ARCHS, get_config, reduced_config
from repro.models.model import init_params, forward, init_cache
from repro.models import ssm
from repro.models.moe import moe_apply
from repro.train.step import loss_fn


def _batch_for(cfg, key, B=2, S=32):
    batch = {}
    if cfg.input_is_embeds:
        batch["embeds"] = jr.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jr.randint(key, (B, S), 0, cfg.vocab)
    if cfg.rope == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    batch["labels"] = jr.randint(jr.fold_in(key, 1), (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one grad step; shapes + finiteness."""
    cfg = reduced_config(arch)
    key = jr.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch_for(cfg, key)
    B, S = batch["labels"].shape
    logits, aux, _ = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, cfg, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen3_8b", "falcon_mamba_7b", "zamba2_7b",
                                  "phi35_moe_42b", "qwen2_vl_2b"])
def test_arch_decode_matches_forward(arch):
    """KV/SSM cache correctness: prefill + stepwise decode == full forward."""
    cfg = dataclasses.replace(reduced_config(arch), compute_dtype="float32",
                              capacity_factor=8.0)
    key = jr.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 12
    batch = _batch_for(cfg, key, B, S)
    batch.pop("labels")
    ref, _, _ = forward(params, cfg, batch)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    h = S // 2
    pre = {k: v[:, :h] for k, v in batch.items()}
    pl_, _, cache = forward(params, cfg, pre, cache=cache)
    errs = [np.max(np.abs(np.asarray(pl_ - ref[:, :h])))]
    for t in range(h, S):
        dec = {k: v[:, t:t + 1] for k, v in batch.items()}
        dl, _, cache = forward(params, cfg, dec, cache=cache)
        errs.append(np.max(np.abs(np.asarray(dl[:, 0] - ref[:, t]))))
    assert max(errs) < 2e-3, errs


def test_full_config_dims_match_assignment():
    """The exact assigned dims — guards against config drift."""
    want = {
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }
    for name, dims in want.items():
        cfg = get_config(name)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == dims, (name, got, dims)
    # MoE structure
    assert get_config("phi3.5-moe-42b-a6.6b").top_k == 2
    assert get_config("llama4-scout-17b-a16e").top_k == 1
    assert get_config("falcon-mamba-7b").ssm_state == 16
    assert get_config("zamba2-7b").ssm_state == 64


def test_param_counts_plausible():
    """Total params should be near the names' billions (sanity of init)."""
    approx = {"qwen3_8b": 8e9, "olmo_1b": 1.2e9, "smollm_135m": 135e6,
              "starcoder2_3b": 3e9, "falcon_mamba_7b": 7e9,
              "zamba2_7b": 7e9, "qwen2_vl_2b": 2e9}
    for arch, want in approx.items():
        n = get_config(arch).param_count()
        assert 0.55 * want < n < 1.8 * want, (arch, n, want)


def test_moe_active_params_fraction():
    cfg = get_config("phi35_moe_42b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert 38e9 < total < 46e9, total          # ~42B
    assert 5.5e9 < active < 8.5e9, active      # ~6.6B


# ------------------------------------------------------------ layer units ----

def test_mamba1_scan_vs_naive():
    key = jr.PRNGKey(0)
    B, S, DI, N = 2, 23, 8, 4
    xc = jr.normal(key, (B, S, DI))
    dt = jax.nn.softplus(jr.normal(jr.fold_in(key, 1), (B, S, DI)))
    Bm = jr.normal(jr.fold_in(key, 2), (B, S, N))
    Cm = jr.normal(jr.fold_in(key, 3), (B, S, N))
    A = -jnp.exp(jr.normal(jr.fold_in(key, 4), (DI, N)))
    h0 = jr.normal(jr.fold_in(key, 5), (B, DI, N))
    y, hf = ssm._mamba1_scan(xc, dt, Bm, Cm, A, h0, q_chunk=5)
    h = h0
    ys = []
    for t in range(S):
        h = jnp.exp(dt[:, t, :, None] * A[None]) * h \
            + (dt[:, t] * xc[:, t])[..., None] * Bm[:, t][:, None, :]
        ys.append(jnp.einsum("bdn,bn->bd", h, Cm[:, t]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h), atol=1e-4)


def test_mamba2_ssd_vs_naive():
    key = jr.PRNGKey(1)
    B, S, NH, P, N = 2, 19, 3, 4, 5
    xh = jr.normal(key, (B, S, NH, P))
    dt = jax.nn.softplus(jr.normal(jr.fold_in(key, 1), (B, S, NH)))
    A = -jnp.exp(jr.normal(jr.fold_in(key, 2), (NH,)))
    Bm = jr.normal(jr.fold_in(key, 3), (B, S, N))
    Cm = jr.normal(jr.fold_in(key, 4), (B, S, N))
    h0 = jr.normal(jr.fold_in(key, 5), (B, NH, P, N))
    y, hf = ssm._ssd_chunked(xh, dt, A, Bm, Cm, h0, q_chunk=4)
    h = h0
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A[None])
        h = decay[:, :, None, None] * h + jnp.einsum(
            "bhp,bn,bh->bhpn", xh[:, t], Bm[:, t], dt[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cm[:, t]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h), atol=1e-3)


def test_moe_invariants():
    """Router invariants: combine weights ≤ 1 per token; capacity respected
    (output is a convex-ish combination — zero for fully dropped tokens)."""
    cfg = reduced_config("phi35_moe_42b")
    key = jr.PRNGKey(3)
    from repro.models.moe import moe_init
    p = moe_init(key, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.act, False)
    x = jr.normal(key, (2, 16, cfg.d_model))
    out, aux = moe_apply(p, x, cfg, capacity_factor=1.0)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound is 1


def test_blocked_attention_chunk_invariance():
    from repro.models.attention import blocked_attention
    key = jr.PRNGKey(4)
    B, Sq, Hkv, G, Dh = 2, 16, 2, 3, 8
    q = jr.normal(key, (B, Sq, Hkv, G, Dh))
    k = jr.normal(jr.fold_in(key, 1), (B, Sq, Hkv, Dh))
    v = jr.normal(jr.fold_in(key, 2), (B, Sq, Hkv, Dh))
    outs = [blocked_attention(q, k, v, causal=True, q_offset=0, kv_chunk=c)
            for c in (4, 7, 16)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)


def test_mrope_differs_from_rope_sections():
    from repro.models.layers import apply_mrope, apply_rope
    key = jr.PRNGKey(5)
    x = jr.normal(key, (1, 8, 2, 16))
    pos3 = jnp.stack([jnp.arange(8)] * 3, axis=-1)[None].astype(jnp.int32)
    got = apply_mrope(x, pos3, 1e4)
    want = apply_rope(x, jnp.arange(8)[None], 1e4)
    # with identical t/h/w position ids, mrope degenerates to rope
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
