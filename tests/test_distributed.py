"""Multi-device behaviour via subprocesses (host-platform device count must
be set before jax initializes, so each case runs in its own interpreter)."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# 8 virtual devices on a <4-core host makes XLA's spin-waiting CPU
# collectives pathological (minutes instead of seconds); scale the virtual
# fleet to the machine while keeping it genuinely multi-device.
DEVICES = 8 if (os.cpu_count() or 1) >= 4 else 4


def run_py(code: str, devices: int = DEVICES, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # Pin the subprocess to the CPU platform: the device-count flag only
    # multiplies *host* devices, and letting jax probe for accelerators makes
    # images that bundle libtpu burn ~8 minutes per subprocess retrying GCP
    # metadata fetches before falling back to CPU.
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pkt_dist_matches_single_device():
    out = run_py("""
import numpy as np, jax
from repro.graphs.csr import build_csr, edges_from_arrays
from repro.core import truss_numpy, pkt_dist
rng = np.random.default_rng(5)
n = 50
mask = rng.random((n, n)) < 0.25
src, dst = np.nonzero(np.triu(mask, 1))
g = build_csr(edges_from_arrays(src, dst, n))
assert len(jax.devices()) >= 2
t = pkt_dist(g, chunk=64)
assert np.array_equal(t, truss_numpy(g.El))
print("OK", g.m)
""")
    assert "OK" in out


def test_pkt_dist_support_kernel_sharded():
    """support_mode="pallas": each shard lowers the support kernel over its
    own table slice (interpret mode off-TPU); result matches the oracle and
    the jnp support path bitwise."""
    out = run_py("""
import numpy as np, jax
from repro.graphs.csr import build_csr, edges_from_arrays
from repro.core import truss_numpy, pkt_dist
rng = np.random.default_rng(11)
n = 40
mask = rng.random((n, n)) < 0.25
src, dst = np.nonzero(np.triu(mask, 1))
g = build_csr(edges_from_arrays(src, dst, n))
assert len(jax.devices()) >= 2
a = pkt_dist(g, chunk=64, support_mode="jnp")
b = pkt_dist(g, chunk=64, support_mode="pallas")
assert np.array_equal(a, b)
assert np.array_equal(b, truss_numpy(g.El))
print("OK", g.m)
""")
    assert "OK" in out


def test_train_step_sharded_small_mesh():
    """Real sharded execution (2x4 mesh): two steps run and loss is finite,
    and the sharded result matches single-device execution."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp, jax.random as jr, dataclasses, functools
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.configs import reduced_config
from repro.models.model import init_params
from repro.models import sharding as shard_rules
from repro.train.step import TrainState, train_step
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.data.pipeline import SyntheticTokens

cfg = dataclasses.replace(reduced_config("smollm_135m"),
                          compute_dtype="float32", d_model=64, n_heads=4,
                          n_kv_heads=4, head_dim=16)
mesh = make_host_mesh(n_data=2)   # (data=2, model=4)
params = init_params(cfg, jr.PRNGKey(0))
state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                   opt=adamw_init(params))
opt_cfg = AdamWConfig(lr=1e-3)
src = SyntheticTokens(cfg.vocab, 32, 4, seed=3)
batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}

# single-device reference
ref, m_ref = train_step(state, batch, cfg, opt_cfg)

pspec = shard_rules.param_specs(cfg, jax.eval_shape(lambda: params),
                                mesh.axis_names)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                   is_leaf=lambda x: isinstance(x, P))
state_sh = TrainState(step=NamedSharding(mesh, P()), params=psh,
                      opt={"m": psh, "v": psh})
bsh = {k: NamedSharding(mesh, P("data")) for k in batch}
jfn = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg),
              in_shardings=(state_sh, bsh), out_shardings=(state_sh, None))
with mesh:
    st = jax.device_put(state, state_sh)
    b = jax.device_put(batch, bsh)
    st, m = jfn(st, b)
assert np.isfinite(float(m["ce"]))
assert abs(float(m["ce"]) - float(m_ref["ce"])) < 1e-3, (float(m["ce"]), float(m_ref["ce"]))
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), ref.params, st.params)))
assert err < 1e-4, err
print("OK sharded-vs-single err", err)
""")
    assert "OK" in out


def test_dryrun_cells_on_tiny_mesh():
    """The dry-run builder compiles decode + prefill + train for a reduced
    arch on an 8-device (2x4) mesh — the same code path as the 512-chip run."""
    out = run_py("""
import numpy as np, jax, dataclasses
from repro.compat import make_mesh
from repro.configs import reduced_config
import repro.configs as C
import repro.launch.dryrun as DR

# dryrun.py forces a 512-virtual-device host platform at import, so the
# (2, 4) mesh is always satisfiable here regardless of run_py's device count
mesh = make_mesh((2, 4), ("data", "model"))
# shrink the shape table so reduced configs fit fast
C.SHAPES["train_4k"] = (64, 8, "train")
C.SHAPES["prefill_32k"] = (128, 4, "prefill")
C.SHAPES["decode_32k"] = (128, 8, "decode")
for arch in ("qwen3_8b", "phi35_moe_42b", "zamba2_7b"):
    cfg = reduced_config(arch)
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        rec = DR.lower_cell(cfg, shape, mesh)
        assert rec["flops"] > 0, (arch, shape)
        print("ok", arch, shape, rec["collectives"]["total_bytes"] > 0)
print("OK")
""")
    assert "OK" in out


def test_support_dist_equals_local():
    out = run_py("""
import numpy as np, jax
from repro.graphs.csr import build_csr, edges_from_arrays
from repro.core import compute_support
from repro.core.pkt_dist import pkt_dist
from repro.core import truss_pkt
rng = np.random.default_rng(9)
n = 64
mask = rng.random((n, n)) < 0.2
src, dst = np.nonzero(np.triu(mask, 1))
E = edges_from_arrays(src, dst, n)
g = build_csr(E)
t_local = truss_pkt(E, reorder=False)
t_dist = pkt_dist(g, chunk=32)
key = g.El[:,0].astype(np.int64) * n + g.El[:,1]
kin = E[:,0] * n + E[:,1]
pos = np.searchsorted(key, kin)
assert np.array_equal(t_dist[pos], t_local)
print("OK")
""")
    assert "OK" in out


def test_checkpoint_elastic_reshard():
    """Save on a (1,1) layout, restore onto a (2,4) mesh — elastic rescale."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp, jax.random as jr, dataclasses, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.configs import reduced_config
from repro.models.model import init_params
from repro.models import sharding as shard_rules
from repro.checkpoint import save_checkpoint, restore_checkpoint

cfg = dataclasses.replace(reduced_config("qwen3_8b"), compute_dtype="float32")
params = init_params(cfg, jr.PRNGKey(0))
d = tempfile.mkdtemp()
save_checkpoint(d, 7, params)           # single-device layout

mesh = make_host_mesh(n_data=2)          # (2, 4) — a different fleet shape
pspec = shard_rules.param_specs(cfg, jax.eval_shape(lambda: params),
                                mesh.axis_names)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                   is_leaf=lambda x: isinstance(x, P))
step, restored = restore_checkpoint(d, jax.eval_shape(lambda: params),
                                    shardings=psh)
assert step == 7
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# restored leaves actually live on the new mesh
leaf = jax.tree.leaves(restored)[0]
assert len(leaf.sharding.device_set) >= 1
print("OK elastic reshard")
""")
    assert "OK" in out
