"""Live-edge compaction (DESIGN.md §10): peeling with compaction enabled —
at any threshold — must be bitwise identical to the uncompacted run, across
the full (support × peel) executor matrix, both table modes, the batched
engine, and the incremental layer's compacted region re-peel.

Runs under real ``hypothesis`` and under the deterministic fallback shim.
"""

import numpy as np
import pytest

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.pkt import PEEL_MODES, peel_live_subset, pkt, truss_pkt
from repro.core.ref import truss_numpy
from repro.core.support import SUPPORT_MODES, compute_support
from repro.graphs.csr import build_csr, edges_from_arrays
from repro.graphs.gen import (barabasi_albert_edges, ring_of_cliques_edges,
                              rmat_edges)

MATRIX = [(pm, sm) for pm in PEEL_MODES for sm in SUPPORT_MODES]

#: "aggressive" compaction: compact at every level boundary, no size floor —
#: maximally different execution schedule from the single-segment run
AGGRESSIVE = dict(compact_frac=0.99, compact_min=0)
OFF = dict(compact_frac=None)


def _er_edges(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


@st.composite
def graphs(draw):
    kind = draw(st.sampled_from(["er", "powerlaw", "cliques"]))
    seed = draw(st.integers(min_value=0, max_value=9999))
    if kind == "er":
        return _er_edges(draw(st.integers(min_value=6, max_value=24)),
                         draw(st.floats(0.15, 0.5)), seed)
    if kind == "powerlaw":
        return barabasi_albert_edges(
            draw(st.integers(min_value=8, max_value=20)),
            m_attach=draw(st.integers(min_value=2, max_value=4)), seed=seed)
    return ring_of_cliques_edges(draw(st.integers(min_value=2, max_value=4)),
                                 draw(st.integers(min_value=3, max_value=6)))


@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(graphs())
def test_compaction_parity_matrix(E):
    """All 6 executor pairs × threshold ∈ {off, aggressive}: bitwise equal
    (multi-clique graphs peel over several levels, so aggressive compaction
    really does segment the run)."""
    if E.shape[0] == 0:
        return
    g = build_csr(E)
    base = pkt(g, **OFF)
    if g.m <= 90:
        assert np.array_equal(base.trussness, truss_numpy(g.El))
    for pm, sm in MATRIX:
        for thresh in (OFF, AGGRESSIVE):
            res = pkt(g, mode=pm, support_mode=sm, **thresh)
            assert np.array_equal(res.trussness, base.trussness), (pm, sm)
            assert np.array_equal(res.support, base.support), (pm, sm)
            assert (res.levels, res.sublevels) == \
                (base.levels, base.sublevels), (pm, sm, thresh)


@pytest.mark.parametrize("table_mode", ["numpy", "device"])
def test_compaction_parity_table_modes(table_mode):
    """Compaction rebuilds tables in whichever table_mode is active; both
    rebuild paths must continue the fixed point exactly."""
    for E in (ring_of_cliques_edges(4, 6), rmat_edges(6, edge_factor=5,
                                                      seed=1)):
        g = build_csr(E)
        base = pkt(g, table_mode=table_mode, **OFF)
        res = pkt(g, table_mode=table_mode, **AGGRESSIVE)
        assert res.compactions > 0          # the axis actually engaged
        assert np.array_equal(res.trussness, base.trussness)
        assert (res.levels, res.sublevels) == (base.levels, base.sublevels)


def test_compact_min_floor_disables_small_graphs():
    g = build_csr(ring_of_cliques_edges(3, 5))
    res = pkt(g, compact_frac=0.99, compact_min=1 << 20)
    assert res.compactions == 0
    assert np.array_equal(res.trussness, pkt(g, **OFF).trussness)


def test_truss_pkt_compaction_threaded():
    E = rmat_edges(6, edge_factor=4, seed=9)
    a = truss_pkt(E, compact_frac=None)
    b = truss_pkt(E, compact_frac=0.99, compact_min=0)
    assert np.array_equal(a, b)


def test_engine_table_mode_parity():
    """Batched engine: device-built (in-jit) tables agree with the host
    operand path graph-for-graph, including tiny and triangle-free ones."""
    from repro.serve.truss_engine import truss_batched

    fleet = [_er_edges(14, 0.35, 2), ring_of_cliques_edges(3, 4),
             np.array([[0, 1]], np.int64),
             np.array([[0, 1], [1, 2], [2, 3]], np.int64),
             rmat_edges(5, edge_factor=4, seed=4)]
    base = truss_batched(fleet, table_mode="numpy")
    for sm in SUPPORT_MODES:
        got = truss_batched(fleet, table_mode="device", support_mode=sm)
        for b, g_ in zip(base, got):
            assert np.array_equal(b, g_), sm


def test_peel_live_subset_whole_graph_is_full_peel():
    """With every edge live and nothing pinned, the compacted subset peel
    IS the full decomposition."""
    g = build_csr(_er_edges(18, 0.35, 11))
    S0 = compute_support(g)
    out = peel_live_subset(g.El, np.arange(g.m), S0,
                           compact_frac=0.9, compact_min=0)
    assert np.array_equal(out + 2, pkt(g).trussness)


@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(graphs(), st.integers(min_value=0, max_value=999))
def test_truss_inc_script_with_compaction(E, seed):
    """An insert/delete script through the incremental layer — region
    re-peels forced onto the compacted jax path with aggressive compaction —
    ends bitwise-equal to from-scratch pkt."""
    if E.shape[0] < 4:
        return
    from repro.core.truss_inc import IncrementalTruss

    n = int(E.max()) + 1
    inc = IncrementalTruss(E, local_frac=1.0, host_peel_max=0,
                           compact_frac=0.99, compact_min=0)
    rng = np.random.default_rng(seed)
    for _ in range(2):
        cur = inc.edges
        rm = cur[rng.choice(cur.shape[0], size=min(2, cur.shape[0]),
                            replace=False)]
        add = np.stack([rng.integers(0, n + 2, 3),
                        rng.integers(0, n + 2, 3)], axis=1)
        add = add[add[:, 0] != add[:, 1]]
        inc.update(add_edges=add, remove_edges=rm)
        assert np.array_equal(inc.trussness, truss_pkt(inc.edges))
