"""Pallas intersect kernel: shape/dtype sweep vs the pure-jnp oracle."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.kernels.intersect import intersect_blocked
from repro.kernels.ref import intersect_ref
from repro.kernels.ops import compute_support_kernel
from repro.core.support import compute_support
from repro.graphs.csr import build_csr, edges_from_arrays


def _rows(rng, E, D, pad, universe=500, dtype=np.int32):
    out = np.full((E, D), pad, dtype)
    for i in range(E):
        k = int(rng.integers(0, D + 1))
        vals = np.unique(rng.choice(universe, size=k, replace=False)) \
            if k else np.zeros(0, dtype)
        out[i, :len(vals)] = np.sort(vals)
    return out


@pytest.mark.parametrize("E,DA,DB", [
    (1, 8, 8), (5, 8, 32), (17, 16, 16), (64, 32, 8), (33, 64, 128),
    (128, 128, 128), (3, 256, 64), (2, 256, 256),
])
@pytest.mark.parametrize("block_rows", [4, 64])
def test_kernel_shape_sweep(E, DA, DB, block_rows):
    rng = np.random.default_rng(E * 1000 + DA + DB)
    a = _rows(rng, E, DA, -1)
    b = _rows(rng, E, DB, -2)
    got = intersect_blocked(jnp.asarray(a), jnp.asarray(b),
                            block_rows=block_rows, interpret=True)
    want = intersect_ref(jnp.asarray(a), jnp.asarray(b))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_kernel_int16_ids():
    """dtype sweep: the kernel contract is dtype-generic over int types."""
    rng = np.random.default_rng(7)
    a = _rows(rng, 9, 16, -1, universe=120, dtype=np.int16)
    b = _rows(rng, 9, 16, -2, universe=120, dtype=np.int16)
    got = intersect_blocked(jnp.asarray(a), jnp.asarray(b), interpret=True)
    want = intersect_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


@given(st.integers(0, 2**31 - 1), st.integers(1, 40),
       st.sampled_from([8, 16, 32]), st.sampled_from([8, 16, 32]))
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_kernel_vs_ref(seed, E, DA, DB):
    rng = np.random.default_rng(seed)
    a = _rows(rng, E, DA, -1, universe=60)
    b = _rows(rng, E, DB, -2, universe=60)
    got = intersect_blocked(jnp.asarray(a), jnp.asarray(b), block_rows=8,
                            interpret=True)
    want = intersect_ref(jnp.asarray(a), jnp.asarray(b))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_support_kernel_end_to_end():
    rng = np.random.default_rng(11)
    n = 70
    mask = rng.random((n, n)) < 0.25
    src, dst = np.nonzero(np.triu(mask, 1))
    g = build_csr(edges_from_arrays(src, dst, n))
    np.testing.assert_array_equal(compute_support_kernel(g),
                                  compute_support(g))
    # forcing tiny classes exercises the fallback path
    np.testing.assert_array_equal(
        compute_support_kernel(g, classes=(8,)), compute_support(g))
