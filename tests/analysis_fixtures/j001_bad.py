"""Fixture: host synchronization inside traced code (J001 fires)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@jax.jit
def bad_item(x):
    return x.sum().item()  # host sync under jit


def bad_while(S):
    def cond(s):
        return jnp.any(s > 0)

    def body(s):
        host = np.asarray(s)  # host materialization in a loop body
        return s - int(host.max())  # traced-value coercion

    return lax.while_loop(cond, body, S)
