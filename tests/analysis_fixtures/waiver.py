"""Fixture: waiver comments the analyzer understands (findings waived)."""

import numpy as np


def pack(lo, hi, n):
    # trusslint: ignore[J003] synthetic ids, wrap-checked by the caller
    return lo.astype(np.int64) * n + hi


def pack_inline(lo, hi, n):
    return lo.astype(np.int64) * n + hi  # trusslint: ignore[*]
