"""Fixture: guarded attribute accessed off-lock (L001 fires)."""

import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self._queue = []

    def put(self, item):
        with self._lock:
            self._queue.append(item)  # assignment under lock → guarded

    def size(self):
        return len(self._queue)  # off-lock read of guarded state
