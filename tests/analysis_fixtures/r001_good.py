"""Fixture: broad handlers that route or re-raise the error (R001 clean)."""


class Scheduler:
    def __init__(self):
        self.errors = 0

    def dispatch(self, req):
        try:
            req.run()
        except Exception as e:
            self._finish(req, exc=e)            # routed to the future

    def readback(self, req):
        try:
            req.run()
        except Exception as e:
            req.future.set_exception(e)         # typed sink

    def guard(self, req):
        try:
            req.run()
        except Exception:
            self.errors += 1
            raise                               # re-raised for retry/heal

    def narrow(self, req):
        try:
            req.run()
        except ValueError:
            self.errors += 1     # specific type: a decision, not a leak

    def _finish(self, req, exc=None):
        req.future.set_exception(exc)
