"""Fixture: raw BlockSpec beside wedge_common (P001 fires)."""

from jax.experimental import pallas as pl

from repro.kernels import wedge_common


def specs(chunk):
    full = wedge_common.replicated_spec
    return [pl.BlockSpec((chunk,), lambda i: (i,)), full(4)]
