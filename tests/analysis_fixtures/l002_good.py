"""Fixture: dispatch strictly outside the lock (L002 quiet)."""

import threading


class Scheduler:
    def __init__(self, engine):
        self._lock = threading.Lock()
        self.engine = engine
        self._pending = []

    def tick(self):
        with self._lock:
            batch, self._pending = self._pending, []
        self.engine.flush()  # lock released before dispatch
        return batch
