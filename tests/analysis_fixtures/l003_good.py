"""Fixture: consistent lock acquisition order (L003 quiet)."""

import threading


class Pair:
    def __init__(self):
        self._lock = threading.Lock()
        self._iolock = threading.Lock()

    def forward(self):
        with self._lock:
            with self._iolock:
                pass

    def also_forward(self):
        with self._lock:
            with self._iolock:
                pass
