"""Fixture: donated name rebound by the call (J004 quiet)."""

import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def step(state, delta):
    return state + delta


def driver(state, delta):
    state = step(state, delta)  # rebinding kills the old buffer name
    return state + delta
