"""Fixture: broad except handlers that swallow the error (R001 fires)."""


class Scheduler:
    def __init__(self):
        self.errors = 0

    def dispatch(self, req):
        try:
            req.run()
        except Exception:
            self.errors += 1        # swallowed: the future never resolves

    def drain(self, reqs):
        for req in reqs:
            try:
                req.run()
            except (OSError, BaseException):
                pass                # broad tuple, still swallowed
