"""Fixture: lock-order cycle and re-entrant acquisition (L003 fires)."""

import threading


class Pair:
    def __init__(self):
        self._lock = threading.Lock()
        self._iolock = threading.Lock()

    def forward(self):
        with self._lock:
            with self._iolock:  # _lock → _iolock
                pass

    def backward(self):
        with self._iolock:
            with self._lock:  # _iolock → _lock: cycle
                pass

    def reentrant(self):
        with self._lock:
            with self._lock:  # threading.Lock is not reentrant
                pass
