"""Fixture: packing routed through the blessed helper (J003 quiet)."""

import numpy as np

from repro.graphs.csr import edge_keys


def pack(lo, hi, n):
    return edge_keys(lo, hi, n)


def edge_keys_local(lo, hi, n):
    # a function *named* edge_keys is the blessed home and may
    # implement the packing; this one is named differently and clean
    return np.stack([lo, hi], axis=1)
