"""Fixture: blocking dispatch while holding the lock (L002 fires)."""

import threading


class Scheduler:
    def __init__(self, engine):
        self._lock = threading.Lock()
        self.engine = engine
        self._pending = []

    def tick(self):
        with self._lock:
            batch, self._pending = self._pending, []
            self.engine.flush()  # device dispatch under the lock
        return batch
