"""Fixture: dynamic shape into a static jit argument (J002 fires)."""

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("size",))
def build_table(x, size):
    return jnp.zeros((size,), jnp.int32) + x[0]


@functools.partial(jax.jit, static_argnums=(1,))
def scaled(x, factor):
    return x * factor


def driver(x):
    t = build_table(x, size=x.shape[0] * 2)  # keyword static, raw shape
    return t + scaled(x, len(x))  # positional static, raw len
