"""Fixture: donated buffer read after the donating call (J004 fires)."""

import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def step(state, delta):
    return state + delta


def driver(state, delta):
    out = step(state, delta)
    return out + state  # state's buffer was donated to step()
