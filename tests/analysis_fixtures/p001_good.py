"""Fixture: BlockSpecs via the wedge_common helpers (P001 quiet)."""

from repro.kernels import wedge_common


def specs(chunk):
    return [wedge_common.chunk_spec(chunk),
            wedge_common.chunk_spec(1),
            wedge_common.replicated_spec(4)]
