"""Fixture: statics derived through pow2 bucketing (J002 quiet)."""

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wedge_common import next_pow2


@functools.partial(jax.jit, static_argnames=("size",))
def build_table(x, size):
    return jnp.zeros((size,), jnp.int32) + x[0]


@functools.partial(jax.jit, static_argnums=(1,))
def scaled(x, factor):
    return x * factor


def driver(x, m):
    t = build_table(x, size=next_pow2(x.shape[0] * 2))  # bucketed
    return t + scaled(x, m)  # plain value, not shape-derived
