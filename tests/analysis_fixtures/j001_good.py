"""Fixture: traced code without host sync (J001 quiet)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@jax.jit
def good_jit(x):
    k = int(x.shape[0])  # shapes are static under trace
    return x * k


def good_while(S):
    def cond(s):
        return jnp.any(s > 0)

    def body(s):
        return s - jnp.minimum(s, 1)

    return lax.while_loop(cond, body, S)


def host_helper(x):
    # not traced: host syncs are fine outside jit / lax bodies
    return int(np.asarray(x).sum())
