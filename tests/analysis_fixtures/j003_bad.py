"""Fixture: raw edge-key packing arithmetic (J003 fires)."""

import numpy as np


def pack(lo, hi, n):
    return lo.astype(np.int64) * n + hi  # bypasses edge_keys


def pack_commuted(lo, hi, n):
    return n * lo + hi  # same hazard, commuted multiply
