"""Fixture: call-site-local chunk clamp (P002 fires)."""


def pick(chunk, size_pad):
    sup_chunk = min(chunk, 1 << 13)  # local clamp, bypasses pow2_chunk
    return sup_chunk


def launch(fn, chunk):
    return fn(chunk=max(chunk, 16))  # clamped at the keyword
