"""Fixture: guarded state only touched under the lock (L001 quiet)."""

import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self._queue = []

    def put(self, item):
        with self._lock:
            self._queue.append(item)

    def size(self):
        with self._lock:
            return len(self._queue)

    def _drain(self):  # trusslint: holds[_lock]
        items, self._queue = self._queue, []
        return items
