"""Fixture: chunk clamping through pow2_chunk (P002 quiet)."""

from repro.kernels.wedge_common import pow2_chunk


def pick(chunk, size_pad):
    sup_chunk = pow2_chunk(size_pad, chunk)
    n_chunks = max(1, size_pad // sup_chunk)  # counts may use max()
    return sup_chunk, n_chunks


def launch(fn, chunk, size_pad):
    return fn(chunk=pow2_chunk(size_pad, chunk))
