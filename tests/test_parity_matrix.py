"""Property-based cross-mode parity: the full executor matrix must agree.

Every combination in {dense, chunked, pallas} peel × {jnp, pallas} support
must produce bitwise-identical trussness, initial support, and level/
sub-level counts — and match the brute-force oracle (``core.ref.truss_numpy``,
the definitional O(m·Δ²)-per-round peel) on graphs small enough to afford it.

Graph population: random Erdős–Rényi and power-law (Barabási–Albert) draws
via ``graphs/gen.py``, plus the adversarial shapes that historically break
table/chunk bookkeeping — stars (empty oriented support table), cliques
(maximal trussness), disconnected unions, the empty graph, and raw inputs
with self-loops / duplicate / endpoint-swapped rows (canonicalized through
``edges_from_arrays`` exactly as production entry points do).

Runs under real ``hypothesis`` when installed and under the deterministic
fallback shim (``repro/testing/hypothesis_fallback.py``) otherwise; CI
exercises both configurations.
"""

import numpy as np
import pytest

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.pkt import PEEL_MODES, pkt
from repro.core.ref import truss_numpy
from repro.core.support import SUPPORT_MODES, compute_support
from repro.graphs.csr import build_csr, edges_from_arrays
from repro.graphs.gen import (barabasi_albert_edges, erdos_renyi_edges,
                              ring_of_cliques_edges, rmat_edges)

MATRIX = [(pm, sm) for pm in PEEL_MODES for sm in SUPPORT_MODES]

#: brute-force oracle bound — small enough that every example stays cheap
ORACLE_MAX_M = 90


def _star(k):
    return np.stack([np.zeros(k, np.int64), np.arange(1, k + 1)], axis=1)


def _clique(k, base=0):
    src, dst = np.nonzero(np.triu(np.ones((k, k)), 1))
    return np.stack([src + base, dst + base], axis=1).astype(np.int64)


def _disconnected(seed):
    """Clique ⊔ star ⊔ path — three components with different trussness."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(3, 6))
    parts = [_clique(k), _star(4) + 20,
             np.array([[30, 31], [31, 32], [32, 33]], np.int64)]
    return np.concatenate(parts, axis=0)


@st.composite
def raw_graph(draw):
    """A raw (k, 2) edge array — possibly loopy, duplicated, or swapped."""
    kind = draw(st.sampled_from(
        ["er", "powerlaw", "star", "clique", "disconnected", "noisy"]))
    seed = draw(st.integers(min_value=0, max_value=9999))
    if kind == "er":
        n = draw(st.integers(min_value=4, max_value=26))
        deg = draw(st.integers(min_value=2, max_value=8))
        return erdos_renyi_edges(n, avg_degree=float(deg), seed=seed)
    if kind == "powerlaw":
        n = draw(st.integers(min_value=6, max_value=22))
        return barabasi_albert_edges(
            n, m_attach=draw(st.integers(min_value=2, max_value=4)),
            seed=seed)
    if kind == "star":
        return _star(draw(st.integers(min_value=2, max_value=14)))
    if kind == "clique":
        return _clique(draw(st.integers(min_value=3, max_value=7)))
    if kind == "disconnected":
        return _disconnected(seed)
    # noisy: self-loops, duplicate and endpoint-swapped rows included
    n = draw(st.integers(min_value=3, max_value=14))
    k = draw(st.integers(min_value=1, max_value=40))
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, n, k), rng.integers(0, n, k)],
                    axis=1).astype(np.int64)


def _assert_matrix_agrees(raw_edges, *, chunk=1 << 14):
    """Canonicalize, run all six executors, compare bitwise (+ oracle)."""
    E = edges_from_arrays(raw_edges[:, 0], raw_edges[:, 1])
    g = build_csr(E)
    base = pkt(g, mode="chunked", support_mode="jnp", chunk=chunk)
    for pm, sm in MATRIX:
        res = pkt(g, mode=pm, support_mode=sm, chunk=chunk)
        assert np.array_equal(res.trussness, base.trussness), (pm, sm)
        assert np.array_equal(res.support, base.support), (pm, sm)
        assert (res.levels, res.sublevels) == (base.levels, base.sublevels), \
            (pm, sm)
    if g.m <= ORACLE_MAX_M:
        assert np.array_equal(base.trussness, truss_numpy(g.El))
    return base


# ------------------------------------------------------------- property ----

@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(raw_graph())
def test_parity_matrix_random(edges):
    _assert_matrix_agrees(edges)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(raw_graph())
def test_support_mode_parity_random(edges):
    """The cheap half of the matrix at higher example volume: support only."""
    E = edges_from_arrays(edges[:, 0], edges[:, 1])
    g = build_csr(E)
    a = compute_support(g, mode="jnp")
    b = compute_support(g, mode="pallas")
    assert np.array_equal(a, b)
    assert a.dtype == b.dtype


# -------------------------------------------------------- named fixtures ----

NAMED = {
    "empty": np.zeros((0, 2), np.int64),
    "single_edge": np.array([[0, 1]], np.int64),
    "triangle_free_path": np.array([[0, 1], [1, 2], [2, 3]], np.int64),
    "star": _star(9),
    "clique": _clique(7),
    "disconnected": _disconnected(0),
    "ring_of_cliques": ring_of_cliques_edges(4, 5),
    "rmat": rmat_edges(5, edge_factor=4, seed=11),
    "multi_edge_with_loops": np.array(
        [[0, 1], [1, 0], [0, 1], [2, 2], [1, 2], [0, 2], [3, 3], [2, 3]],
        np.int64),
}


@pytest.mark.parametrize("name", sorted(NAMED))
def test_parity_matrix_named(name):
    raw = NAMED[name]
    if name == "empty":
        g = build_csr(raw)
        for pm, sm in MATRIX:
            res = pkt(g, mode=pm, support_mode=sm)
            assert res.trussness.shape == (0,), (pm, sm)
        return
    _assert_matrix_agrees(raw)


def test_parity_matrix_small_chunks():
    """Chunk boundaries must not affect any executor pair."""
    raw = ring_of_cliques_edges(3, 5)
    for chunk in (4, 32):
        _assert_matrix_agrees(raw, chunk=chunk)


def test_invalid_support_mode_rejected():
    g = build_csr(np.array([[0, 1]], np.int64))
    with pytest.raises(ValueError, match="support_mode"):
        pkt(g, support_mode="warp")
    with pytest.raises(ValueError, match="mode"):
        compute_support(g, mode="warp")


def test_peel_mode_alias_wins_over_mode():
    g = build_csr(_clique(5))
    a = pkt(g, mode="dense", peel_mode="chunked")
    b = pkt(g, mode="chunked")
    assert np.array_equal(a.trussness, b.trussness)
    with pytest.raises(ValueError, match="mode"):
        pkt(g, mode="chunked", peel_mode="warp")
