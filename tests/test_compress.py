"""Gradient compression: error-feedback correctness + training parity."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import jax.random as jr

from repro.optim.compress import (quantize_int8, dequantize_int8,
                                  compress_grads, init_error, wire_bytes)
from repro.configs import reduced_config
from repro.models.model import init_params
from repro.train.step import TrainState, train_step, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.data.pipeline import SyntheticTokens


def test_quantize_roundtrip_bounds():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 33)).astype(np.float32))
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    err = np.max(np.abs(np.asarray(deq - g)))
    assert err <= float(s) * 0.5 + 1e-7  # half-ulp of the int8 grid


def test_error_feedback_accumulates():
    """With EF, the *running sum* of compressed grads tracks the true sum —
    the property that keeps SGD unbiased."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros((32,), np.float32)
    comp_sum = np.zeros((32,), np.float32)
    err = None
    for t in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
        true_sum += np.asarray(g["w"])
        cg, err = compress_grads(g, err)
        comp_sum += np.asarray(cg["w"])
    # residual bounded by one quantization step, not growing with t
    resid = np.max(np.abs(true_sum - comp_sum))
    assert resid < 0.2, resid


def test_training_parity_with_compression():
    cfg = dataclasses.replace(reduced_config("smollm_135m"),
                              compute_dtype="float32")
    params = init_params(cfg, jr.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    src = SyntheticTokens(cfg.vocab, 32, 4, seed=5)

    def run(compress: bool, steps=8):
        state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                           opt=adamw_init(params))
        err = init_error(params) if compress else None
        losses = []
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
            if compress:
                g, m = jax.grad(loss_fn, has_aux=True)(state.params, cfg,
                                                       batch)
                g, err = compress_grads(g, err)
                from repro.optim.adamw import adamw_update
                p, o = adamw_update(state.params, g, state.opt, state.step,
                                    opt_cfg)
                state = TrainState(step=state.step + 1, params=p, opt=o)
            else:
                state, m = train_step(state, batch, cfg, opt_cfg)
            losses.append(float(m["ce"]))
        return losses

    base = run(False)
    comp = run(True)
    # same qualitative trajectory; int8+EF stays within a small offset
    assert abs(base[-1] - comp[-1]) < 0.15, (base, comp)
    assert comp[-1] < comp[0]


def test_wire_bytes():
    g = {"a": jnp.zeros((100, 10)), "b": jnp.zeros((50,))}
    c, u = wire_bytes(g)
    assert u == 4 * 1050 and c == 1050 + 8
