"""Incremental maintenance: parity with from-scratch PKT under arbitrary
insert/delete sequences, across repair paths and executor modes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graphs.csr import edges_from_arrays
from repro.graphs.gen import ring_of_cliques_edges
from repro.core.pkt import truss_pkt
from repro.core.support import compute_support
from repro.core.truss_inc import (INSERT_MODES, IncrementalTruss, _Incidence,
                                  _host_peel, triangle_list,
                                  triangles_through, wedge_subtable)

SETTINGS = dict(max_examples=12, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


def _er_edges(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


def _assert_state_exact(inc, ctx=None):
    """Bitwise agreement with a from-scratch decomposition of the current
    edge set, plus support- and triangle-state invariants."""
    if inc.m == 0:
        assert inc.trussness.shape == (0,)
        return
    ref = truss_pkt(inc.edges)
    assert np.array_equal(inc.trussness, ref), ctx
    S_ref = compute_support(inc.g)
    assert np.array_equal(inc.support, S_ref), ctx
    assert inc.triangles.shape[0] == int(S_ref.sum()) // 3, ctx


# ------------------------------------------------------------- hypothesis ----

@st.composite
def update_scripts(draw):
    """An initial graph plus a script of insert/delete batches."""
    n = draw(st.integers(6, 20))
    density = draw(st.floats(0.08, 0.5))
    seed = draw(st.integers(0, 2**31 - 1))
    E = _er_edges(n, density, seed)
    batches = []
    for _ in range(draw(st.integers(1, 3))):
        n_rm = draw(st.integers(0, 6))
        n_add = draw(st.integers(0, 6))
        batches.append((n_add, n_rm))
    return n, E, batches, seed


def _apply_script(inc, n, batches, seed):
    _apply_script.history = []
    rng = np.random.default_rng(seed + 1)
    for n_add, n_rm in batches:
        cur = inc.edges
        m = cur.shape[0]
        rm = cur[rng.choice(m, size=min(n_rm, m), replace=False)] \
            if m else np.zeros((0, 2), np.int64)
        add = np.stack([rng.integers(0, n + 2, n_add),
                        rng.integers(0, n + 2, n_add)], axis=1)
        add = add[add[:, 0] != add[:, 1]]
        st_ = inc.update(add_edges=add, remove_edges=rm)
        _apply_script.history.append(st_)
        assert st_.mode in ("noop", "local", "full")
        _assert_state_exact(inc, (n_add, n_rm, st_.mode))


@given(update_scripts())
@settings(**SETTINGS)
def test_property_incremental_parity(script):
    """Any insert/delete sequence ends bitwise-equal to from-scratch pkt."""
    n, E, batches, seed = script
    if E.shape[0] == 0:
        return
    inc = IncrementalTruss(E, local_frac=1.0)
    _assert_state_exact(inc, "init")
    _apply_script(inc, n, batches, seed)


@given(update_scripts())
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_full_fallback_parity(script):
    """local_frac=0 forces the full-recompute fallback on every non-noop
    update; parity must hold through that path too."""
    n, E, batches, seed = script
    if E.shape[0] == 0:
        return
    inc = IncrementalTruss(E, local_frac=0.0)
    _apply_script(inc, n, batches, seed)
    # any update that had actual repair work must have taken the full path
    # (an update with an empty repair set may legitimately stay local)
    assert all(s.affected == 0 for s in _apply_script.history
               if s.mode == "local")


@given(update_scripts())
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_jax_masked_peel_parity(script):
    """host_peel_max=0 routes every insertion region through the masked
    ``_peel_loop`` (pinned-boundary) path instead of the host mirror."""
    n, E, batches, seed = script
    if E.shape[0] == 0:
        return
    inc = IncrementalTruss(E, local_frac=1.0, host_peel_max=0)
    _apply_script(inc, n, batches, seed)


# ------------------------------------------------------------ fixed cases ----

def test_insert_increase_cascade():
    """Completing K4 raises every edge 3 -> 4 — the increase side must
    propagate beyond the inserted edge's own triangles."""
    E = np.array([[0, 1], [0, 2], [0, 3], [1, 2], [1, 3]], np.int64)
    inc = IncrementalTruss(E, local_frac=1.0)
    st_ = inc.update(add_edges=np.array([[2, 3]]))
    assert st_.mode == "local" and st_.inserted == 1
    assert (inc.trussness == 4).all()
    _assert_state_exact(inc)


def test_delete_decrease_cascade():
    """Breaking K4 drops the survivors back to 3."""
    inc = IncrementalTruss(np.array(
        [[0, 1], [0, 2], [0, 3], [1, 2], [1, 3], [2, 3]], np.int64),
        local_frac=1.0)
    st_ = inc.update(remove_edges=np.array([[2, 3]]))
    assert st_.mode == "local" and st_.deleted == 1
    assert (inc.trussness == 3).all()
    _assert_state_exact(inc)


def test_empty_transitions_and_vertex_growth():
    inc = IncrementalTruss(np.array([[0, 1], [0, 2], [1, 2]], np.int64))
    inc.update(remove_edges=inc.edges)
    assert inc.m == 0 and inc.trussness.shape == (0,)
    st_ = inc.update(add_edges=np.array([[5, 9], [9, 11], [5, 11]], np.int64))
    assert st_.inserted == 3 and inc.n == 12
    assert (inc.trussness == 3).all()
    _assert_state_exact(inc)


def test_noop_and_setwise_semantics():
    inc = IncrementalTruss(np.array([[0, 1], [0, 2], [1, 2]], np.int64))
    # inserting an existing edge / removing a missing one is a no-op
    st_ = inc.update(add_edges=np.array([[1, 0]]),
                     remove_edges=np.array([[5, 6]]))
    assert st_.mode == "noop" and st_.inserted == 0 and st_.deleted == 0
    # an edge in both batches ends up present (add wins set-wise)
    st_ = inc.update(add_edges=np.array([[1, 2], [0, 3]]),
                     remove_edges=np.array([[1, 2]]))
    assert inc.m == 4 and st_.inserted == 1 and st_.deleted == 0
    _assert_state_exact(inc)


def test_ring_of_cliques_bridge_churn():
    inc = IncrementalTruss(ring_of_cliques_edges(4, 5), local_frac=1.0)
    rng = np.random.default_rng(3)
    for _ in range(4):
        cur = inc.edges
        rm = cur[rng.choice(cur.shape[0], size=2, replace=False)]
        add = np.stack([rng.integers(0, 20, 3), rng.integers(0, 20, 3)], 1)
        add = add[add[:, 0] != add[:, 1]]
        inc.update(add_edges=add, remove_edges=rm)
        _assert_state_exact(inc)


@pytest.mark.parametrize("mode", ["chunked", "dense", "pallas"])
def test_masked_peel_executor_modes(mode):
    """The pinned-boundary jax re-peel agrees across all three peel
    executors (the pinned mask is threaded through each)."""
    inc = IncrementalTruss(ring_of_cliques_edges(3, 4), mode=mode,
                           local_frac=1.0, host_peel_max=0)
    inc.update(add_edges=np.array([[0, 2], [1, 9]]),
               remove_edges=np.array([[0, 1]]))
    _assert_state_exact(inc, mode)


def test_update_validation_matches_submit():
    inc = IncrementalTruss(np.array([[0, 1], [0, 2], [1, 2]], np.int64))
    with pytest.raises(ValueError, match="self-loop"):
        inc.update(add_edges=np.array([[3, 3]]))
    with pytest.raises(ValueError, match="negative"):
        inc.update(remove_edges=np.array([[-1, 2]]))
    with pytest.raises(ValueError, match="integer"):
        IncrementalTruss(np.array([[0.5, 1.0]]))
    with pytest.raises(ValueError, match="local_frac"):
        IncrementalTruss(np.zeros((0, 2), np.int64), local_frac=1.5)


def test_query_alignment_and_missing_edge():
    inc = IncrementalTruss(np.array([[0, 1], [0, 2], [1, 2]], np.int64))
    assert list(inc.query(np.array([[2, 0], [1, 0], [0, 1]]))) == [3, 3, 3]
    with pytest.raises(ValueError, match="not present"):
        inc.query(np.array([[0, 9]]))
    with pytest.raises(ValueError, match="not present"):
        inc.query(np.array([[1, 2], [0, 3]][::-1]))


def test_update_stats_bookkeeping():
    inc = IncrementalTruss(_er_edges(16, 0.3, 5), local_frac=1.0)
    m0 = inc.m
    st_ = inc.update(add_edges=np.array([[0, 15], [1, 14]]),
                     remove_edges=inc.edges[:2])
    assert st_.m_before == m0 and st_.m_after == inc.m
    assert st_.seconds >= 0 and st_.mode == "local"
    assert inc.stats["updates"] == 1 and inc.stats["last"] is st_


# ------------------------------------------------------- building blocks ----

def test_wedge_subtable_matches_full_table():
    from repro.graphs.csr import build_csr
    from repro.core.support import build_peel_table
    g = build_csr(_er_edges(14, 0.4, 7))
    full = build_peel_table(g)
    sub = wedge_subtable(g, np.arange(g.m))
    assert np.array_equal(sub.e1, full.e1)
    assert np.array_equal(sub.cand_slot, full.cand_slot)
    assert np.array_equal(sub.off, full.off)


def test_triangle_list_each_once():
    from repro.graphs.csr import build_csr
    g = build_csr(_er_edges(15, 0.4, 8))
    tri = triangle_list(g)
    S = compute_support(g)
    assert tri.shape[0] == int(S.sum()) // 3
    # rows sorted and unique
    assert (tri[:, 0] < tri[:, 1]).all() and (tri[:, 1] < tri[:, 2]).all()
    keys = (tri[:, 0] * g.m + tri[:, 1]) * g.m + tri[:, 2]
    assert np.unique(keys).shape[0] == tri.shape[0]
    # per-edge membership counts reproduce the support vector
    assert np.array_equal(np.bincount(tri.ravel(), minlength=g.m), S)


def test_incidence_roundtrip():
    from repro.graphs.csr import build_csr
    g = build_csr(_er_edges(12, 0.5, 9))
    tri = triangle_list(g)
    inc = _Incidence(tri, g.m)
    for e in range(g.m):
        rows = np.unique(inc.rows_of(np.array([e])))
        assert set(rows) == set(np.nonzero((tri == e).any(axis=1))[0])


def test_host_peel_matches_pkt_on_whole_graph():
    """With the whole graph as the region and no pins, the host mirror IS a
    full peel — it must reproduce pkt exactly."""
    from repro.graphs.csr import build_csr
    from repro.core.pkt import pkt
    g = build_csr(_er_edges(18, 0.35, 11))
    tri = triangle_list(g)
    S = compute_support(g)
    out = _host_peel(g.m, tri, S.astype(np.int64),
                     np.ones(g.m, bool), np.zeros(g.m, bool))
    assert np.array_equal(out + 2, pkt(g).trussness)


def test_triangles_through_subset_anchors():
    from repro.graphs.csr import build_csr
    g = build_csr(_er_edges(14, 0.45, 13))
    anchors = np.array([0, g.m // 2, g.m - 1])
    a, e2, e3 = triangles_through(g, anchors)
    tri = triangle_list(g)
    for x in anchors:
        got = {tuple(sorted((int(p), int(q))))
               for aa, p, q in zip(a, e2, e3) if aa == x}
        want = {tuple(sorted(int(y) for y in row if y != x))
                for row in tri if (row == x).any()}
        assert got == want, x


# ------------------------------------------------ batched insertions (§13) --

#: Region-size regimes × executors × table modes the batched insertion path
#: must agree across, bitwise: host-mirror regions, masked-device regions,
#: forced mid-peel compaction, all three peel executors, both wedge-table
#: builders, and the forced full-recompute fallback.
BATCH_AXES = {
    "host-region": dict(local_frac=1.0),
    "device-region": dict(local_frac=1.0, host_peel_max=0),
    "compacting": dict(local_frac=1.0, host_peel_max=0,
                       compact_frac=0.9, compact_min=1),
    "dense": dict(local_frac=1.0, host_peel_max=0, mode="dense"),
    "pallas": dict(local_frac=1.0, host_peel_max=0, mode="pallas"),
    "numpy-table": dict(local_frac=1.0, host_peel_max=0, table_mode="numpy"),
    "forced-fallback": dict(local_frac=0.0),
}


def _tri_set(inc):
    tri = inc.triangles
    return np.unique(tri, axis=0) if tri.size else tri


def _paired_script(seq, bat, n, batches, seed):
    """Drive identical scripts through the sequential oracle and the batched
    instance, asserting bitwise agreement (trussness, support, triangle
    set) plus from-scratch parity after every batch."""
    rng = np.random.default_rng(seed + 1)
    for n_add, n_rm in batches:
        cur = seq.edges
        m = cur.shape[0]
        rm = cur[rng.choice(m, size=min(n_rm, m), replace=False)] \
            if m else np.zeros((0, 2), np.int64)
        add = np.stack([rng.integers(0, n + 2, n_add),
                        rng.integers(0, n + 2, n_add)], axis=1)
        add = add[add[:, 0] != add[:, 1]]
        s1 = seq.update(add_edges=add, remove_edges=rm)
        s2 = bat.update(add_edges=add, remove_edges=rm)
        if s1.inserted and s1.mode != "noop":
            assert s1.insert_mode == "sequential"
            assert s2.insert_mode == "batched"
        assert np.array_equal(bat.edges, seq.edges)
        assert np.array_equal(bat.trussness, seq.trussness), (n_add, n_rm)
        assert np.array_equal(bat.support, seq.support), (n_add, n_rm)
        assert np.array_equal(_tri_set(bat), _tri_set(seq)), (n_add, n_rm)
        _assert_state_exact(bat, (n_add, n_rm, s2.mode))


@given(script=update_scripts(), axis=st.sampled_from(sorted(BATCH_AXES)))
@settings(max_examples=21, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_batched_matches_sequential_and_scratch(script, axis):
    """The §13 parity harness: batched ≡ sequential ≡ from-scratch pkt,
    bitwise, across the executor × table × region-regime matrix (the axis
    is drawn per example; every axis also runs deterministically in
    ``test_batched_axes_fixed_script``)."""
    n, E, batches, seed = script
    if E.shape[0] == 0:
        return
    kw = BATCH_AXES[axis]
    seq = IncrementalTruss(E, insert_mode="sequential", **kw)
    bat = IncrementalTruss(E, insert_mode="batched", **kw)
    _paired_script(seq, bat, n, batches, seed)


@pytest.mark.parametrize("axis", sorted(BATCH_AXES))
def test_batched_axes_fixed_script(axis):
    """Deterministic coverage of every matrix axis with a fixed script —
    guaranteed to run (and force region merges: multi-insert batches into
    a clique ring) whichever property backend is active."""
    kw = BATCH_AXES[axis]
    E = ring_of_cliques_edges(4, 5)
    seq = IncrementalTruss(E, insert_mode="sequential", **kw)
    bat = IncrementalTruss(E, insert_mode="batched", **kw)
    _paired_script(seq, bat, 20, [(4, 2), (3, 3), (5, 0)], seed=17)


@given(update_scripts())
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_update_many_batched_parity(script):
    """Interleaved insert/delete batches composed through ``update_many``
    under ``insert_mode="batched"`` end bitwise-equal to applying them one
    at a time sequentially, and to from-scratch pkt."""
    n, E, batches, seed = script
    if E.shape[0] == 0:
        return
    rng = np.random.default_rng(seed + 1)
    seq = IncrementalTruss(E, insert_mode="sequential", local_frac=1.0)
    bat = IncrementalTruss(E, insert_mode="batched", local_frac=1.0)
    blist = []
    for n_add, n_rm in batches:
        cur = seq.edges          # draw against the sequentially-applied state
        m = cur.shape[0]
        rm = cur[rng.choice(m, size=min(n_rm, m), replace=False)] \
            if m else np.zeros((0, 2), np.int64)
        add = np.stack([rng.integers(0, n + 2, n_add),
                        rng.integers(0, n + 2, n_add)], axis=1)
        add = add[add[:, 0] != add[:, 1]]
        seq.update(add_edges=add, remove_edges=rm)
        blist.append((add, rm))
    st_ = bat.update_many(blist)
    assert st_.coalesced == len(blist)
    assert np.array_equal(bat.edges, seq.edges)
    assert np.array_equal(bat.trussness, seq.trussness)
    _assert_state_exact(bat)


def test_insert_mode_validation_and_override():
    E = np.array([[0, 1], [0, 2], [1, 2]], np.int64)
    with pytest.raises(ValueError, match="insert_mode"):
        IncrementalTruss(E, insert_mode="bogus")
    inc = IncrementalTruss(E)
    assert inc.insert_mode == "batched"      # the default path
    assert set(INSERT_MODES) == {"sequential", "batched"}
    with pytest.raises(ValueError, match="insert_mode"):
        inc.update(add_edges=np.array([[0, 3]]), insert_mode="bogus")
    st_ = inc.update(add_edges=np.array([[0, 3], [1, 3], [2, 3]]),
                     insert_mode="sequential")
    assert st_.insert_mode == "sequential"
    st_ = inc.update(remove_edges=np.array([[0, 3]]))
    assert st_.insert_mode is None           # no insertions in the batch
    _assert_state_exact(inc)


def test_batched_single_region_dispatch(monkeypatch):
    """A multi-insert batch with overlapping candidate regions re-peels
    exactly once — the per-edge regions merge into one dispatch (§13) —
    while the sequential oracle re-peels once per inserted edge."""
    calls = {"n": 0}
    orig = IncrementalTruss._region_peel

    def counting(self, *a, **k):
        calls["n"] += 1
        return orig(self, *a, **k)

    monkeypatch.setattr(IncrementalTruss, "_region_peel", counting)
    # three K5s, each missing one edge; the batch completes all three
    rows, missing = [], []
    for c in range(3):
        vs = range(5 * c, 5 * c + 5)
        allp = [(i, j) for i in vs for j in vs if i < j]
        missing.append(allp.pop(c))
        rows += allp
    E = np.array(rows, np.int64)
    add = np.array(missing, np.int64)

    bat = IncrementalTruss(E, insert_mode="batched", local_frac=1.0)
    calls["n"] = 0
    st_ = bat.update(add_edges=add)
    assert st_.inserted == 3 and st_.insert_mode == "batched"
    assert st_.mode == "local" and calls["n"] == 1
    seq = IncrementalTruss(E, insert_mode="sequential", local_frac=1.0)
    calls["n"] = 0
    st_ = seq.update(add_edges=add)
    assert st_.mode == "local" and calls["n"] == 3
    assert np.array_equal(bat.trussness, seq.trussness)
    assert (bat.trussness == 5).all()        # every K5 completed
    _assert_state_exact(bat)


def test_batched_overlapping_cascades():
    """Two inserted edges completing two overlapping near-cliques: the
    shared middle edges sit in both candidate regions and the merged
    re-peel must settle the joint cascade exactly."""
    allp = [(i, j) for i in range(5) for j in range(i + 1, 5)]
    E = np.array([e for e in allp if e not in [(0, 1), (3, 4)]], np.int64)
    for imode in INSERT_MODES:
        inc = IncrementalTruss(E, insert_mode=imode, local_frac=1.0)
        inc.update(add_edges=np.array([[0, 1], [3, 4]], np.int64))
        assert (inc.trussness == 5).all(), imode
        _assert_state_exact(inc, imode)


def test_batched_insert_and_delete_one_batch():
    """Inserts and deletes in one batch under batched mode: the deletion
    descent runs first, then one merged-region insertion repair, ending
    bitwise-equal to scratch."""
    E = ring_of_cliques_edges(4, 5)
    seq = IncrementalTruss(E, insert_mode="sequential", local_frac=1.0)
    bat = IncrementalTruss(E, insert_mode="batched", local_frac=1.0)
    add = np.array([[0, 7], [1, 11], [2, 16]], np.int64)
    rem = E[:3]
    s1 = seq.update(add_edges=add, remove_edges=rem)
    s2 = bat.update(add_edges=add, remove_edges=rem)
    assert s1.mode == s2.mode == "local"
    assert s2.insert_mode == "batched" and s2.deleted == 3
    assert np.array_equal(bat.trussness, seq.trussness)
    assert np.array_equal(bat.support, seq.support)
    _assert_state_exact(bat)


def test_batched_spans_compaction_boundary():
    """A batch whose merged region runs the compacted device subset peel
    with compaction forced on every sub-level (compact_min=1) — the region
    re-peel crosses compaction boundaries mid-batch."""
    E = _er_edges(26, 0.35, 21)
    kw = dict(local_frac=1.0, host_peel_max=0, compact_frac=0.99,
              compact_min=1)
    add = np.array([[0, 25], [1, 24], [2, 23], [3, 22]], np.int64)
    seq = IncrementalTruss(E, insert_mode="sequential", **kw)
    bat = IncrementalTruss(E, insert_mode="batched", **kw)
    seq.update(add_edges=add)
    s2 = bat.update(add_edges=add)
    # the merged region must repair locally through the compacting subset
    # peel (the oracle may legitimately fall back on cumulative work —
    # its result is exact either way)
    assert s2.mode == "local" and s2.insert_mode == "batched"
    assert np.array_equal(bat.trussness, seq.trussness)
    _assert_state_exact(bat)


def test_batched_touches_kmax_edges():
    """A batch inserted inside the maximum-k clique — touching k_max edges
    and raising k_max itself — repairs exactly in one merged region."""
    allp = [(i, j) for i in range(6) for j in range(i + 1, 6)]
    E = np.array(allp + [(0, 6), (0, 7), (6, 7)], np.int64)
    bat = IncrementalTruss(E, insert_mode="batched", local_frac=1.0)
    assert int(bat.trussness.max()) == 6
    st_ = bat.update(add_edges=np.array([[6, k] for k in range(1, 6)],
                                        np.int64))
    assert st_.mode == "local" and st_.insert_mode == "batched"
    assert int(bat.trussness.max()) == 7     # vertex 6 completed K7
    _assert_state_exact(bat)


class _Boom(RuntimeError):
    pass


@pytest.mark.parametrize("imode,fail_at", [("batched", 1), ("sequential", 2)])
def test_fault_injection_no_half_applied_batch(monkeypatch, imode, fail_at):
    """A region peel raising mid-batch leaves the handle bitwise untouched —
    including the deletion phase of the same update (no half-applied
    batch) — and the handle stays serviceable afterwards (§13)."""
    E = ring_of_cliques_edges(4, 5)
    inc = IncrementalTruss(E, insert_mode=imode, local_frac=1.0)
    snap = (inc.edges, inc.trussness, inc.support, _tri_set(inc),
            dict(inc.stats))
    orig = IncrementalTruss._region_peel
    calls = {"n": 0}

    def flaky(self, *a, **k):
        calls["n"] += 1
        if calls["n"] == fail_at:
            raise _Boom("injected mid-batch")
        return orig(self, *a, **k)

    monkeypatch.setattr(IncrementalTruss, "_region_peel", flaky)
    add = np.array([[0, 7], [1, 11], [2, 16]], np.int64)
    rem = E[:2]
    with pytest.raises(_Boom):
        inc.update(add_edges=add, remove_edges=rem)
    assert calls["n"] == fail_at             # it really failed mid-batch
    assert np.array_equal(inc.edges, snap[0])
    assert np.array_equal(inc.trussness, snap[1])
    assert np.array_equal(inc.support, snap[2])
    assert np.array_equal(_tri_set(inc), snap[3])
    assert inc.stats["updates"] == snap[4]["updates"]
    monkeypatch.setattr(IncrementalTruss, "_region_peel", orig)
    st_ = inc.update(add_edges=add, remove_edges=rem)
    assert st_.mode == "local"               # same batch now lands cleanly
    _assert_state_exact(inc)


# ------------------------------------------------------- batch composition --


def test_compose_update_batches_set_algebra():
    """Composition follows A <- (A \\ r) | a, R <- R | r: add-wins, sorted."""
    from repro.core.truss_inc import compose_update_batches

    b1 = (np.array([[0, 1], [2, 3]], np.int64), None)
    b2 = (np.array([[4, 5]], np.int64), np.array([[0, 1]], np.int64))
    b3 = (np.array([[0, 1]], np.int64), np.array([[8, 9]], np.int64))
    add, rem = compose_update_batches([b1, b2, b3])
    # [0,1] was added, removed, re-added -> survives in add; [8,9] was
    # never added so it only accumulates in remove
    assert add.tolist() == [[0, 1], [2, 3], [4, 5]]
    assert rem.tolist() == [[0, 1], [8, 9]]
    assert add.dtype == np.int64 and rem.dtype == np.int64


def test_compose_update_batches_matches_sequential():
    """One composed update == the same batches applied one at a time."""
    from repro.core.truss_inc import compose_update_batches

    e = _er_edges(16, 0.35, 40)
    batches = [
        (np.array([[0, 9], [1, 10]], np.int64), None),
        (None, np.array([[0, 9]], np.int64)),
        (np.array([[2, 11]], np.int64), np.array([[1, 10]], np.int64)),
    ]
    seq = IncrementalTruss(e)
    for add, rem in batches:
        seq.update(add_edges=add, remove_edges=rem)
    one = IncrementalTruss(e)
    st = one.update_many(batches)
    assert st.coalesced == 3
    assert np.array_equal(one.edges, seq.edges)
    assert np.array_equal(one.trussness, seq.trussness)
    assert np.array_equal(one.trussness, truss_pkt(one.edges))
    # degenerate cases
    add, rem = compose_update_batches([])
    assert add.shape == (0, 2) and rem.shape == (0, 2)
    with pytest.raises(ValueError):
        compose_update_batches([(np.array([[1, 1]], np.int64), None)])
