"""Correctness of the paper's core: PKT and every baseline vs the oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graphs.csr import build_csr, edges_from_arrays, relabel, \
    degeneracy_order
from repro.graphs.datasets import (paper_fig1_edges, k4_edges, triangle_edges,
                                   path_edges, karate_like_edges)
from repro.graphs.gen import rmat_edges, ring_of_cliques_edges
from repro.core import (pkt, truss_pkt, truss_wc, truss_ros, truss_numpy,
                        truss_trilist, compute_support, compute_support_ros,
                        triangle_count)
from repro.kernels.ops import compute_support_kernel

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


def _er_edges(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


# ---------------------------------------------------------------- fixed ----

def test_paper_fig1():
    """The paper's Figure 1 example: two trussness-2 edges, rest 3."""
    g = build_csr(paper_fig1_edges())
    t = pkt(g).trussness
    assert sorted(t) == [2, 2] + [3] * 10


@pytest.mark.parametrize("edges_fn,expected", [
    (triangle_edges, [3, 3, 3]),
    (k4_edges, [4] * 6),
    (path_edges, [2] * 4),
])
def test_small_known(edges_fn, expected):
    g = build_csr(edges_fn())
    assert list(pkt(g).trussness) == expected


def test_ring_of_cliques():
    """Intra-clique edges have trussness = clique size; bridges 2."""
    k = 6
    g = build_csr(ring_of_cliques_edges(5, k))
    t = pkt(g).trussness
    n_bridge = 5
    assert (t == 2).sum() == n_bridge
    assert (t == k).sum() == g.m - n_bridge


# ----------------------------------------------------------- vs oracles ----

@pytest.mark.parametrize("seed", range(6))
def test_pkt_matches_oracle_er(seed):
    E = _er_edges(10 + 7 * seed, 0.1 + 0.06 * seed, seed)
    if E.size == 0:
        return
    g = build_csr(E)
    ref = truss_numpy(g.El)
    assert np.array_equal(pkt(g).trussness, ref)
    assert np.array_equal(truss_wc(g), ref)
    assert np.array_equal(truss_ros(g), ref)
    assert np.array_equal(truss_trilist(g), ref)


def test_pkt_dense_mode_and_chunks():
    E = _er_edges(40, 0.3, 3)
    g = build_csr(E)
    ref = truss_numpy(g.El)
    for mode in ("chunked", "dense"):
        for chunk in (16, 128, 1 << 14):
            assert np.array_equal(pkt(g, mode=mode, chunk=chunk).trussness,
                                  ref), (mode, chunk)


def test_reorder_invariance():
    """Trussness is label-invariant; KCO reorder must not change results."""
    E = _er_edges(50, 0.2, 4)
    t_nat = truss_pkt(E, reorder=False)
    t_kco = truss_pkt(E, reorder=True)
    assert np.array_equal(t_nat, t_kco)


def test_karate_like_all_algorithms():
    g = build_csr(karate_like_edges())
    ref = truss_numpy(g.El)
    assert np.array_equal(pkt(g).trussness, ref)
    assert np.array_equal(truss_trilist(g), ref)


def test_rmat_medium_consistency():
    """PKT == triangle-list on a skewed RMAT graph (oracle too slow here)."""
    E = rmat_edges(9, edge_factor=6, seed=1)
    perm = degeneracy_order(E, int(E.max()) + 1)
    g = build_csr(relabel(E, perm))
    t1 = pkt(g).trussness
    t2 = truss_trilist(g)
    assert np.array_equal(t1, t2)


# ------------------------------------------- input-validation bugfix sweep ----

def test_truss_pkt_swapped_and_duplicate_rows_align():
    """truss_pkt used to silently return wrong trussness for
    endpoint-swapped or duplicate rows; now rows are canonicalized like
    TrussEngine.submit and results align to the caller's rows."""
    canon = np.array([[0, 1], [0, 2], [1, 2], [2, 3]], np.int64)
    messy = np.array([[1, 0], [0, 1], [2, 1], [2, 0], [3, 2]], np.int64)
    t_canon = truss_pkt(canon)
    t_messy = truss_pkt(messy)
    assert list(t_messy) == [t_canon[0], t_canon[0], t_canon[2],
                             t_canon[1], t_canon[3]]


def test_truss_pkt_rejects_malformed_input():
    with pytest.raises(ValueError, match="self-loop"):
        truss_pkt(np.array([[1, 1]], np.int64))
    with pytest.raises(ValueError, match="negative"):
        truss_pkt(np.array([[-1, 2]], np.int64))
    with pytest.raises(ValueError, match=r"\(k, 2\)"):
        truss_pkt(np.array([[0, 1, 2]], np.int64))
    with pytest.raises(ValueError, match="integer"):
        truss_pkt(np.array([[0.5, 1.0]]))
    # int64 key-packing / int32 CSR overflow guard on huge vertex ids
    with pytest.raises(ValueError, match="exceeds"):
        truss_pkt(np.array([[0, 2**31]], np.int64))


def test_align_to_input_missing_edge_raises():
    """align_to_input used to misalign silently (searchsorted insertion
    point) or IndexError (pos == len) for edges absent from g.El."""
    from repro.core.pkt import align_to_input, pkt
    E = np.array([[0, 1], [0, 2], [1, 2]], np.int64)
    g = build_csr(E)
    t = pkt(g).trussness
    # absent edge whose key falls between present keys
    with pytest.raises(ValueError, match=r"not present.*\(1, 3\)"):
        align_to_input(t, g, np.array([[1, 3]], np.int64), 4)
    # absent edge whose key sorts past the end (old IndexError path)
    with pytest.raises(ValueError, match="not present"):
        align_to_input(t, g, np.array([[3, 4]], np.int64), 5)
    # empty graph
    g0 = build_csr(np.zeros((0, 2), np.int64))
    with pytest.raises(ValueError, match="empty graph"):
        align_to_input(np.zeros(0), g0, np.array([[0, 1]], np.int64), 2)


def test_edge_key_packing_guard():
    from repro.graphs.csr import MAX_PACK_N, edge_keys
    lo = np.array([0], np.int64)
    hi = np.array([1], np.int64)
    assert edge_keys(lo, hi, 10)[0] == 1
    with pytest.raises(ValueError, match="overflows"):
        edge_keys(lo, hi, MAX_PACK_N + 1)


# -------------------------------------------------------------- support ----

@pytest.mark.parametrize("seed", range(4))
def test_support_equals_naive(seed):
    from repro.core.ref import support_naive
    E = _er_edges(12 + 9 * seed, 0.25, 10 + seed)
    if E.size == 0:
        return
    g = build_csr(E)
    S = compute_support(g)
    S_ros = compute_support_ros(g)
    S_naive = support_naive(g.El, np.ones(g.m, bool))
    assert np.array_equal(S, S_naive)
    assert np.array_equal(S_ros, S_naive)
    assert np.array_equal(compute_support_kernel(g), S_naive)


def test_triangle_count_invariants():
    E = _er_edges(60, 0.2, 42)
    g = build_csr(E)
    S = compute_support(g)
    assert int(S.sum()) % 3 == 0
    assert triangle_count(g) == int(S.sum()) // 3


# ------------------------------------------------------------ hypothesis ----

@st.composite
def graphs(draw):
    n = draw(st.integers(4, 28))
    density = draw(st.floats(0.05, 0.6))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < density
    src, dst = np.nonzero(np.triu(mask, 1))
    return edges_from_arrays(src, dst, n)


@given(graphs())
@settings(**SETTINGS)
def test_property_pkt_equals_oracle(E):
    if E.size == 0:
        return
    g = build_csr(E)
    ref = truss_numpy(g.El)
    assert np.array_equal(pkt(g, chunk=64).trussness, ref)


@given(graphs())
@settings(**SETTINGS)
def test_property_trussness_invariants(E):
    """System invariants: trussness ≥ 2; trussness ≤ support+2;
    trussness(e) ≤ min coreness of endpoints + 1 (Cohen)."""
    if E.size == 0:
        return
    from repro.core.kcore import kcore_numpy
    g = build_csr(E)
    res = pkt(g)
    t = res.trussness
    assert (t >= 2).all()
    assert (t <= res.support + 2).all()
    core = kcore_numpy(g)
    cap = np.minimum(core[g.El[:, 0]], core[g.El[:, 1]]) + 1
    assert (t <= cap).all()


@given(graphs())
@settings(**SETTINGS)
def test_property_wc_equals_pkt(E):
    if E.size == 0:
        return
    g = build_csr(E)
    assert np.array_equal(truss_wc(g), pkt(g).trussness)
