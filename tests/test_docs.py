"""Docs are load-bearing: README examples execute, DESIGN.md §s resolve."""

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _readme_blocks():
    text = (ROOT / "README.md").read_text()
    return re.findall(r"```python\n(.*?)```", text, re.S)


def test_readme_has_python_examples():
    """The README keeps runnable examples for every serving entry point."""
    blocks = _readme_blocks()
    assert len(blocks) >= 4
    joined = "\n".join(blocks)
    for api in ("truss_pkt", "TrussScheduler", "TrussEngine",
                "update_async", "communities"):
        assert api in joined, f"README examples no longer cover {api}"


@pytest.mark.parametrize("idx", range(len(_readme_blocks())))
def test_readme_python_block_executes(idx):
    """Every fenced python block in the README runs as written."""
    block = _readme_blocks()[idx]
    exec(compile(block, f"<README.md block {idx}>", "exec"),
         {"__name__": f"readme_block_{idx}"})


def test_design_sections_referenced_from_code_exist():
    """Every `§N` cited in source/benchmarks/README is a DESIGN.md heading."""
    design = (ROOT / "DESIGN.md").read_text()
    headings = {int(m) for m in re.findall(r"^## §(\d+)", design, re.M)}
    assert headings, "DESIGN.md has no §N headings?"
    cited = set()
    files = [ROOT / "README.md"]
    for sub in ("src", "benchmarks", "tests"):
        files += sorted((ROOT / sub).rglob("*.py"))
    for f in files:
        for m in re.findall(r"§(\d+)", f.read_text(errors="ignore")):
            cited.add((int(m), str(f.relative_to(ROOT))))
    assert cited, "no §N citations found — the convention died silently"
    missing = {(n, f) for n, f in cited if n not in headings}
    assert not missing, f"dangling DESIGN.md references: {sorted(missing)}"


def test_readme_links_every_bench_snapshot():
    """Each committed BENCH_*.json is linked from the README bench table."""
    readme = (ROOT / "README.md").read_text()
    for snap in sorted(ROOT.glob("BENCH_*.json")):
        assert f"({snap.name})" in readme, f"README does not link {snap.name}"
