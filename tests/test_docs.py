"""Docs are load-bearing: examples execute, §s resolve, benches stay fresh."""

import pathlib
import re
import subprocess

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: markdown files whose fenced python blocks must execute as written
EXECUTABLE_DOCS = ("README.md", "docs/PERFORMANCE.md")


def _doc_blocks(rel: str):
    text = (ROOT / rel).read_text()
    return re.findall(r"```python\n(.*?)```", text, re.S)


def _all_blocks():
    return [(rel, idx, block)
            for rel in EXECUTABLE_DOCS
            for idx, block in enumerate(_doc_blocks(rel))]


def test_readme_has_python_examples():
    """The README keeps runnable examples for every serving entry point."""
    blocks = _doc_blocks("README.md")
    assert len(blocks) >= 4
    joined = "\n".join(blocks)
    for api in ("truss_pkt", "TrussScheduler", "TrussEngine",
                "update_async", "communities"):
        assert api in joined, f"README examples no longer cover {api}"


def test_performance_doc_covers_the_knobs():
    """The handbook keeps runnable examples for the §16 tuning surface."""
    joined = "\n".join(_doc_blocks("docs/PERFORMANCE.md"))
    for api in ("phase_timings", "auto_chunk", "tuned_env"):
        assert api in joined, f"PERFORMANCE.md examples no longer cover {api}"


@pytest.mark.parametrize(("rel", "idx", "block"),
                         [pytest.param(r, i, b, id=f"{r}:{i}")
                          for r, i, b in _all_blocks()])
def test_doc_python_block_executes(rel, idx, block):
    """Every fenced python block in the executable docs runs as written."""
    exec(compile(block, f"<{rel} block {idx}>", "exec"),
         {"__name__": f"doc_block_{idx}"})


def test_design_sections_referenced_from_code_exist():
    """Every `§N` cited in source/benchmarks/docs is a DESIGN.md heading."""
    design = (ROOT / "DESIGN.md").read_text()
    headings = {int(m) for m in re.findall(r"^## §(\d+)", design, re.M)}
    assert headings, "DESIGN.md has no §N headings?"
    cited = set()
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    for sub in ("src", "benchmarks", "tests"):
        files += sorted((ROOT / sub).rglob("*.py"))
    for f in files:
        for m in re.findall(r"§(\d+)", f.read_text(errors="ignore")):
            cited.add((int(m), str(f.relative_to(ROOT))))
    assert cited, "no §N citations found — the convention died silently"
    missing = {(n, f) for n, f in cited if n not in headings}
    assert not missing, f"dangling DESIGN.md references: {sorted(missing)}"


def test_performance_doc_cross_references_resolve():
    """Repo paths and artifacts named in the handbook actually exist."""
    text = (ROOT / "docs/PERFORMANCE.md").read_text()
    # markdown links are relative to docs/
    for target in re.findall(r"\]\(([^)#]+)\)", text):
        if "://" in target:
            continue
        assert (ROOT / "docs" / target).resolve().exists(), (
            f"PERFORMANCE.md links missing file {target}")
    # inline-code repo paths (modules, benches, artifacts) resolve from root
    for path in re.findall(r"`([\w./-]+\.(?:py|json|md))`", text):
        assert (ROOT / path).exists(), (
            f"PERFORMANCE.md names missing path {path}")


def test_readme_links_every_bench_snapshot():
    """Each committed BENCH_*.json is linked from the README bench table."""
    readme = (ROOT / "README.md").read_text()
    for snap in sorted(ROOT.glob("BENCH_*.json")):
        assert f"({snap.name})" in readme, f"README does not link {snap.name}"


#: bench snapshot -> the code whose changes should invalidate it (the
#: producing bench module; core modules churn too often to pin here)
_BENCH_PRODUCERS = {
    "BENCH_smoke.json": "benchmarks/run.py",
    "BENCH_inc.json": "benchmarks/inc_bench.py",
    "BENCH_compact.json": "benchmarks/compact_bench.py",
    "BENCH_hier.json": "benchmarks/hier_bench.py",
    "BENCH_serve.json": "benchmarks/serve_bench.py",
    "BENCH_retrace.json": "benchmarks/retrace_bench.py",
    "BENCH_chaos.json": "benchmarks/chaos_bench.py",
}


def _commit_time(rel: str):
    try:
        out = subprocess.run(
            ["git", "log", "-1", "--format=%ct", "--", rel],
            cwd=ROOT, capture_output=True, text=True, timeout=30)
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode != 0 or not out.stdout.strip():
        return None
    return int(out.stdout.strip())


def test_bench_snapshots_fresher_than_their_bench():
    """Committed snapshots postdate the bench that writes them.

    A snapshot older than its producing module means the bench changed and
    nobody re-ran it — the committed trend would be comparing incompatible
    measurements.  Equal timestamps (same commit) pass; working-tree edits
    are invisible to this check by design — it gates what lands in a PR.
    """
    if not (ROOT / ".git").exists() or _commit_time("README.md") is None:
        pytest.skip("git history unavailable")
    for snap in sorted(ROOT.glob("BENCH_*.json")):
        producer = _BENCH_PRODUCERS.get(snap.name)
        assert producer is not None, (
            f"{snap.name} has no producer mapping — extend _BENCH_PRODUCERS")
        t_snap = _commit_time(snap.name)
        t_bench = _commit_time(producer)
        if t_snap is None or t_bench is None:
            continue  # never committed yet (fresh working tree)
        assert t_snap >= t_bench, (
            f"{snap.name} (committed {t_snap}) is staler than {producer} "
            f"({t_bench}) — re-run the bench and commit the new snapshot")
