"""trusslint test suite: fixture corpus, waivers, config, self-check.

Every rule has a seeded-violation fixture that must fire and a fixed
form that must stay quiet (`tests/analysis_fixtures/`); the self-check
asserts the analyzer runs clean on ``src/repro`` itself with the repo
config — the same invocation as the CI ``static-analysis`` job.
"""

import pathlib

import pytest

from repro.analysis import LintConfig, RetraceGuard, run_paths
from repro.analysis.config import load_config, parse_toml_subset
from repro.analysis import modgraph

ROOT = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = pathlib.Path(__file__).parent / "analysis_fixtures"


def run_fixture(name, cfg=None):
    findings = run_paths([FIXTURES / name], cfg or LintConfig(), ROOT)
    return [f for f in findings if not f.waived]


RULE_CASES = ["j001", "j002", "j003", "j004", "p001", "p002",
              "l001", "l002"]


@pytest.mark.parametrize("stem", RULE_CASES)
def test_rule_fires_on_seeded_violation(stem):
    rule = stem.upper()
    found = run_fixture(f"{stem}_bad.py")
    assert any(f.rule == rule for f in found), \
        f"{rule} did not fire on {stem}_bad.py: {found}"


@pytest.mark.parametrize("stem", RULE_CASES)
def test_rule_quiet_on_fixed_form(stem):
    found = run_fixture(f"{stem}_good.py")
    assert found == [], f"{stem}_good.py should be clean: {found}"


def test_j002_fires_on_both_keyword_and_positional_statics():
    found = run_fixture("j002_bad.py")
    assert len([f for f in found if f.rule == "J002"]) == 2


def _l003_cfg():
    # two distinct locks, no aliasing (the repo config aliases
    # _lock/_work because the Condition wraps the same mutex)
    return LintConfig(lock_attrs=("_lock", "_iolock"), lock_aliases=())


def test_l003_fires_on_cycle_and_reentrancy():
    found = [f for f in run_fixture("l003_bad.py", _l003_cfg())
             if f.rule == "L003"]
    msgs = " | ".join(f.message for f in found)
    assert "cycle" in msgs and "re-acquired" in msgs


def test_l003_quiet_on_consistent_order():
    assert run_fixture("l003_good.py", _l003_cfg()) == []


def _r001_cfg():
    # the repo default only scans the serving path; point the rule at the
    # fixture dir so the corpus exercises it
    return LintConfig(fault_paths=("*",))


def test_r001_fires_on_swallowed_broad_handlers():
    found = [f for f in run_fixture("r001_bad.py", _r001_cfg())
             if f.rule == "R001"]
    assert len(found) == 2          # plain Exception + broad tuple
    assert all("swallows" in f.message for f in found)


def test_r001_quiet_on_routed_handlers():
    assert run_fixture("r001_good.py", _r001_cfg()) == []


def test_r001_scoped_to_configured_fault_paths():
    # default config: the fixture is outside the serving path, no finding
    assert run_fixture("r001_bad.py") == []


def test_waiver_comments_silence_findings():
    all_findings = run_paths([FIXTURES / "waiver.py"], LintConfig(), ROOT)
    assert all(f.waived for f in all_findings)
    assert len(all_findings) == 2  # the violations are still detected


# ---------------------------------------------------------------- U-rules --


def _write(path, text):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)


def test_module_liveness_rules(tmp_path):
    src = tmp_path / "src" / "repro"
    _write(src / "live" / "__init__.py", "from repro.live import used\n")
    _write(src / "live" / "used.py",
           "from repro.scaffolding import old\n")
    _write(src / "live" / "orphan.py", "X = 1\n")
    _write(src / "scaffolding" / "__init__.py", "")
    _write(src / "scaffolding" / "old.py", "Y = 2\n")
    cfg = LintConfig(roots=("repro.live",),
                     quarantine=("repro.scaffolding",))
    findings = modgraph.check(tmp_path, cfg)
    by_rule = {f.rule: f for f in findings}
    assert set(by_rule) == {"U001", "U002"}
    assert by_rule["U001"].path.endswith("orphan.py")
    assert by_rule["U002"].path.endswith("used.py")
    assert "repro.scaffolding.old" in by_rule["U002"].message


def test_module_liveness_clean_partition(tmp_path):
    src = tmp_path / "src" / "repro"
    _write(src / "app.py", "from repro import lib\n")
    _write(src / "lib.py", "Z = 3\n")
    cfg = LintConfig(roots=("repro.app",), quarantine=())
    assert modgraph.check(tmp_path, cfg) == []


# ----------------------------------------------------------------- config --


def test_toml_subset_parser_handles_the_table_shapes():
    text = """
# comment with a ] bracket
[tool.trusslint]
src_root = "src"  # trailing comment
[tool.trusslint.locks]
lock_attrs = ["_lock",
              "_work"]
lock_aliases = [["_lock", "_work"]]
[tool.trusslint.retrace]
engine_flush = 5
strictness = true
"""
    data = parse_toml_subset(text)
    table = data["tool"]["trusslint"]
    assert table["src_root"] == "src"
    assert table["locks"]["lock_attrs"] == ["_lock", "_work"]
    assert table["locks"]["lock_aliases"] == [["_lock", "_work"]]
    assert table["retrace"] == {"engine_flush": 5, "strictness": True}


def test_repo_config_loads_and_matches_tomllib_when_available():
    cfg = load_config(ROOT)
    assert "_lock" in cfg.lock_attrs
    assert cfg.roots and cfg.quarantine and cfg.retrace_budgets
    try:
        import tomllib
    except ModuleNotFoundError:
        tomllib = None
    text = (ROOT / "pyproject.toml").read_text()
    mine = parse_toml_subset(text)["tool"]["trusslint"]
    if tomllib is not None:
        assert mine == tomllib.loads(text)["tool"]["trusslint"]
    assert mine["modules"]["quarantine"]


# ------------------------------------------------------------- self-check --


def test_trusslint_runs_clean_on_the_repo():
    cfg = load_config(ROOT)
    active = [f for f in run_paths(["src"], cfg, ROOT) if not f.waived]
    assert active == [], "\n".join(f.render() for f in active)


# ---------------------------------------------------------- retrace guard --


class _FakeJit:
    """Stands in for a jit callable: exposes only _cache_size()."""

    def __init__(self):
        self.entries = 0

    def _cache_size(self):
        return self.entries


def test_retrace_guard_budgets():
    fn = _FakeJit()
    guard = RetraceGuard(budgets={"site": 2})
    guard.track("site", fn)
    with guard:
        fn.entries += 3
    assert guard.compiles("site") == 3
    assert not guard.ok()
    assert guard.violations() == ["site"]
    with guard:  # re-entry re-snapshots
        fn.entries += 1
    assert guard.compiles("site") == 1
    assert guard.ok()


def test_retrace_guard_unmeasurable_site_passes():
    guard = RetraceGuard(budgets={"x": 0})
    guard.track("x", object())  # no _cache_size hook on this jax
    with guard:
        pass
    report = guard.report()
    assert report["x"]["measured"] is False and report["x"]["ok"]
    assert guard.ok()
