"""End-to-end behaviour: the paper pipeline and the training loop as a user
would run them (examples-level flows, assertions on outcomes)."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import jax.random as jr

from repro.graphs.gen import rmat_edges, ring_of_cliques_edges
from repro.graphs.csr import build_csr
from repro.core import truss_pkt, pkt, truss_trilist
from repro.configs import reduced_config
from repro.models.model import init_params, init_cache
from repro.train.step import TrainState, train_step
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.data.pipeline import SyntheticTokens
from repro.serve.engine import prefill, decode


def test_truss_pipeline_end_to_end():
    """generate → preprocess (KCO reorder) → PKT → k-truss extraction."""
    E = rmat_edges(8, edge_factor=8, seed=5)
    t = truss_pkt(E, reorder=True)
    assert t.shape[0] == E.shape[0]
    assert t.min() >= 2
    # the maximal k-class is non-empty and its edges form a dense subgraph:
    # every edge in the t_max-class has >= t_max-2 triangles within the class
    tmax = int(t.max())
    sub = E[t >= tmax]
    from repro.core.ref import support_naive
    S = support_naive(sub, np.ones(len(sub), bool))
    assert (S >= tmax - 2).all()


def test_truss_deep_peeling():
    """Graph with deep hierarchy: trussness spread over many levels."""
    E = ring_of_cliques_edges(3, 24)
    g = build_csr(E)
    res = pkt(g)
    assert int(res.trussness.max()) == 24
    assert res.levels >= 2
    assert np.array_equal(res.trussness, truss_trilist(g))


def test_train_prefill_decode_roundtrip():
    """Train a tiny model a few steps, then serve it: prefill + decode."""
    cfg = dataclasses.replace(reduced_config("smollm_135m"),
                              compute_dtype="float32")
    params = init_params(cfg, jr.PRNGKey(0))
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt=adamw_init(params))
    opt_cfg = AdamWConfig(lr=1e-3)
    src = SyntheticTokens(cfg.vocab, 32, 4, seed=11)
    step = jax.jit(lambda s, b: train_step(s, b, cfg, opt_cfg))
    for i in range(3):
        b = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        state, metrics = step(state, b)
    assert int(state.step) == 3

    # serve: prefill a prompt and decode 5 tokens greedily
    B, P, MAX = 2, 8, 20
    prompt = jr.randint(jr.PRNGKey(1), (B, P), 0, cfg.vocab)
    cache = init_cache(cfg, B, MAX, dtype=jnp.float32)
    logits, cache = prefill(state.params, cfg, {"tokens": prompt}, cache)
    toks = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    outs = [toks]
    for _ in range(5):
        nxt, _, cache = decode(state.params, cfg, toks, cache)
        toks = nxt[:, None]
        outs.append(toks)
    seq = jnp.concatenate(outs, axis=1)
    assert seq.shape == (B, 6)
    assert int(cache["kv"]["fill"]) == P + 5
    assert ((seq >= 0) & (seq < cfg.vocab)).all()


def test_serving_batch_consistency():
    """Decoding a batch equals decoding each row alone (no cross-batch
    leakage through the cache)."""
    cfg = dataclasses.replace(reduced_config("olmo_1b"),
                              compute_dtype="float32")
    params = init_params(cfg, jr.PRNGKey(2))
    B, P, MAX = 3, 6, 10
    prompt = jr.randint(jr.PRNGKey(3), (B, P), 0, cfg.vocab)
    cache = init_cache(cfg, B, MAX, dtype=jnp.float32)
    logits_b, _ = prefill(params, cfg, {"tokens": prompt}, cache)
    for i in range(B):
        c1 = init_cache(cfg, 1, MAX, dtype=jnp.float32)
        li, _ = prefill(params, cfg, {"tokens": prompt[i:i + 1]}, c1)
        np.testing.assert_allclose(np.asarray(li[0]),
                                   np.asarray(logits_b[i]), atol=2e-4)
