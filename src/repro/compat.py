"""Version tolerance for the span of jax releases this repo runs under.

The repo is exercised on anything from jax 0.4.3x (this container, CPU-only)
up to current releases (TPU pods). Three API moves are papered over here so
that *importing* any repro module never requires a bleeding-edge jax:

  * ``shard_map`` lived in ``jax.experimental.shard_map`` before being
    promoted to ``jax.shard_map``;
  * its replication-check kwarg was renamed ``check_rep`` → ``check_vma``;
  * ``jax.sharding.AxisType`` (explicit-sharding axis annotations) does not
    exist before 0.5; meshes fall back to untyped axes.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5-ish: top-level function
    from jax import shard_map as _shard_map

    if not callable(_shard_map):  # pragma: no cover - defensive
        raise ImportError
except ImportError:  # older: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map

try:
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` across the kwarg rename and module move."""
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check_vma is None:
        return _shard_map(f, **kwargs)
    try:
        return _shard_map(f, check_vma=check_vma, **kwargs)
    except TypeError:
        return _shard_map(f, check_rep=check_vma, **kwargs)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a dict.

    jax 0.4.x returns a one-element list of per-computation dicts; newer
    releases return the dict directly (and may return None off-CPU).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the release supports them.

    ``jax.make_mesh`` itself only exists from ~0.4.35; on the declared
    floor (0.4.30, see pyproject/CI's jax matrix) the mesh is assembled the
    pre-0.4.35 way from ``mesh_utils.create_device_mesh``.
    """
    if not hasattr(jax, "make_mesh"):  # pragma: no cover - floor releases
        from jax.experimental import mesh_utils

        devices = mesh_utils.create_device_mesh(shape)
        return jax.sharding.Mesh(devices, axes)
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # release has AxisType but older make_mesh signature
            pass
    return jax.make_mesh(shape, axes)
