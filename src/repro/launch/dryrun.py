import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (assignment deliverable e).

For every (architecture × shape × mesh) cell this lowers + compiles the real
step function (train_step / prefill / decode) against ShapeDtypeStruct inputs
with production shardings, and records:

  prod mode:  memory_analysis (fits-HBM proof, with auto microbatch
              escalation for train cells), compile wall time, and the
              collective-op inventory of the optimized per-device HLO.
  cost mode:  exact FLOPs / bytes / collective-bytes via fully-unrolled scans
              at 2–3 small layer counts, extrapolated linearly in L (exact:
              per-layer HLO is identical; measured that XLA cost_analysis
              counts a while body once regardless of trip count).

Also dry-runs the paper's workload itself: the distributed-PKT support pass
and one peel sub-level on the production mesh (mode=truss).

Results land in artifacts/dryrun/*.json (idempotent; --force re-runs).
"""

import argparse
import dataclasses
import functools
import json
import re
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import cost_analysis
from repro.launch.mesh import make_production_mesh
from repro.configs import (ARCHS, SHAPES, get_config, input_specs,
                           cell_is_valid)
from repro.models.model import ModelConfig, init_params
from repro.models import sharding as shard_rules
from repro.train.step import TrainState, train_step
from repro.optim.adamw import adamw_init, AdamWConfig
from repro.serve import engine

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")
HBM_BYTES = 16 * 2**30          # v5e
FIT_TARGET = 15.5 * 2**30

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(m) -> float:
    dt, dims = m
    if dt not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collectives(hlo: str) -> dict:
    """Per-device collective inventory from optimized HLO text.

    bytes convention (ring model, per device):
      all-reduce: 2×result, all-gather/all-to-all/permute: result,
      reduce-scatter: operand (≈ result × group size).
    """
    out: dict[str, dict] = {k: {"count": 0, "bytes": 0.0}
                            for k in _COLL_KINDS}
    for line in hlo.splitlines():
        if "=" not in line:
            continue
        for kind in _COLL_KINDS:
            token = f" {kind}("
            start_tok = f" {kind}-start("
            if token not in line and start_tok not in line:
                continue
            if f"{kind}-done" in line:
                continue
            lhs, _, rhs = line.partition("=")
            lhs_shapes = _SHAPE_RE.findall(lhs.split("=")[0])
            # result shapes appear on the RHS before the op name too; prefer
            # the RHS type annotation (post-'=' up to the op token)
            pre_op = rhs.split(kind)[0]
            res_shapes = _SHAPE_RE.findall(pre_op)
            shapes = res_shapes or lhs_shapes
            res_bytes = sum(_shape_bytes(m) for m in shapes)
            if kind == "reduce-scatter":
                inner = rhs.partition("(")[2]
                op_shapes = _SHAPE_RE.findall(inner.split(")")[0])
                b = sum(_shape_bytes(m) for m in op_shapes) or res_bytes
            elif kind == "all-reduce":
                b = 2 * res_bytes
            else:
                b = res_bytes
            out[kind]["count"] += 1
            out[kind]["bytes"] += b
            break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ----------------------------------------------------------- cell builder ----

def _sp_spec(mesh_axes):
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    return (dp, "model", None)


def _cast_tree(tree, dtype):
    def cast(x):
        if np.issubdtype(x.dtype, np.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype)
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    return jax.tree.map(cast, tree)


def build_cell(cfg: ModelConfig, shape: str, mesh, *, microbatches: int = 1,
               donate: bool = True):
    """Returns (jitted fn, example args (SDS), meta) for one cell."""
    axes = mesh.axis_names
    kind = SHAPES[shape][2]
    seq, gbs, _ = SHAPES[shape]
    spec = input_specs(cfg, shape)
    batch_sds = spec["batch"]
    bspec = shard_rules.batch_specs(cfg, batch_sds, axes,
                                    mesh_shape=dict(mesh.shape))
    bsh = {k: NamedSharding(mesh, s) for k, s in bspec.items()}

    if kind == "train":
        cfg = dataclasses.replace(cfg, act_pspec=_sp_spec(axes))
        pshape = jax.eval_shape(functools.partial(init_params, cfg),
                                jax.random.PRNGKey(0))
        pspec = shard_rules.param_specs(cfg, pshape, axes)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                           is_leaf=lambda x: isinstance(x, P))
        oshape = jax.eval_shape(lambda p: adamw_init(p), pshape)
        osh = {"m": psh, "v": psh}
        state_sds = TrainState(step=jax.ShapeDtypeStruct((), jnp.int32),
                               params=pshape, opt=oshape)
        state_sh = TrainState(step=NamedSharding(mesh, P()), params=psh,
                              opt=osh)
        fn = functools.partial(train_step, cfg=cfg, opt_cfg=AdamWConfig(),
                               microbatches=microbatches)
        jfn = jax.jit(fn, in_shardings=(state_sh, bsh),
                      out_shardings=(state_sh, None),
                      donate_argnums=(0,) if donate else ())
        return jfn, (state_sds, batch_sds), {"kind": kind}

    # serving cells: bf16 params, KV/SSM cache
    seq_shard = (shape == "long_500k") and cfg.serve_seq_shard
    if kind == "prefill":
        cfg = dataclasses.replace(cfg, act_pspec=_sp_spec(axes))
    pshape = jax.eval_shape(functools.partial(init_params, cfg),
                            jax.random.PRNGKey(0))
    pshape = _cast_tree(pshape, jnp.bfloat16)
    pspec = shard_rules.param_specs(cfg, pshape, axes,
                                    fsdp_enabled=cfg.serve_fsdp)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                       is_leaf=lambda x: isinstance(x, P))
    cache_sds = spec["cache"]
    csh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        shard_rules.cache_specs(cfg, cache_sds, axes, seq_shard=seq_shard,
                                mesh_shape=dict(mesh.shape)),
        is_leaf=lambda x: isinstance(x, P))

    if kind == "prefill":
        cfg_p = cfg

        def fn(params, batch, cache):
            return engine.prefill(params, cfg_p, batch, cache)

        jfn = jax.jit(fn, in_shardings=(psh, bsh, csh),
                      out_shardings=(None, csh),
                      donate_argnums=(2,) if donate else ())
        return jfn, (pshape, batch_sds, cache_sds), {"kind": kind}

    # decode: single new token
    tok_key = "embeds" if cfg.input_is_embeds else "tokens"
    tok_sds = batch_sds[tok_key]
    tok_sh = bsh[tok_key]
    pos_sds = batch_sds.get("positions")

    def fn(params, tokens, cache, positions=None):
        return engine.decode(params, cfg, tokens, cache, positions=positions)

    if pos_sds is not None:
        jfn = jax.jit(fn, in_shardings=(psh, tok_sh, csh, bsh["positions"]),
                      out_shardings=(None, None, csh),
                      donate_argnums=(2,) if donate else ())
        return jfn, (pshape, tok_sds, cache_sds, pos_sds), {"kind": kind}
    jfn = jax.jit(fn, in_shardings=(psh, tok_sh, csh),
                  out_shardings=(None, None, csh),
                  donate_argnums=(2,) if donate else ())
    return jfn, (pshape, tok_sds, cache_sds), {"kind": kind}


def lower_cell(cfg, shape, mesh, *, microbatches=1, want_hlo=False,
               donate=True):
    jfn, args, meta = build_cell(cfg, shape, mesh, microbatches=microbatches,
                                 donate=donate)
    t0 = time.time()
    with mesh:
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    dt = time.time() - t0
    ma = compiled.memory_analysis()
    ca = cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    rec = {
        "compile_s": round(dt, 2),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "arg_bytes": int(ma.argument_size_in_bytes),
        "out_bytes": int(ma.output_size_in_bytes),
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "microbatches": microbatches,
        "kind": meta["kind"],
    }
    if want_hlo:
        rec["_hlo"] = hlo
    return rec


# ------------------------------------------------------------- cost mode ----

def _cost_layer_counts(cfg: ModelConfig) -> tuple[int, int, int]:
    """(L1, L2, tail_L) with L2-L1 = one period; 0 tail if none."""
    p = cfg.attn_every if (cfg.family == "hybrid" and cfg.attn_every) else 1
    r = cfg.n_layers % p
    return p, 2 * p, (p + r) if r else 0


def cost_cell(cfg: ModelConfig, shape: str, mesh, *,
              microbatches: int = 1) -> dict:
    """Exact extrapolated cost terms for the full-depth model."""
    L = cfg.n_layers
    L1, L2, Lt = _cost_layer_counts(cfg)
    kv_chunk = max(cfg.kv_chunk, 8192)     # fewer unrolled chunks, same math
    base_cfg = dataclasses.replace(cfg, unroll_scans=True, kv_chunk=kv_chunk,
                                   ssm_q_chunk=max(cfg.ssm_q_chunk, 512))

    def run(nl):
        c = dataclasses.replace(base_cfg, n_layers=nl)
        return lower_cell(c, shape, mesh, donate=False,
                          microbatches=microbatches)

    r1 = run(L1)
    r2 = run(L2)
    period = cfg.attn_every if (cfg.family == "hybrid" and cfg.attn_every) else 1
    k = L // period
    rt = run(Lt) if Lt else None

    def extrap(field, sub=None):
        def g(r):
            return r[field] if sub is None else r[field][sub]["bytes"]
        delta = g(r2) - g(r1)
        total = g(r1) + (k - 1) * delta
        if rt is not None:
            total += g(rt) - g(r1)
        return total

    coll = {}
    for kind in _COLL_KINDS:
        coll[kind] = {
            "bytes": extrap("collectives", kind),
            "count_L1": r1["collectives"][kind]["count"],
        }
    coll["total_bytes"] = sum(v["bytes"] for v in coll.values()
                              if isinstance(v, dict))
    return {
        "flops": extrap("flops"),
        "bytes_accessed": extrap("bytes_accessed"),
        "collectives": coll,
        "layer_counts": [L1, L2, Lt],
        "compile_s": r1["compile_s"] + r2["compile_s"]
        + (rt["compile_s"] if rt else 0.0),
        "kind": r1["kind"],
    }


# ------------------------------------------------------------ truss cells ----

def truss_cell(mesh, *, log_m: int = 27, chunk: int = 1 << 14) -> dict:
    """Dry-run the distributed PKT on the production mesh.

    Synthetic sizes: m = 2**log_m edges, wedge tables ~16 entries/edge.
    Lowers (a) the sharded support pass (no loops — exact cost) and (b) the
    full peel loop (compile/memory proof).
    """
    from repro.core.pkt_dist import make_support_dist, make_pkt_dist
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names) + ("model",)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    m = 1 << log_m
    two_m = 2 * m
    tab = 16 * m
    tab = -(-tab // (n_dev * chunk)) * (n_dev * chunk)
    sds = jax.ShapeDtypeStruct
    i32 = jnp.int32
    N = sds((two_m,), i32)
    Eid = sds((two_m,), i32)
    S0 = sds((m,), i32)
    e1 = sds((tab,), i32)
    cs = sds((tab,), i32)
    lo = sds((tab,), i32)
    hi = sds((tab,), i32)

    rec = {}
    sup = make_support_dist(mesh, axes, m=m, iters=20)
    with mesh:
        t0 = time.time()
        c = sup.lower(N, Eid, e1, cs, lo, hi).compile()
        ma = c.memory_analysis()
        ca = cost_analysis(c)
        rec["support"] = {
            "compile_s": round(time.time() - t0, 2),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "arg_bytes": int(ma.argument_size_in_bytes),
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "collectives": parse_collectives(c.as_text()),
        }
        peel = make_pkt_dist(mesh, axes, m=m, two_m=two_m, table_size=tab,
                             iters=20, chunk=chunk)
        t0 = time.time()
        c2 = peel.lower(N, Eid, S0, e1, cs, lo, hi).compile()
        ma2 = c2.memory_analysis()
        rec["peel_loop"] = {
            "compile_s": round(time.time() - t0, 2),
            "temp_bytes": int(ma2.temp_size_in_bytes),
            "arg_bytes": int(ma2.argument_size_in_bytes),
            "collectives_static": parse_collectives(c2.as_text()),
        }
    rec["m"] = m
    rec["table_entries"] = tab
    rec["devices"] = n_dev
    return rec


# ------------------------------------------------------------------ main ----

def run_one(arch: str, shape: str, mesh_kind: str, mode: str,
            force: bool = False) -> dict | None:
    os.makedirs(ART_DIR, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_kind}__{mode}"
    path = os.path.join(ART_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    ok, why = cell_is_valid(arch, shape)
    if not ok:
        rec = {"skipped": True, "reason": why}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    cfg = get_config(arch)
    kind = SHAPES[shape][2]
    try:
        if mode == "cost":
            # match the microbatch count the prod pass settled on, so the
            # cost terms describe the configuration that actually fits
            prod = run_one(arch, shape, mesh_kind, "prod", force=False)
            mb = prod.get("microbatches", 1) if prod else 1
            rec = cost_cell(cfg, shape, mesh, microbatches=mb or 1)
        else:
            rec = None
            mbs = [1, 2, 4, 8, 16] if kind == "train" else [1]
            for mb in mbs:
                rec = lower_cell(cfg, shape, mesh, microbatches=mb)
                rec["fits_hbm"] = (rec["temp_bytes"] + rec["arg_bytes"]
                                   <= FIT_TARGET)
                if rec["fits_hbm"]:
                    break
    except Exception as e:  # record failures — they are bugs to fix
        rec = {"error": f"{type(e).__name__}: {e}"}
    rec["arch"] = arch
    rec["shape"] = shape
    rec["mesh"] = mesh_kind
    rec["mode"] = mode
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "pod", "multipod"])
    ap.add_argument("--mode", default=None, choices=[None, "prod", "cost"])
    ap.add_argument("--workload", default="lm", choices=["lm", "truss"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.workload == "truss":
        os.makedirs(ART_DIR, exist_ok=True)
        for mesh_kind in ([args.mesh] if args.mesh else ["pod", "multipod"]):
            path = os.path.join(ART_DIR, f"truss__{mesh_kind}.json")
            if os.path.exists(path) and not args.force:
                print(f"truss {mesh_kind}: cached")
                continue
            mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
            rec = truss_cell(mesh)
            rec["mesh"] = mesh_kind
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"truss {mesh_kind}: support temp "
                  f"{rec['support']['temp_bytes']/2**30:.2f} GiB, peel temp "
                  f"{rec['peel_loop']['temp_bytes']/2**30:.2f} GiB")
        return

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["pod", "multipod"]
    modes = [args.mode] if args.mode else ["prod", "cost"]
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                for mode in modes:
                    if mode == "cost" and mesh_kind == "multipod":
                        continue  # roofline table is single-pod
                    t0 = time.time()
                    rec = run_one(arch, shape, mesh_kind, mode,
                                  force=args.force)
                    status = ("SKIP" if rec.get("skipped") else
                              "ERR " if rec.get("error") else "ok  ")
                    extra = ""
                    if not rec.get("skipped") and not rec.get("error"):
                        if mode == "prod":
                            tot = (rec["temp_bytes"] + rec["arg_bytes"]) / 2**30
                            extra = (f"mem {tot:6.2f} GiB mb={rec['microbatches']}"
                                     f" fits={rec.get('fits_hbm')}")
                        else:
                            extra = (f"flops {rec['flops']:.3e} coll "
                                     f"{rec['collectives']['total_bytes']:.3e}B")
                    print(f"{arch:18s} {shape:12s} {mesh_kind:8s} {mode:4s} "
                          f"{status} {time.time()-t0:6.1f}s  {extra}",
                          flush=True)
                    if rec.get("error"):
                        print("    ", rec["error"][:300], flush=True)


if __name__ == "__main__":
    main()
