"""Training driver: config → mesh → data → train loop with checkpointing,
straggler detection, heartbeat, and restart-on-failure.

CPU-runnable end-to-end with reduced configs; the same driver lowers the
production shapes on the 256/512-chip meshes (see dryrun.py for the
compile-only proof).

  PYTHONPATH=src python -m repro.launch.train --arch smollm_135m \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.models.model import init_params
from repro.models import sharding as shard_rules
from repro.train.step import TrainState, train_step
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.data.pipeline import SyntheticTokens
from repro.checkpoint import CheckpointManager
from repro.runtime.fault import StragglerMonitor, Heartbeat, run_with_retries
from repro.launch.mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "never"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject one failure (fault-tolerance demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh()
    print(f"arch={cfg.name} reduced={args.reduced} mesh={dict(mesh.shape)} "
          f"params≈{cfg.param_count()/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                          total_steps=args.steps)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt=adamw_init(params))

    pspec = shard_rules.param_specs(cfg, jax.eval_shape(lambda: params),
                                    mesh.axis_names)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                       is_leaf=lambda x: isinstance(x, P))
    state_sh = TrainState(step=NamedSharding(mesh, P()), params=psh,
                          opt={"m": psh, "v": psh})
    jfn = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                                    microbatches=args.microbatches),
                  in_shardings=(state_sh, None), out_shardings=(state_sh,
                                                                None),
                  donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start = 0
    if args.resume == "auto":
        like = jax.eval_shape(lambda: state)
        got = mgr.restore_latest(like, shardings=state_sh)
        if got is not None:
            start, state = got
            print(f"resumed from step {start}")
    if start == 0:
        with mesh:
            state = jax.device_put(state, state_sh)

    src = SyntheticTokens(cfg.vocab, args.seq, args.batch, seed=args.seed)
    mon = StragglerMonitor()
    hb = Heartbeat(os.path.join(args.ckpt_dir, "heartbeat.json"),
                   interval_s=2.0)
    holder = {"state": state, "failed": False}

    def one_step(step: int):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(step).items()}
        if step == args.fail_at_step and not holder["failed"]:
            holder["failed"] = True
            raise RuntimeError("injected node failure")
        t0 = time.perf_counter()
        with mesh:
            holder["state"], metrics = jfn(holder["state"], batch)
        jax.block_until_ready(holder["state"].step)
        dt = time.perf_counter() - t0
        slow = mon.observe(step, dt)
        hb.beat(step, loss=float(metrics["ce"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms"
                  f"{' STRAGGLER' if slow else ''}", flush=True)
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            mgr.save(step + 1, holder["state"])

    def on_retry(step: int, exc: Exception) -> int:
        print(f"step {step} failed ({exc}); restoring from checkpoint")
        got = mgr.restore_latest(jax.eval_shape(lambda: holder["state"]),
                                 shardings=state_sh)
        if got is None:
            holder["state"] = jax.device_put(
                TrainState(step=jnp.zeros((), jnp.int32), params=init_params(
                    cfg, jax.random.PRNGKey(args.seed)),
                    opt=adamw_init(params)), state_sh)
            return 0
        s, holder["state"] = got
        return s

    run_with_retries(one_step, start_step=start, end_step=args.steps,
                     on_retry=on_retry)
    mgr.wait()
    print("done; final loss above, checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
