"""Serving driver: batched prefill + decode over a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m --reduced \
      --requests 8 --prompt-len 16 --gen 24
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp
import jax.random as jr

from repro.configs import get_config, reduced_config
from repro.models.model import init_params, init_cache
from repro.serve.engine import prefill, decode
from repro.launch.mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    make_host_mesh()   # device-mesh init (serving here is single-host)
    params = init_params(cfg, jr.PRNGKey(args.seed))
    B, P, G = args.requests, args.prompt_len, args.gen
    max_seq = P + G
    key = jr.PRNGKey(args.seed + 1)

    if cfg.input_is_embeds:
        prompts = jr.normal(key, (B, P, cfg.d_model), cfg.dtype)
        batch = {"embeds": prompts}
    else:
        prompts = jr.randint(key, (B, P), 0, cfg.vocab)
        batch = {"tokens": prompts}
    if cfg.rope == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(P)[None, :, None], (B, P, 3)).astype(jnp.int32)

    cache = init_cache(cfg, B, max_seq)
    t0 = time.perf_counter()
    logits, cache = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))(
        params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    toks = jnp.argmax(logits.astype(jnp.float32), axis=-1)[:, None] \
        .astype(jnp.int32)

    dec = jax.jit(lambda p, t, c, pos: decode(p, cfg, t, c, positions=pos,
                                              temperature=args.temperature))
    outs = [toks]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = None
        if cfg.rope == "mrope":
            pos = jnp.full((B, 1, 3), P + i, jnp.int32)
        step_in = toks
        if cfg.input_is_embeds:
            step_in = params["embed"][toks[:, 0]][:, None].astype(cfg.dtype)
        nxt, _, cache = dec(params, step_in, cache, pos)
        toks = nxt[:, None]
        outs.append(toks)
    jax.block_until_ready(toks)
    t_dec = time.perf_counter() - t0
    seqs = np.asarray(jnp.concatenate(outs, axis=1))
    print(f"arch={cfg.name} B={B} prefill({P} toks) {t_prefill*1e3:.1f}ms  "
          f"decode {G-1} steps {t_dec*1e3:.1f}ms "
          f"({(G-1)*B/max(t_dec,1e-9):.1f} tok/s)")
    for i in range(min(4, B)):
        print(f"  req{i}: {seqs[i][:16]}...")


if __name__ == "__main__":
    main()
