"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state. Single pod: 256 chips as (data=16, model=16). Multi-pod: a
leading pod=2 axis (512 chips) — the "pod" axis carries pure data parallelism
with gradient all-reduce over the (slow) cross-pod links.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    n_data = n_data or n
    return make_mesh((n_data, n // n_data), ("data", "model"))
