"""Truss decomposition driver — the paper's pipeline end-to-end.

  PYTHONPATH=src python -m repro.launch.truss --graph rmat-small \
      [--order kco|natural] [--engine pkt|dist|trilist|wc|ros] [--verify]

Streaming replay (incremental maintenance, DESIGN.md §9): open the graph as
a persistent engine handle and replay K churn batches through
``TrussEngine.update``, reporting local-vs-full repair decisions and
timings; with ``--verify`` the final state is checked against a
from-scratch PKT:

  PYTHONPATH=src python -m repro.launch.truss --graph rmat-small \
      --update-stream 16 --churn 0.01 \
      [--insert-mode batched|sequential] [--verify]

Community serving (DESIGN.md §11): open the graph as a handle, build the
triangle-connected k-truss community index, and answer queries at level k —
with ``--verify`` the device label-propagation labels are checked bitwise
against the host union-find oracle on every level.  Composes with
``--update-stream`` (the index is queried on the post-churn graph, having
survived the updates through remap/dirty-level invalidation):

  PYTHONPATH=src python -m repro.launch.truss --graph rmat-small \
      --query-communities 4 [--hier-mode device|host] [--verify]

Async serving (DESIGN.md §12): replay paced mixed 90/9/1 query/update/open
traffic through the continuous-batching ``TrussScheduler``, printing
per-kind latency percentiles and the scheduler's per-stage timing; with
``--verify`` every async result is checked bitwise against a synchronous
engine replay of the same schedule:

  PYTHONPATH=src python -m repro.launch.truss --graph rmat-small \
      --serve 200 --qps 200 [--max-batch 16] [--max-delay-ms 2] [--verify]

Chaos serving (DESIGN.md §15): same replay with deterministic faults
injected at every dispatch site at ``--fault-rate`` and optional
per-request ``--deadline-ms`` budgets; failures surface as typed errors,
the availability and resilience counters (retries, ladder demotions,
heals) are reported, and ``--verify`` masks failed requests before the
synchronous parity replay:

  PYTHONPATH=src python -m repro.launch.truss --graph rmat-small \
      --serve 200 --qps 200 --fault-rate 0.1 [--deadline-ms 250] [--verify]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

from repro.graphs.datasets import named_graph
from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.kernels.wedge_common import pow2_chunk
from repro.core import (pkt, truss_wc, truss_ros, truss_trilist, truss_numpy,
                        pkt_dist)

# ------------------------------------------------------- host env tuning ----

#: re-exec guard: set once tuning has been applied so ``--tune-env`` cannot
#: loop the process
_ENV_TUNED_MARK = "_TRUSS_ENV_TUNED"

#: where distro packages put tcmalloc (the SNIPPETS.md serving exemplar);
#: first hit wins, absence just skips the preload
TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def tuned_env(environ=None) -> dict[str, str]:
    """Host-side env additions for serving (docs/PERFORMANCE.md):

    * ``LD_PRELOAD`` tcmalloc — glibc malloc serializes the multi-GiB host
      buffer churn of table builds; tcmalloc's thread caches don't.
    * ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` raised so steady-state
      large allocations don't spam stderr.
    * ``TF_CPP_MIN_LOG_LEVEL=4`` — silence the XLA C++ banner on every
      worker.
    * ``JAX_DEFAULT_DTYPE_BITS=32`` — the whole pipeline is int32/float32;
      keep accidental int64 promotion off the device.

    Returns only the *additions* (never overrides anything the user set),
    so it is unit-testable and composes with existing environments.
    """
    env = os.environ if environ is None else environ
    add: dict[str, str] = {}
    if "TF_CPP_MIN_LOG_LEVEL" not in env:
        add["TF_CPP_MIN_LOG_LEVEL"] = "4"
    if "JAX_DEFAULT_DTYPE_BITS" not in env:
        add["JAX_DEFAULT_DTYPE_BITS"] = "32"
    if "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" not in env:
        add["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = "60000000000"
    if "libtcmalloc" not in env.get("LD_PRELOAD", ""):
        for p in TCMALLOC_PATHS:
            if os.path.exists(p):
                pre = env.get("LD_PRELOAD", "")
                add["LD_PRELOAD"] = f"{pre}:{p}".strip(":")
                break
    return add


def apply_env_tuning(*, reexec: bool = True) -> dict[str, str]:
    """Apply ``tuned_env`` to this process (idempotent via the guard var).

    ``LD_PRELOAD`` only binds at process start, so when the preload is part
    of the additions and ``reexec`` is allowed the process re-execs itself
    once with the tuned environment; everything else takes effect in place.
    Returns the additions that were applied.
    """
    if os.environ.get(_ENV_TUNED_MARK):
        return {}
    add = tuned_env()
    os.environ[_ENV_TUNED_MARK] = "1"
    os.environ.update(add)
    if reexec and "LD_PRELOAD" in add:
        os.execv(sys.executable, [sys.executable] + sys.argv)
    return add


def churn_batch(edges: np.ndarray, n: int, frac: float, rng):
    """One synthetic update batch: remove ``frac·m`` existing edges and add
    the same number of random absent edges (vertex space preserved)."""
    m = edges.shape[0]
    k = max(1, int(round(frac * m)))
    rm = edges[rng.choice(m, size=min(k, m), replace=False)]
    present = set(map(tuple, edges.tolist()))
    add = []
    tries = 0
    while len(add) < k and tries < 100 * k + 1000:  # dense graphs: give up
        tries += 1
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u == v:
            continue
        e = (min(u, v), max(u, v))
        if e not in present:
            present.add(e)
            add.append(e)
    if not add:
        return np.zeros((0, 2), np.int64), rm
    return np.asarray(add, np.int64), rm


def report_communities(handle, k: int, *, verify: bool = False) -> None:
    """Build the community index on ``handle`` and report level-``k`` stats.

    Prints index-build cost (one vmapped dispatch in device mode), the
    level-k community size spectrum, and a sampled per-query latency; with
    ``verify`` every level's labels are checked bitwise against the host
    union-find oracle.
    """
    t0 = time.perf_counter()
    hier = handle.hierarchy().build_all()
    t_build = time.perf_counter() - t0
    comms = handle.communities(k)
    sizes = sorted((c.shape[0] for c in comms), reverse=True)
    E = handle.edges                    # hoisted: El copies stay untimed
    t0 = time.perf_counter()
    n_q = 0
    for eid in range(0, handle.m, max(1, handle.m // 64)):
        handle.community(tuple(E[eid]), k)
        n_q += 1
    t_query = (time.perf_counter() - t0) / max(1, n_q)
    print(f"community index: k_max={hier.k_max} "
          f"levels={len(list(hier.levels))} build {t_build * 1e3:.1f}ms "
          f"({hier.stats})")
    print(f"k={k}: {len(comms)} communities, edge sizes top5={sizes[:5]}, "
          f"query {t_query * 1e6:.0f}us/edge")
    if verify:
        other = "host" if hier.mode == "device" else "device"
        oracle = handle.hierarchy(mode=other).build_all()
        ok = all(np.array_equal(hier.level_labels(kk), oracle.level_labels(kk))
                 for kk in hier.levels)
        print(f"verify {hier.mode} labels vs {other} builder:",
              "OK" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


def run_update_stream(args) -> None:
    """Replay ``--update-stream`` churn batches through an engine handle."""
    from repro.serve.truss_engine import TrussEngine

    E = named_graph(args.graph)
    n = int(E.max()) + 1
    eng = TrussEngine(mode=args.mode, support_mode=args.support_mode,
                      table_mode=args.table_mode, hier_mode=args.hier_mode,
                      insert_mode=args.insert_mode,
                      chunk=args.chunk)
    t0 = time.perf_counter()
    h = eng.open(E, local_frac=args.local_frac)
    t_open = time.perf_counter() - t0
    print(f"graph={args.graph} n={n} m={h.m} open {t_open:.3f}s "
          f"mode={args.mode} sup={args.support_mode} "
          f"insert={args.insert_mode}")
    if args.query_communities:
        # build the index up front so the stream exercises its survival
        # (local repairs remap untouched levels, dirty the rest)
        h.hierarchy().build_all()

    rng = np.random.default_rng(args.update_seed)
    for i in range(args.update_stream):
        add, rm = churn_batch(h.edges, n, args.churn, rng)
        st = eng.update(h, add_edges=add, remove_edges=rm)
        print(f"batch {i:3d}: +{st.inserted} -{st.deleted} -> m={st.m_after} "
              f"repair={st.mode} affected={st.affected} "
              f"boundary={st.boundary} changed={st.changed} "
              f"{st.seconds * 1e3:.1f}ms")

    s = eng.stats
    mean_ms = 1e3 * s["update_seconds"] / max(1, s["updates"])
    print(f"stream done: {s['updates']} updates "
          f"({s['updates_local']} local / {s['updates_full']} full), "
          f"mean {mean_ms:.1f}ms vs open {t_open * 1e3:.1f}ms")

    if args.query_communities:
        report_communities(h, args.query_communities, verify=args.verify)

    if args.verify:
        from repro.core import truss_pkt
        ok = np.array_equal(h.trussness, truss_pkt(h.edges))
        print("verify vs from-scratch pkt:", "OK" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


def run_serve(args) -> None:
    """Replay paced mixed traffic through the async scheduler (``--serve``).

    Opens the named graph as a persistent handle, then replays ``--serve``
    requests at ``--qps`` in the 90/9/1 query/update/open serving mix
    (DESIGN.md §12): trussness queries on base rows, churn updates toggling
    a reserved extra-edge pool (so queried rows always exist), and opens of
    small fresh graphs.  Prints per-kind latency and the scheduler's stage
    breakdown; ``--verify`` replays the same schedule through a synchronous
    engine and checks every result bitwise.

    With ``--fault-rate`` a seeded ``FaultPlan`` injects dispatch faults
    during the replay (DESIGN.md §15): completed requests stay bitwise
    parity-checked, failed ones are masked from the sync replay (their
    updates never committed — commit is batch-scoped).
    """
    import contextlib

    from repro.graphs.gen import erdos_renyi_edges
    from repro.serve.scheduler import TrussScheduler

    E = named_graph(args.graph)
    n = int(E.max()) + 1
    rng = np.random.default_rng(args.update_seed)
    # reserved churn pool: absent edges the updates toggle, disjoint from
    # the base rows the queries sample (keeps both replays valid)
    present = {(int(u), int(v)) for u, v in E}
    pool = []
    while len(pool) < 32:
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u != v and (min(u, v), max(u, v)) not in present:
            pool.append((min(u, v), max(u, v)))
            present.add(pool[-1])

    # a replay measures latency, not shedding: admit the whole schedule
    sched = TrussScheduler(
        max_batch=args.max_batch, max_delay_ms=args.max_delay_ms,
        max_queue=max(256, 4 * args.serve),
        max_inflight=max(64, 4 * args.serve),
        deadline_ms=args.deadline_ms,
        mode=args.mode, support_mode=args.support_mode,
        table_mode=args.table_mode, hier_mode=args.hier_mode,
        insert_mode=args.insert_mode,
        chunk=args.chunk)
    t0 = time.perf_counter()
    h = sched.open_async(E, local_frac=args.local_frac).result()
    print(f"graph={args.graph} n={n} m={h.m} open "
          f"{time.perf_counter() - t0:.3f}s qps={args.qps} "
          f"mix=90/9/1 query/update/open fault_rate={args.fault_rate}")

    plan = None
    if args.fault_rate > 0.0:
        from repro.testing.chaos import FaultPlan
        plan = FaultPlan.uniform(args.fault_rate, seed=args.update_seed)

    # deterministic schedule (generation tracks pool presence so removals
    # always hit present edges)
    ops, in_pool, n_open = [], set(), 0
    for _ in range(args.serve):
        r = rng.random()
        if r < 0.90:
            ops.append(("query", E[rng.integers(0, E.shape[0], size=8)]))
        elif r < 0.99:
            picks = [pool[j] for j in rng.choice(len(pool), size=4,
                                                 replace=False)]
            add = [e for e in picks if e not in in_pool]
            rem = [e for e in picks if e in in_pool]
            in_pool |= set(add)
            in_pool -= set(rem)
            ops.append(("update", np.array(add or np.zeros((0, 2)), np.int64),
                        np.array(rem or np.zeros((0, 2)), np.int64)))
        else:
            ops.append(("open", erdos_renyi_edges(
                64, 8.0, seed=args.update_seed + 5000 + n_open)))
            n_open += 1

    lat, futs = [], []
    with plan if plan is not None else contextlib.nullcontext():
        t_start = time.perf_counter()
        for i, op in enumerate(ops):
            target = t_start + i / args.qps
            if target > time.perf_counter():
                time.sleep(target - time.perf_counter())
            t_enq = time.perf_counter()
            if op[0] == "query":
                f = sched.query_async(h, op[1])
            elif op[0] == "update":
                f = sched.update_async(h, add_edges=op[1],
                                       remove_edges=op[2])
            else:
                f = sched.open_async(op[1])
            f.add_done_callback(lambda f, k=op[0], t=t_enq:
                                lat.append((k, time.perf_counter() - t)))
            futs.append(f)
        outcomes = []
        for f in futs:
            try:
                outcomes.append(("ok", f.result()))
            except Exception as e:  # noqa: BLE001 — typed, classified below
                outcomes.append(("failed", e))
        duration = time.perf_counter() - t_start
    st = sched.stats()
    sched.close()

    for kind in ("query", "update", "open"):
        ms = sorted(1e3 * s for k, s in lat if k == kind)
        if ms:
            print(f"{kind:6s} n={len(ms):4d} "
                  f"p50={ms[len(ms) // 2]:.2f}ms "
                  f"p99={ms[min(len(ms) - 1, int(0.99 * len(ms)))]:.2f}ms "
                  f"max={ms[-1]:.2f}ms")
    print(f"achieved {len(ops) / duration:.0f} qps "
          f"(offered {args.qps:.0f}); dispatches="
          f"{st['counters']['dispatches']} "
          f"coalesced_updates={st['counters']['coalesced_updates']} "
          f"shed={st['counters']['shed']}")
    for stage, s in st["stages"].items():
        if s["count"]:
            print(f"  stage {stage:10s} n={s['count']:4d} "
                  f"total={s['seconds'] * 1e3:.1f}ms "
                  f"max={s['max_seconds'] * 1e3:.1f}ms")

    n_ok = sum(1 for s, _ in outcomes if s == "ok")
    if plan is not None or args.deadline_ms:
        from repro.serve import DeadlineExceeded
        from repro.testing.chaos import InjectedFault
        fails = [e for s, e in outcomes if s == "failed"]
        n_inj = sum(isinstance(e, InjectedFault) for e in fails)
        n_dead = sum(isinstance(e, DeadlineExceeded) for e in fails)
        inj = dict(plan.stats()["injected"]) if plan is not None else {}
        print(f"chaos: availability {n_ok}/{len(ops)} "
              f"({n_ok / max(1, len(ops)):.3f}) injected={inj} "
              f"failed: injected={n_inj} deadline={n_dead} "
              f"other={len(fails) - n_inj - n_dead}")
        print(f"  retries={st['counters']['retries']} "
              f"heals={st['counters']['heals']} "
              f"deadline_exceeded={st['counters']['deadline_exceeded']} "
              f"rungs=" +
              ", ".join(f"{site}:{r['rung']}"
                        for site, r in st["resilience"].items()))

    if args.verify:
        from repro.serve.truss_engine import TrussEngine

        eng = TrussEngine(mode=args.mode, support_mode=args.support_mode,
                          table_mode=args.table_mode,
                          hier_mode=args.hier_mode,
                          chunk=args.chunk)
        hs = eng.open(E, local_frac=args.local_frac)
        ok = True
        for op, (status, got) in zip(ops, outcomes):
            if status != "ok":
                continue            # failed ops never committed: masked
            if op[0] == "query":
                ok = ok and np.array_equal(got, hs.query(op[1]))
            elif op[0] == "update":
                eng.update(hs, add_edges=op[1], remove_edges=op[2])
            else:
                ok = ok and np.array_equal(got.trussness,
                                           eng.open(op[1]).trussness)
        ok = ok and np.array_equal(h.trussness, hs.trussness)
        print("verify async vs sync engine (failed ops masked):",
              "OK" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


def run_query_communities(args) -> None:
    """Open the graph as a serving handle and answer community queries."""
    from repro.serve.truss_engine import TrussEngine

    E = named_graph(args.graph)
    eng = TrussEngine(mode=args.mode, support_mode=args.support_mode,
                      table_mode=args.table_mode, hier_mode=args.hier_mode,
                      chunk=args.chunk)
    t0 = time.perf_counter()
    h = eng.open(E)
    t_open = time.perf_counter() - t0
    print(f"graph={args.graph} n={h.n} m={h.m} open {t_open:.3f}s "
          f"hier_mode={args.hier_mode}")
    report_communities(h, args.query_communities, verify=args.verify)


def main(argv=None):
    # env tuning must act before any heavy jax work; re-exec only on a real
    # CLI invocation (tests pass argv explicitly and must not exec away)
    raw = sys.argv[1:] if argv is None else argv
    if "--tune-env" in raw:
        apply_env_tuning(reexec=argv is None)
    ap = argparse.ArgumentParser()
    ap.add_argument("--tune-env", action="store_true",
                    help="apply host env tuning (tcmalloc preload, XLA/TF "
                         "log + dtype defaults) before running; re-execs "
                         "once when the preload changes")
    ap.add_argument("--graph", default="rmat-small")
    ap.add_argument("--order", default="kco", choices=["kco", "natural"])
    ap.add_argument("--engine", default="pkt",
                    choices=["pkt", "dist", "trilist", "wc", "ros"])
    ap.add_argument("--chunk", type=int, default=None,
                    help="peel chunk size (default: derived from the table "
                         "size, see kernels.wedge_common.auto_chunk)")
    from repro.core.pkt import PEEL_MODES
    from repro.core.support import SUPPORT_MODES, TABLE_MODES
    ap.add_argument("--mode", default="chunked", choices=list(PEEL_MODES))
    ap.add_argument("--support-mode", default="jnp",
                    choices=list(SUPPORT_MODES))
    ap.add_argument("--table-mode", default="device",
                    choices=list(TABLE_MODES),
                    help="where wedge tables are built: jitted XLA on "
                         "device (default) or host numpy (parity oracle)")
    ap.add_argument("--compact-frac", type=float, default=0.25,
                    help="live-edge compaction threshold for the peel loop "
                         "(0 disables; see DESIGN.md §10)")
    from repro.core.hierarchy import HIER_MODES
    ap.add_argument("--query-communities", type=int, default=0, metavar="K",
                    help="build the truss community index and report the "
                         "K-truss communities (DESIGN.md §11); composes "
                         "with --update-stream")
    ap.add_argument("--hier-mode", default="device",
                    choices=list(HIER_MODES),
                    help="community-index builder: device label propagation "
                         "(default) or the host union-find parity oracle")
    ap.add_argument("--verify", action="store_true",
                    help="check against the numpy oracle (small graphs!)")
    ap.add_argument("--update-stream", type=int, default=0, metavar="K",
                    help="replay K incremental churn batches through "
                         "TrussEngine.update instead of one decomposition")
    from repro.core.truss_inc import INSERT_MODES
    ap.add_argument("--insert-mode", default="batched",
                    choices=list(INSERT_MODES),
                    help="insertion repair strategy for handle updates: one "
                         "merged-region re-peel per batch (default) or the "
                         "one-at-a-time parity oracle (DESIGN.md §13)")
    ap.add_argument("--churn", type=float, default=0.01,
                    help="fraction of edges swapped per update batch")
    ap.add_argument("--local-frac", type=float, default=0.25,
                    help="affected-region fraction above which an update "
                         "falls back to full recompute")
    ap.add_argument("--update-seed", type=int, default=0)
    ap.add_argument("--serve", type=int, default=0, metavar="N",
                    help="replay N mixed 90/9/1 query/update/open requests "
                         "through the async TrussScheduler (DESIGN.md §12)")
    ap.add_argument("--qps", type=float, default=200.0,
                    help="offered request rate for --serve")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="scheduler bucket size before dispatch (--serve)")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="scheduler latency bound: a non-full bucket "
                         "dispatches once its oldest request waits this "
                         "long (--serve)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="inject seeded dispatch faults at this rate during "
                         "--serve (DESIGN.md §15); completed requests stay "
                         "parity-checked under --verify")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for --serve; expired "
                         "requests fail with a typed DeadlineExceeded")
    args = ap.parse_args(argv)

    if args.serve:
        return run_serve(args)
    if args.update_stream:
        return run_update_stream(args)
    if args.query_communities:
        return run_query_communities(args)

    E = named_graph(args.graph)
    n = int(E.max()) + 1
    t0 = time.perf_counter()
    if args.order == "kco":
        E = relabel(E, degeneracy_order(E, n))
    g = build_csr(E, n)
    t_build = time.perf_counter() - t0
    print(f"graph={args.graph} n={g.n} m={g.m} wedges={g.wedge_count():.3e} "
          f"build {t_build:.2f}s order={args.order}")

    t0 = time.perf_counter()
    if args.engine == "pkt":
        res = pkt(g, chunk=args.chunk, mode=args.mode,
                  support_mode=args.support_mode,
                  table_mode=args.table_mode,
                  compact_frac=args.compact_frac or None)
        truss = res.trussness
        extra = (f"levels={res.levels} sublevels={res.sublevels} "
                 f"compactions={res.compactions}")
    elif args.engine == "dist":
        truss = pkt_dist(g, chunk=pow2_chunk(1 << 12,
                                             args.chunk or (1 << 12)),
                         support_mode=args.support_mode,
                         table_mode=args.table_mode)
        extra = ""
    elif args.engine == "trilist":
        truss = truss_trilist(g)
        extra = ""
    elif args.engine == "wc":
        truss = truss_wc(g)
        extra = ""
    else:
        truss = truss_ros(g)
        extra = ""
    dt = time.perf_counter() - t0
    gweps = g.wedge_count() / max(dt, 1e-12) / 1e9

    tmax = int(truss.max(initial=2))
    hist = np.bincount(np.asarray(truss, np.int64))
    top = ", ".join(f"{k}:{hist[k]}" for k in np.nonzero(hist)[0][-5:])
    print(f"engine={args.engine} time {dt:.3f}s  GWeps {gweps:.4f}  "
          f"t_max {tmax}  {extra}")
    print(f"largest k-classes: {top}")

    if args.verify:
        ref = truss_numpy(g.El)
        ok = np.array_equal(np.asarray(truss, np.int64), ref)
        print("verify vs oracle:", "OK" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
