"""Truss decomposition driver — the paper's pipeline end-to-end.

  PYTHONPATH=src python -m repro.launch.truss --graph rmat-small \
      [--order kco|natural] [--engine pkt|dist|trilist|wc|ros] [--verify]

Streaming replay (incremental maintenance, DESIGN.md §9): open the graph as
a persistent engine handle and replay K churn batches through
``TrussEngine.update``, reporting local-vs-full repair decisions and
timings; with ``--verify`` the final state is checked against a
from-scratch PKT:

  PYTHONPATH=src python -m repro.launch.truss --graph rmat-small \
      --update-stream 16 --churn 0.01 [--verify]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.graphs.datasets import named_graph
from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.core import (pkt, truss_wc, truss_ros, truss_trilist, truss_numpy,
                        pkt_dist)


def churn_batch(edges: np.ndarray, n: int, frac: float, rng):
    """One synthetic update batch: remove ``frac·m`` existing edges and add
    the same number of random absent edges (vertex space preserved)."""
    m = edges.shape[0]
    k = max(1, int(round(frac * m)))
    rm = edges[rng.choice(m, size=min(k, m), replace=False)]
    present = set(map(tuple, edges.tolist()))
    add = []
    tries = 0
    while len(add) < k and tries < 100 * k + 1000:  # dense graphs: give up
        tries += 1
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u == v:
            continue
        e = (min(u, v), max(u, v))
        if e not in present:
            present.add(e)
            add.append(e)
    if not add:
        return np.zeros((0, 2), np.int64), rm
    return np.asarray(add, np.int64), rm


def run_update_stream(args) -> None:
    """Replay ``--update-stream`` churn batches through an engine handle."""
    from repro.serve.truss_engine import TrussEngine

    E = named_graph(args.graph)
    n = int(E.max()) + 1
    eng = TrussEngine(mode=args.mode, support_mode=args.support_mode,
                      table_mode=args.table_mode,
                      chunk=args.chunk or (1 << 12))
    t0 = time.perf_counter()
    h = eng.open(E, local_frac=args.local_frac)
    t_open = time.perf_counter() - t0
    print(f"graph={args.graph} n={n} m={h.m} open {t_open:.3f}s "
          f"mode={args.mode} sup={args.support_mode}")

    rng = np.random.default_rng(args.update_seed)
    for i in range(args.update_stream):
        add, rm = churn_batch(h.edges, n, args.churn, rng)
        st = eng.update(h, add_edges=add, remove_edges=rm)
        print(f"batch {i:3d}: +{st.inserted} -{st.deleted} -> m={st.m_after} "
              f"repair={st.mode} affected={st.affected} "
              f"boundary={st.boundary} changed={st.changed} "
              f"{st.seconds * 1e3:.1f}ms")

    s = eng.stats
    mean_ms = 1e3 * s["update_seconds"] / max(1, s["updates"])
    print(f"stream done: {s['updates']} updates "
          f"({s['updates_local']} local / {s['updates_full']} full), "
          f"mean {mean_ms:.1f}ms vs open {t_open * 1e3:.1f}ms")

    if args.verify:
        from repro.core import truss_pkt
        ok = np.array_equal(h.trussness, truss_pkt(h.edges))
        print("verify vs from-scratch pkt:", "OK" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat-small")
    ap.add_argument("--order", default="kco", choices=["kco", "natural"])
    ap.add_argument("--engine", default="pkt",
                    choices=["pkt", "dist", "trilist", "wc", "ros"])
    ap.add_argument("--chunk", type=int, default=None,
                    help="peel chunk size (default: derived from the table "
                         "size, see kernels.wedge_common.auto_chunk)")
    from repro.core.pkt import PEEL_MODES
    from repro.core.support import SUPPORT_MODES, TABLE_MODES
    ap.add_argument("--mode", default="chunked", choices=list(PEEL_MODES))
    ap.add_argument("--support-mode", default="jnp",
                    choices=list(SUPPORT_MODES))
    ap.add_argument("--table-mode", default="device",
                    choices=list(TABLE_MODES),
                    help="where wedge tables are built: jitted XLA on "
                         "device (default) or host numpy (parity oracle)")
    ap.add_argument("--compact-frac", type=float, default=0.25,
                    help="live-edge compaction threshold for the peel loop "
                         "(0 disables; see DESIGN.md §10)")
    ap.add_argument("--verify", action="store_true",
                    help="check against the numpy oracle (small graphs!)")
    ap.add_argument("--update-stream", type=int, default=0, metavar="K",
                    help="replay K incremental churn batches through "
                         "TrussEngine.update instead of one decomposition")
    ap.add_argument("--churn", type=float, default=0.01,
                    help="fraction of edges swapped per update batch")
    ap.add_argument("--local-frac", type=float, default=0.25,
                    help="affected-region fraction above which an update "
                         "falls back to full recompute")
    ap.add_argument("--update-seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.update_stream:
        return run_update_stream(args)

    E = named_graph(args.graph)
    n = int(E.max()) + 1
    t0 = time.perf_counter()
    if args.order == "kco":
        E = relabel(E, degeneracy_order(E, n))
    g = build_csr(E, n)
    t_build = time.perf_counter() - t0
    print(f"graph={args.graph} n={g.n} m={g.m} wedges={g.wedge_count():.3e} "
          f"build {t_build:.2f}s order={args.order}")

    t0 = time.perf_counter()
    if args.engine == "pkt":
        res = pkt(g, chunk=args.chunk, mode=args.mode,
                  support_mode=args.support_mode,
                  table_mode=args.table_mode,
                  compact_frac=args.compact_frac or None)
        truss = res.trussness
        extra = (f"levels={res.levels} sublevels={res.sublevels} "
                 f"compactions={res.compactions}")
    elif args.engine == "dist":
        truss = pkt_dist(g, chunk=min(args.chunk or (1 << 12), 1 << 12),
                         support_mode=args.support_mode,
                         table_mode=args.table_mode)
        extra = ""
    elif args.engine == "trilist":
        truss = truss_trilist(g)
        extra = ""
    elif args.engine == "wc":
        truss = truss_wc(g)
        extra = ""
    else:
        truss = truss_ros(g)
        extra = ""
    dt = time.perf_counter() - t0
    gweps = g.wedge_count() / max(dt, 1e-12) / 1e9

    tmax = int(truss.max(initial=2))
    hist = np.bincount(np.asarray(truss, np.int64))
    top = ", ".join(f"{k}:{hist[k]}" for k in np.nonzero(hist)[0][-5:])
    print(f"engine={args.engine} time {dt:.3f}s  GWeps {gweps:.4f}  "
          f"t_max {tmax}  {extra}")
    print(f"largest k-classes: {top}")

    if args.verify:
        ref = truss_numpy(g.El)
        ok = np.array_equal(np.asarray(truss, np.int64), ref)
        print("verify vs oracle:", "OK" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
