"""Truss decomposition driver — the paper's pipeline end-to-end.

  PYTHONPATH=src python -m repro.launch.truss --graph rmat-small \
      [--order kco|natural] [--engine pkt|dist|trilist|wc|ros] [--verify]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.graphs.datasets import named_graph
from repro.graphs.csr import build_csr, relabel, degeneracy_order
from repro.core import (pkt, truss_wc, truss_ros, truss_trilist, truss_numpy,
                        pkt_dist)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat-small")
    ap.add_argument("--order", default="kco", choices=["kco", "natural"])
    ap.add_argument("--engine", default="pkt",
                    choices=["pkt", "dist", "trilist", "wc", "ros"])
    ap.add_argument("--chunk", type=int, default=1 << 14)
    from repro.core.pkt import PEEL_MODES
    from repro.core.support import SUPPORT_MODES
    ap.add_argument("--mode", default="chunked", choices=list(PEEL_MODES))
    ap.add_argument("--support-mode", default="jnp",
                    choices=list(SUPPORT_MODES))
    ap.add_argument("--verify", action="store_true",
                    help="check against the numpy oracle (small graphs!)")
    args = ap.parse_args(argv)

    E = named_graph(args.graph)
    n = int(E.max()) + 1
    t0 = time.perf_counter()
    if args.order == "kco":
        E = relabel(E, degeneracy_order(E, n))
    g = build_csr(E, n)
    t_build = time.perf_counter() - t0
    print(f"graph={args.graph} n={g.n} m={g.m} wedges={g.wedge_count():.3e} "
          f"build {t_build:.2f}s order={args.order}")

    t0 = time.perf_counter()
    if args.engine == "pkt":
        res = pkt(g, chunk=args.chunk, mode=args.mode,
                  support_mode=args.support_mode)
        truss = res.trussness
        extra = f"levels={res.levels} sublevels={res.sublevels}"
    elif args.engine == "dist":
        truss = pkt_dist(g, chunk=min(args.chunk, 1 << 12),
                         support_mode=args.support_mode)
        extra = ""
    elif args.engine == "trilist":
        truss = truss_trilist(g)
        extra = ""
    elif args.engine == "wc":
        truss = truss_wc(g)
        extra = ""
    else:
        truss = truss_ros(g)
        extra = ""
    dt = time.perf_counter() - t0
    gweps = g.wedge_count() / max(dt, 1e-12) / 1e9

    tmax = int(truss.max(initial=2))
    hist = np.bincount(np.asarray(truss, np.int64))
    top = ", ".join(f"{k}:{hist[k]}" for k in np.nonzero(hist)[0][-5:])
    print(f"engine={args.engine} time {dt:.3f}s  GWeps {gweps:.4f}  "
          f"t_max {tmax}  {extra}")
    print(f"largest k-classes: {top}")

    if args.verify:
        ref = truss_numpy(g.El)
        ok = np.array_equal(np.asarray(truss, np.int64), ref)
        print("verify vs oracle:", "OK" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
