"""ModelConfig + composable decoder LM covering all assigned families.

One forward covers dense / MoE / SSM / hybrid / audio / vlm via config flags.
Layers are scanned with stacked params (small HLO even at 81 layers); remat is
configurable per block. Decode carries per-layer caches through the same scan.

Inputs (the ``batch`` dict):
  tokens      (B,S) int32          — lm families
  embeds      (B,S,D) bf16         — audio/vlm stub frontends (assignment)
  labels      (B,S) int32          — training
  positions   (B,S) or (B,S,3)     — optional (mrope needs the 3-tuple)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, attention, moe, ssm

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_version: int = 1
    ssd_head_p: int = 64
    # hybrid (zamba2): shared attention block applied every `attn_every` slots
    attn_every: int = 0
    # attention / misc
    qk_norm: bool = False
    rope: str = "rope"           # rope | mrope | sinusoidal | none
    rope_theta: float = 1e4
    norm: str = "rms"            # rms | layernorm | rms_nonparam
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    input_is_embeds: bool = False  # audio/vlm stub frontend
    # execution knobs (perf-iterated, not architecture)
    remat: str = "block"         # none | block
    kv_chunk: int = 1024
    ssm_q_chunk: int = 128
    capacity_factor: float = 1.25
    compute_dtype: str = "bfloat16"
    # cost-analysis mode: fully unroll every scan so compiled.cost_analysis()
    # sees all FLOPs (XLA counts a while-loop body exactly once — measured)
    unroll_scans: bool = False
    # sequence-parallel residual stream: PartitionSpec entries (as nested
    # tuples/strs/None) applied to block-boundary activations (B, S, D).
    # Megatron-SP: saved remat residuals shrink by the TP degree.
    act_pspec: tuple | None = None
    # flat-head GQA attention (shard H=Hkv·G q-heads instead of capping TP
    # at Hkv ways — see attention.blocked_attention); §Perf lever
    attn_flat_kv: bool = False
    # master parameter dtype: "float32" (fp32 master + bf16 compute casts)
    # or "bfloat16" (pure-bf16 params, fp32 optimizer moments); §Perf lever
    param_dtype: str = "float32"
    # serving shard policy (§Perf levers for decode cells):
    # seq-shard the long-context KV cache over data axes vs replicate it
    serve_seq_shard: bool = True
    # FSDP-shard serving weights over data (per-token gathers) vs TP-only
    serve_fsdp: bool = True

    @property
    def attn_qdim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic total parameter count (for 6ND roofline accounting)."""
        shapes = jax.eval_shape(lambda k: init_params(self, k),
                                jax.random.PRNGKey(0))
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts + shared)."""
        total = self.param_count()
        if self.family != "moe" or self.n_experts == 0:
            return total
        expert_p = self.n_layers * self.n_experts * self.d_model * self.d_ff \
            * (3 if self.act == "swiglu" else 2)
        active = total - expert_p + expert_p * self.top_k / self.n_experts
        return int(active)


# ----------------------------------------------------------------- init ----

def _norm_init(cfg) -> Params:
    if cfg.norm == "rms_nonparam":
        return {}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32)}


def _norm_apply(p: Params, x, cfg):
    if cfg.norm == "layernorm":
        return layers.layer_norm(x, p.get("scale"), p.get("bias"))
    return layers.rms_norm(x, p.get("scale"))


def _block_init(cfg: ModelConfig, key) -> Params:
    """One decoder block's params (unstacked)."""
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": _norm_init(cfg)}
    if cfg.family in ("ssm",):
        p["mamba"] = ssm.mamba1_init(k1, cfg.d_model, cfg.ssm_state,
                                     cfg.ssm_expand, cfg.ssm_conv)
        return p
    if cfg.family == "hybrid":
        p["mamba"] = ssm.mamba2_init(k1, cfg.d_model, cfg.ssm_state,
                                     cfg.ssm_expand, cfg.ssm_conv,
                                     cfg.ssd_head_p)
        return p
    p["attn"] = attention.attn_init(k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, cfg.qk_norm)
    p["norm2"] = _norm_init(cfg)
    if cfg.family == "moe":
        p["moe"] = moe.moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                cfg.act, cfg.shared_expert)
    else:
        p["mlp"] = layers.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kl, kh, ka = jax.random.split(key, 4)
    pdt = jnp.dtype(cfg.param_dtype)
    p: Params = {}
    p["embed"] = (jax.random.normal(
        ke, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(pdt)
    # stacked per-layer params for scan
    p["layers"] = jax.vmap(lambda k: _block_init(cfg, k))(
        jax.random.split(kl, cfg.n_layers))
    if cfg.family == "hybrid" and cfg.attn_every:
        p["shared_attn"] = {
            "norm": _norm_init(cfg),
            "attn": attention.attn_init(ka, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim,
                                        cfg.qk_norm),
        }
    p["final_norm"] = _norm_init(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(
            kh, (cfg.d_model, cfg.vocab), jnp.float32) * 0.02).astype(pdt)
    # stacked/attention/norm leaves follow the master dtype too
    if pdt != jnp.float32:
        for k in ("layers", "shared_attn"):
            if k in p:
                p[k] = jax.tree.map(lambda a: a.astype(pdt), p[k])
        p["final_norm"] = jax.tree.map(lambda a: a.astype(pdt),
                                       p["final_norm"])
    return p


# ---------------------------------------------------------------- cache ----

def n_attn_apps(cfg: ModelConfig) -> int:
    """How many attention applications exist (hybrid: shared-block count)."""
    if cfg.family in ("ssm",):
        return 0
    if cfg.family == "hybrid":
        return 0 if not cfg.attn_every else len(
            [i for i in range(cfg.n_layers)
             if i % cfg.attn_every == cfg.attn_every - 1])
    return cfg.n_layers


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Decode cache pytree: attention KV (+fill) and/or SSM states."""
    dtype = dtype or cfg.dtype
    cache: Params = {}
    na = n_attn_apps(cfg)
    if na:
        kvd = cfg.n_kv_heads
        cache["kv"] = {
            "k": jnp.zeros((na, batch, max_seq, kvd, cfg.head_dim), dtype),
            "v": jnp.zeros((na, batch, max_seq, kvd, cfg.head_dim), dtype),
            "fill": jnp.zeros((), jnp.int32),
        }
    if cfg.family in ("ssm", "hybrid"):
        conv, h = ssm.ssm_state_shapes(cfg, batch, cfg.ssd_head_p)
        cache["ssm"] = {
            "conv": jnp.zeros((cfg.n_layers,) + conv, dtype),
            "h": jnp.zeros((cfg.n_layers,) + h, jnp.float32),
        }
    return cache


# -------------------------------------------------------------- forward ----

def _constrain_act(x, cfg: ModelConfig):
    """Apply the configured residual-stream sharding constraint (SP)."""
    if cfg.act_pspec is None:
        return x
    from repro.models.moe import _in_mesh_context
    if not _in_mesh_context():
        return x
    spec = jax.sharding.PartitionSpec(*cfg.act_pspec)
    return jax.lax.with_sharding_constraint(x, spec)


def _gather_act(x, cfg: ModelConfig):
    """SP → replicated-sequence transition, placed explicitly on the bf16
    hidden states entering attention. Without this GSPMD floats the gather
    to the f32 RoPE/score intermediates inside attention — 3 gathers at 2×
    the bytes (measured; §Perf)."""
    if cfg.act_pspec is None:
        return x
    from repro.models.moe import _in_mesh_context
    if not _in_mesh_context():
        return x
    dp = cfg.act_pspec[0]
    spec = jax.sharding.PartitionSpec(dp, None, None)
    return jax.lax.with_sharding_constraint(x, spec)


def _attn_block(bp: Params, x, cfg, positions, kv_cache):
    h = _gather_act(_norm_apply(bp["norm1"], x, cfg), cfg)
    out, new_kv = attention.attn_apply(
        bp["attn"], h, cfg, positions=positions, cache=kv_cache,
        kv_chunk=cfg.kv_chunk)
    # constrain the row-parallel projection output to the SP spec *at the
    # psum source* so GSPMD emits reduce-scatter instead of all-reduce+slice
    x = x + _constrain_act(out, cfg)
    h = _norm_apply(bp["norm2"], x, cfg)
    if cfg.family == "moe":
        out, aux = moe.moe_apply(bp["moe"], h, cfg,
                                 capacity_factor=cfg.capacity_factor)
    else:
        out, aux = layers.mlp_apply(bp["mlp"], h, cfg.act), 0.0
    return x + _constrain_act(out, cfg), new_kv, aux


def _mamba_block(bp: Params, x, cfg, state):
    h = _norm_apply(bp["norm1"], x, cfg)
    fn = ssm.mamba1_apply if cfg.mamba_version == 1 else ssm.mamba2_apply
    kw = {} if cfg.mamba_version == 1 else {"head_p": cfg.ssd_head_p}
    out, new_state = fn(bp["mamba"], h, cfg, state=state,
                        q_chunk=cfg.ssm_q_chunk, **kw)
    return x + out, new_state


def forward(params: Params, cfg: ModelConfig, batch: dict, *,
            cache: Optional[Params] = None):
    """Returns (logits (B,S,V), aux dict with 'moe_aux', new cache or None)."""
    if cfg.input_is_embeds:
        x = batch["embeds"].astype(cfg.dtype)
        B, S = x.shape[0], x.shape[1]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens].astype(cfg.dtype)
    positions = batch.get("positions")
    fill0 = cache["kv"]["fill"] if (cache and "kv" in cache) else None
    if cfg.rope == "sinusoidal":
        off = 0 if fill0 is None else fill0
        pos_emb = layers.sinusoidal_positions(S, cfg.d_model, off)
        x = x + pos_emb[None].astype(cfg.dtype)

    decode = cache is not None
    unroll = True if cfg.unroll_scans else 1
    new_cache: Params = {} if decode else None
    moe_aux = jnp.zeros((), jnp.float32)

    def maybe_ckpt(f):
        return jax.checkpoint(f) if (cfg.remat == "block" and not decode) else f

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        if decode:
            fills = cache["kv"]["fill"]

            # full cache rides the carry; per-layer slices are read/written
            # with dynamic_index/update — in-place friendly for XLA buffer
            # assignment (a stacked-ys formulation costs ~2× cache in temp)
            def body2(carry, xs_):
                x, aux, ks, vs = carry
                x = _constrain_act(x, cfg)
                bp, i = xs_
                k_l = jax.lax.dynamic_index_in_dim(ks, i, keepdims=False)
                v_l = jax.lax.dynamic_index_in_dim(vs, i, keepdims=False)
                x, new_kv, a = _attn_block(bp, x, cfg, positions,
                                           (k_l, v_l, fills))
                ks = jax.lax.dynamic_update_index_in_dim(ks, new_kv[0], i, 0)
                vs = jax.lax.dynamic_update_index_in_dim(vs, new_kv[1], i, 0)
                return (x, aux + a, ks, vs), None
            (x, moe_aux, nk, nv), _ = jax.lax.scan(
                body2, (x, moe_aux, cache["kv"]["k"], cache["kv"]["v"]),
                (params["layers"], jnp.arange(cfg.n_layers)),
                unroll=unroll)
            new_cache["kv"] = {"k": nk, "v": nv, "fill": fills + S}
        else:
            def body3(carry, bp):
                x, aux = carry
                x = _constrain_act(x, cfg)
                x, _, a = _attn_block(bp, x, cfg, positions, None)
                return (x, aux + a), None
            (x, moe_aux), _ = jax.lax.scan(
                maybe_ckpt(body3), (x, moe_aux), params["layers"],
                unroll=unroll)

    elif cfg.family == "ssm":
        if decode:
            def body4(x, xs_):
                x = _constrain_act(x, cfg)
                bp, (conv_l, h_l) = xs_
                x, st = _mamba_block(bp, x, cfg, (conv_l, h_l))
                return x, st
            x, (ncv, nh) = jax.lax.scan(
                body4, x, (params["layers"],
                           (cache["ssm"]["conv"], cache["ssm"]["h"])),
                unroll=unroll)
            new_cache["ssm"] = {"conv": ncv, "h": nh}
        else:
            def body5(x, bp):
                x = _constrain_act(x, cfg)
                x, _ = _mamba_block(bp, x, cfg, None)
                return x, None
            x, _ = jax.lax.scan(maybe_ckpt(body5), x, params["layers"],
                                unroll=unroll)

    elif cfg.family == "hybrid":
        period = cfg.attn_every
        sap = params.get("shared_attn")

        def shared_attn_apply(x, j, kv_all, fills):
            """The shared attention block at application slot j."""
            h = _norm_apply(sap["norm"], x, cfg)
            kv = None
            if kv_all is not None:
                k_j = jax.lax.dynamic_index_in_dim(kv_all[0], j,
                                                   keepdims=False)
                v_j = jax.lax.dynamic_index_in_dim(kv_all[1], j,
                                                   keepdims=False)
                kv = (k_j, v_j, fills)
            out, new_kv = attention.attn_apply(
                sap["attn"], h, cfg, positions=positions, cache=kv,
                kv_chunk=cfg.kv_chunk)
            if kv_all is not None:
                kv_all = (
                    jax.lax.dynamic_update_index_in_dim(
                        kv_all[0], new_kv[0], j, 0),
                    jax.lax.dynamic_update_index_in_dim(
                        kv_all[1], new_kv[1], j, 0))
            return x + out, kv_all

        if cfg.unroll_scans:
            # literal python loop: no lax.cond, so HLO cost analysis sees
            # exactly the 13 real shared-attn applications, not both branches
            # of all n_layers conds (6× memory-term overcount measured)
            if decode:
                kv_all = (cache["kv"]["k"], cache["kv"]["v"])
                fills = cache["kv"]["fill"]
            else:
                kv_all, fills = None, None
            new_conv, new_h = [], []
            for i in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[i], params["layers"])
                st = None
                if decode:
                    st = (cache["ssm"]["conv"][i], cache["ssm"]["h"][i])
                x = _constrain_act(x, cfg)
                x, st_out = _mamba_block(bp, x, cfg, st)
                if decode:
                    new_conv.append(st_out[0])
                    new_h.append(st_out[1])
                if sap is not None and i % period == period - 1:
                    x, kv_all = shared_attn_apply(x, i // period, kv_all,
                                                  fills)
            if decode:
                new_cache["ssm"] = {"conv": jnp.stack(new_conv),
                                    "h": jnp.stack(new_h)}
                new_cache["kv"] = {"k": kv_all[0], "v": kv_all[1],
                                   "fill": fills + S}
            x = _finish_lm(params, cfg, x)
            return x, {"moe_aux": moe_aux}, new_cache

        def hybrid_step(x, bp, idx, ssm_st, kv_all, fills):
            x, new_st = _mamba_block(bp, x, cfg, ssm_st)
            if sap is not None:
                j = idx // period
                use = (idx % period) == (period - 1)

                def do_attn(op):
                    x, kv_all = op
                    h = _norm_apply(sap["norm"], x, cfg)
                    kv = None
                    if kv_all is not None:
                        k_j = jax.lax.dynamic_index_in_dim(
                            kv_all[0], j, keepdims=False)
                        v_j = jax.lax.dynamic_index_in_dim(
                            kv_all[1], j, keepdims=False)
                        kv = (k_j, v_j, fills)
                    out, new_kv = attention.attn_apply(
                        sap["attn"], h, cfg, positions=positions, cache=kv,
                        kv_chunk=cfg.kv_chunk)
                    if kv_all is not None:
                        kv_all = (
                            jax.lax.dynamic_update_index_in_dim(
                                kv_all[0], new_kv[0], j, 0),
                            jax.lax.dynamic_update_index_in_dim(
                                kv_all[1], new_kv[1], j, 0))
                    return (x + out, kv_all)

                x, kv_all = jax.lax.cond(use, do_attn, lambda op: op,
                                         (x, kv_all))
            return x, new_st, kv_all

        if decode:
            kv_all = (cache["kv"]["k"], cache["kv"]["v"])
            fills = cache["kv"]["fill"]

            def body6(carry, xs_):
                x, kv_all = carry
                x = _constrain_act(x, cfg)
                bp, (conv_l, h_l), idx = xs_
                x, st, kv_all = hybrid_step(x, bp, idx, (conv_l, h_l),
                                            kv_all, fills)
                return (x, kv_all), st
            (x, kv_all), (ncv, nh) = jax.lax.scan(
                body6, (x, kv_all),
                (params["layers"],
                 (cache["ssm"]["conv"], cache["ssm"]["h"]),
                 jnp.arange(cfg.n_layers)), unroll=unroll)
            new_cache["ssm"] = {"conv": ncv, "h": nh}
            new_cache["kv"] = {"k": kv_all[0], "v": kv_all[1],
                               "fill": fills + S}
        else:
            def body7(x, xs_):
                x = _constrain_act(x, cfg)
                bp, idx = xs_
                x, _, _ = hybrid_step(x, bp, idx, None, None, None)
                return x, None
            x, _ = jax.lax.scan(maybe_ckpt(body7), x,
                                (params["layers"],
                                 jnp.arange(cfg.n_layers)), unroll=unroll)
    else:
        raise ValueError(cfg.family)

    logits = _finish_lm(params, cfg, x)
    return logits, {"moe_aux": moe_aux}, new_cache


def _finish_lm(params: Params, cfg: ModelConfig, x):
    x = _constrain_act(x, cfg)
    x = _norm_apply(params["final_norm"], x, cfg)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    return jnp.einsum("bsd,dv->bsv", x, head)


def decode_step(params: Params, cfg: ModelConfig, tokens_or_embeds,
                cache: Params, positions=None):
    """One decode step (S new tokens, usually 1). Returns (logits, cache)."""
    if cfg.input_is_embeds:
        batch = {"embeds": tokens_or_embeds}
    else:
        batch = {"tokens": tokens_or_embeds}
    if positions is not None:
        batch["positions"] = positions
    logits, _, new_cache = forward(params, cfg, batch, cache=cache)
    return logits, new_cache
