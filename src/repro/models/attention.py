"""Attention: GQA/MQA/MHA with optional qk-norm, blocked online-softmax.

``blocked_attention`` is a pure-XLA flash-style attention: a lax.scan over KV
chunks carrying (running max, denominator, accumulator). Peak memory is
O(Sq * kv_chunk) per head group instead of O(Sq * Skv) — this is what makes
prefill_32k and the 500k-cache decode lowerable at production shapes. It is
deliberately *not* a Pallas kernel so that compiled cost_analysis keeps seeing
the real FLOPs (see kernels/__init__.py).

KV heads are kept un-repeated: q is reshaped to (B, S, Hkv, G, Dh) and all
einsums contract against (B, C, Hkv, Dh) — GQA without materializing the
G-fold KV copy.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers

Params = dict[str, Any]

_NEG_INF = -1e30


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d_model)
    so = 1.0 / np.sqrt(n_heads * head_dim)
    p = {
        "wq": jax.random.normal(kq, (d_model, n_heads * head_dim), dtype) * s,
        "wk": jax.random.normal(kk, (d_model, n_kv * head_dim), dtype) * s,
        "wv": jax.random.normal(kv, (d_model, n_kv * head_dim), dtype) * s,
        "wo": jax.random.normal(ko, (n_heads * head_dim, d_model), dtype) * so,
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def blocked_attention(q, k, v, *, causal: bool, q_offset, kv_chunk: int,
                      kv_len=None, unroll=1, flat_kv: bool = False):
    """q: (B,Sq,Hkv,G,Dh); k,v: (B,Skv,Hkv,Dh). Returns (B,Sq,Hkv,G,Dh).

    q_offset: scalar (may be traced) — absolute position of q[0] for causal
    masking against absolute KV positions. kv_len: optional scalar — number of
    valid KV entries (cache fill level).

    flat_kv: run the einsums with a single flat head dim H = Hkv·G and KV
    logically repeated G-fold. The (Hkv, G) split caps TP sharding of
    attention at Hkv ways — on a 16-way model axis with 8 KV heads GSPMD
    falls back to partial replication with f32 partial-sum all-reduces
    (measured: the dominant collective in train cells). Flat heads shard
    H-ways; the repeat is local per shard. Use when H % TP == 0.
    """
    B, Sq, Hkv, G, Dh = q.shape
    Skv = k.shape[1]
    if flat_kv and G > 1:
        q_f = q.reshape(B, Sq, Hkv * G, Dh)
        k_f = jnp.repeat(k, G, axis=2)
        v_f = jnp.repeat(v, G, axis=2)
        out = blocked_attention(
            q_f[:, :, :, None, :], k_f, v_f, causal=causal,
            q_offset=q_offset, kv_chunk=kv_chunk, kv_len=kv_len,
            unroll=unroll, flat_kv=False)
        return out.reshape(B, Sq, Hkv, G, Dh)
    scale = 1.0 / np.sqrt(Dh)
    C = min(kv_chunk, Skv)
    n_chunks = -(-Skv // C)
    pad = n_chunks * C - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # KV chunks are sliced *by index* in the scan body — no transposed copy
    # of the cache — and fed to the MXU in their native dtype (bf16×bf16→f32
    # accumulate); converting a 500k-token cache to f32 per step would
    # triple the decode memory term (measured — see EXPERIMENTS.md §Perf).
    q_in = (q * scale).astype(q.dtype)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, c_idx):
        m, l, acc = carry
        k_i = jax.lax.dynamic_slice_in_dim(k, c_idx * C, C, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(v, c_idx * C, C, axis=1)
        kpos = c_idx * C + jnp.arange(C)
        s = jnp.einsum("bqhgd,bchd->bqhgc", q_in, k_i,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((Sq, C), bool)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if kv_len is not None:
            mask = mask & (kpos[None, :] < kv_len)
        if pad:
            mask = mask & (kpos[None, :] < Skv)
        s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgc,bchd->bqhgd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), jnp.arange(n_chunks), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def attn_apply(p: Params, x: jnp.ndarray, cfg, *, positions=None,
               cache=None, cache_index=None, kv_chunk: int = 1024):
    """Self-attention. Without cache: causal over x (train/prefill; returns
    (out, new_kv) where new_kv is the (k, v) to seed a cache). With cache
    (k, v, fill): single/few-token decode against the cache.

    x: (B, S, D); positions: (B, S) absolute ids or (B, S, 3) for mrope.
    """
    B, S, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    xb = x
    q = jnp.einsum("bsd,de->bse", xb, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xb, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xb, p["wv"].astype(x.dtype))
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)

    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])

    if positions is None:
        base = 0 if cache is None else cache[2]
        positions = base + jnp.arange(S)[None, :]

    if cfg.rope == "rope":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = layers.apply_mrope(q, positions, cfg.rope_theta)
        k = layers.apply_mrope(k, positions, cfg.rope_theta)
    # "none"/"sinusoidal": positions handled at the embedding level

    qg = q.reshape(B, S, Hkv, G, Dh)
    unroll = True if getattr(cfg, 'unroll_scans', False) else 1

    flat_kv = bool(getattr(cfg, "attn_flat_kv", False))
    if cache is None:
        out = blocked_attention(qg, k, v, causal=True, q_offset=0,
                                kv_chunk=kv_chunk, unroll=unroll,
                                flat_kv=flat_kv)
        new_kv = (k, v)
    else:
        ck, cv, fill = cache
        # write the new kv at [fill, fill+S)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, fill, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, fill, 0, 0))
        # causal w.r.t. absolute positions: correct for multi-token prefill
        # and reduces to "see everything ≤ fill" for single-token decode
        out = blocked_attention(qg, ck, cv, causal=True, q_offset=fill,
                                kv_chunk=kv_chunk, kv_len=fill + S,
                                unroll=unroll, flat_kv=flat_kv)
        new_kv = (ck, cv, fill + S)

    out = out.reshape(B, S, H * Dh)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    return out, new_kv
