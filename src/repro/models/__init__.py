"""LM substrate: layers, attention, MoE, SSM, and the composable model."""

from repro.models.model import ModelConfig, init_params, forward, init_cache, decode_step

__all__ = ["ModelConfig", "init_params", "forward", "init_cache", "decode_step"]
