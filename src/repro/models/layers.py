"""Shared neural layers: norms, rotary embeddings, MLPs, embeddings.

Everything is a pure function over explicit param pytrees (dicts of jnp
arrays) so that jax.eval_shape / jit.lower work without any framework magic.
Compute dtype is bf16 by default with fp32 params and fp32 norm/softmax
accumulation (the production-standard mixed-precision recipe).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


# ---------------------------------------------------------------- norms ----

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray | None, eps: float = 1e-6):
    """RMSNorm; ``scale=None`` gives OLMo-style non-parametric normalization."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray | None,
               bias: jnp.ndarray | None, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


# ----------------------------------------------------------------- RoPE ----

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                            # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE: head_dim/2 freq slots split into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (B, S, H, Dh); positions3: (B, S, 3) int32.
    ``sections`` entries sum to Dh/2 (scaled automatically if not).
    """
    dh = x.shape[-1]
    half = dh // 2
    sec = np.asarray(sections, np.int64)
    if sec.sum() != half:
        sec = np.maximum(1, sec * half // max(1, int(sec.sum())))
        sec[-1] = half - sec[:-1].sum()
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)        # (half,)
    # pick the position id for each frequency slot by section
    sec_id = jnp.asarray(np.repeat(np.arange(3), sec), jnp.int32)  # (half,)
    pos = positions3.astype(jnp.float32)[..., sec_id]              # (B,S,half)
    angles = pos * freqs[None, None, :]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int, offset=0) -> jnp.ndarray:
    """MusicGen-style fixed sinusoidal position embeddings (S, D).
    ``offset`` may be a traced scalar (decode fill level)."""
    pos = (jnp.arange(seq, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (np.log(10000.0) / d_model))
    ang = pos * inv
    emb = jnp.zeros((seq, d_model), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(ang))
    emb = emb.at[:, 1::2].set(jnp.cos(ang))
    return emb


# ------------------------------------------------------------------ MLP ----

def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    """Gated (SwiGLU) or plain (GeLU) MLP. Params: wi/(wg)/wo."""
    if act == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif act == "gelu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    p = {
        "wi": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "wo": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out,
    }
    if act == "swiglu":
        p["wg"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p
