"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch.

Dispatch is *group-local sort* (MegaBlocks-lite): tokens are grouped by the
batch dim (which is data-sharded), sorted by assigned expert inside each
group, clamped to a per-group capacity, gathered into (B, E, C, D) expert
batches, and pushed through per-expert matmuls. Under GSPMD the
(tokens: data-sharded) → (experts: model-sharded) regroup lowers to an
all-to-all — exactly the EP communication pattern we want the dry-run to
surface (and the roofline to price).

A capacity-dropped token contributes nothing (its combine weight is zero) —
standard Switch/GShard semantics. Router aux loss (load-balancing) is
returned for the train loss.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


def moe_init(key, d_model: int, d_ff: int, n_experts: int, act: str,
             shared_expert: bool, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    p: Params = {
        "router": jax.random.normal(ks[0], (d_model, n_experts), dtype) * s_in,
        "w1": jax.random.normal(ks[1], (n_experts, d_model, d_ff), dtype) * s_in,
        "w2": jax.random.normal(ks[2], (n_experts, d_ff, d_model), dtype) * s_out,
    }
    if act == "swiglu":
        p["wg"] = jax.random.normal(ks[3], (n_experts, d_model, d_ff),
                                    dtype) * s_in
    if shared_expert:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], d_model, d_ff, act, dtype)
    return p


def moe_apply(p: Params, x: jnp.ndarray, cfg, *, capacity_factor: float = 1.25):
    """x: (B, S, D) → (out (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = int(np.ceil(S * K / E * capacity_factor))
    C = max(8, -(-C // 8) * 8)  # pad capacity to a lane-friendly multiple

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * Σ_e mean(frac_tokens_e)·mean(prob_e)
    # (scatter-add bincount — no (B,S,E) one-hot materialization)
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[
        expert_idx[..., 0].reshape(-1)].add(1.0) / (B * S)
    aux = E * jnp.sum(me * ce)

    # ---- group-local sort dispatch (group = batch row) ----
    SK = S * K
    e_flat = expert_idx.reshape(B, SK)                        # (B, SK)
    g_flat = gate_vals.reshape(B, SK).astype(jnp.float32)
    tok = jnp.broadcast_to(jnp.arange(S)[:, None], (S, K)).reshape(SK)
    tok = jnp.broadcast_to(tok[None], (B, SK))                # (B, SK)

    order = jnp.argsort(e_flat, axis=1, stable=True)          # (B, SK)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    tok_sorted = jnp.take_along_axis(tok, order, axis=1)
    g_sorted = jnp.take_along_axis(g_flat, order, axis=1)

    # per-expert start offsets from the sorted ids (no (B,SK,E) one-hot)
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E)))(e_sorted)
    rank = jnp.arange(SK)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=1)
    keep = rank < C
    slot = jnp.where(keep, e_sorted * C + rank, E * C)        # (B, SK)

    # invert: expert slot -> source token (sentinel S = zero row)
    src = jnp.full((B, E * C + 1), S, jnp.int32)
    src = jax.vmap(lambda s_, sl_, t_: s_.at[sl_].set(
        jnp.where(sl_ < E * C, t_, S).astype(jnp.int32)))(src, slot, tok_sorted)
    src = src[:, : E * C]

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    xe = jnp.take_along_axis(x_pad, src[..., None], axis=1)   # (B, E*C, D)
    xe = xe.reshape(B, E, C, D)
    if _in_mesh_context():
        # EP regroup: tokens (data-sharded) → experts (model-sharded); the
        # batch axes come from the configured activation spec so the pod
        # axis is respected on multi-pod meshes
        dp = cfg.act_pspec[0] if getattr(cfg, "act_pspec", None) else "data"
        xe = jax.lax.with_sharding_constraint(
            xe, jax.sharding.PartitionSpec(dp, "model", None, None))

    h = jnp.einsum("becd,edf->becf", xe, p["w1"].astype(x.dtype))
    if "wg" in p:
        g = jnp.einsum("becd,edf->becf", xe, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("becf,efd->becd", h, p["w2"].astype(x.dtype))
    y_flat = ye.reshape(B, E * C, D)

    # combine: each kept (token copy) adds gate * y[slot] at its token
    y_pad = jnp.concatenate([y_flat, jnp.zeros((B, 1, D), y_flat.dtype)],
                            axis=1)
    safe_slot = jnp.minimum(slot, E * C)
    y_sorted = jnp.take_along_axis(y_pad, safe_slot[..., None], axis=1)
    w = (g_sorted * keep.astype(jnp.float32))[..., None]
    contrib = (y_sorted.astype(jnp.float32) * w).astype(x.dtype)
    out = jnp.zeros((B, S, D), x.dtype)
    out = jax.vmap(lambda o, t, c: o.at[t].add(c))(out, tok_sorted, contrib)

    if "shared" in p:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(p["shared"], x, cfg.act)
    return out, aux.astype(jnp.float32)


def _in_mesh_context() -> bool:
    """True when called under an active mesh (so constraints are legal)."""
    try:
        from jax._src import mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        return not env_mesh.empty
    except Exception:
        return False
