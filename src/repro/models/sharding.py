"""Sharding rules: param/activation PartitionSpecs for the production mesh.

Axes (DESIGN.md §5): "data" = DP/FSDP, "model" = TP/EP, optional leading
"pod" = cross-pod DP. Strategy:

  * TP over "model" on the flattened head dims (q_dim / kv_dim / d_ff /
    vocab) — divisibility by 16 holds for every assigned arch on the flat
    dims even when head counts (40, 24, 9, 12) do not divide 16;
  * FSDP over ("pod","data") on the other large dim of each ≥2-D param
    (ZeRO-3-style; XLA inserts the pipelined all-gathers around the scan);
  * activations: batch over ("pod","data");
  * MoE experts over "model" (EP, 1 expert/shard at E=16);
  * SSM: TP over d_inner-derived dims, scan stays local.

`param_specs` walks the param pytree by path; `batch_specs` shards inputs.
"""

from __future__ import annotations

from typing import Any

from jax.sharding import PartitionSpec as P

from repro.models.model import ModelConfig


def _fsdp_axes(mesh_axes) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def _leaf_spec(path: str, shape: tuple, cfg: ModelConfig, fsdp, *,
               fsdp_enabled: bool = True) -> P:
    """Spec for one param leaf. ``path`` is '/'-joined key names (no layer
    index — stacked leaves get their leading L dim unsharded)."""
    f = fsdp if fsdp_enabled else ()
    nd = len(shape)

    def spec(*dims):
        # pad with None for any leading stacked-layer dim
        return P(*([None] * (nd - len(dims)) + list(dims)))

    if "embed" in path:
        # vocab-parallel: lookup lowers to masked-gather + psum, and the tied
        # LM head yields vocab-sharded logits (keeps CE transients 1/TP)
        return P("model", None)
    if "lm_head" in path:
        return P(f or None, "model")
    if "router" in path:
        return spec(f or None, None)
    if path.endswith(("moe/w1", "moe/wg")):
        return spec("model", f or None, None)       # (E, D, F): EP
    if path.endswith("moe/w2"):
        return spec("model", None, f or None)       # (E, F, D): EP
    if "attn" in path and path.endswith(("wq", "wk", "wv")):
        return spec(f or None, "model")
    if "attn" in path and path.endswith("wo"):
        return spec("model", f or None)
    if path.endswith(("mlp/wi", "mlp/wg", "shared/wi", "shared/wg")):
        return spec(f or None, "model")
    if path.endswith(("mlp/wo", "shared/wo")):
        return spec("model", f or None)
    if path.endswith(("mamba/in_proj", "mamba/x_proj")):
        return spec(f or None, "model")
    if path.endswith(("mamba/out_proj", "mamba/dt_proj")):
        return spec("model", f or None)
    if path.endswith("mamba/A_log") and cfg.mamba_version == 1:
        return spec("model", None)                  # (DI, N) mamba1
    if path.endswith(("mamba/conv_w", "mamba/conv_b")) and nd >= 1:
        return spec("model")                        # channel dim last
    if path.endswith(("mamba/D", "mamba/dt_bias", "mamba/A_log",
                      "mamba/norm_scale")):
        return spec("model") if nd >= 1 and shape[-1] % 16 == 0 else spec(None)
    # norms, small vectors: replicated
    return P(*([None] * nd))


def param_specs(cfg: ModelConfig, params_shape: Any, mesh_axes,
                *, fsdp_enabled: bool = True) -> Any:
    """PartitionSpec pytree matching params (works on shapes or arrays)."""
    fsdp = _fsdp_axes(mesh_axes)
    fsdp = fsdp if len(fsdp) > 0 else ()

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        shape = tuple(tree.shape)
        return _leaf_spec(prefix, shape, cfg, fsdp,
                          fsdp_enabled=fsdp_enabled)

    return walk(params_shape, "")


def batch_specs(cfg: ModelConfig, batch_shape: dict, mesh_axes,
                mesh_shape: dict | None = None) -> dict:
    """Inputs: batch dim over (pod, data); replicate if not divisible
    (long_500k has global_batch=1)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    out = {}
    for k, v in batch_shape.items():
        nd = len(v.shape)
        spec_dp = dp
        if mesh_shape is not None:
            n_dp = 1
            for a in dp:
                n_dp *= mesh_shape[a]
            if v.shape[0] % max(n_dp, 1) != 0:
                spec_dp = None
        out[k] = P(spec_dp, *([None] * (nd - 1)))
    return out


def cache_specs(cfg: ModelConfig, cache_shape: Any, mesh_axes,
                *, seq_shard: bool = False,
                mesh_shape: dict | None = None) -> Any:
    """Decode-cache specs: batch over data axes, kv/state channels over model.

    seq_shard=True shards the KV cache *sequence* dim over the data axes
    instead of batch (long-context, batch=1 — the long_500k cells).
    seq_shard=False with batch==1 replicates the cache over the data axes
    (it fits — and keeps decode attention collective-free on that axis).
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    n_dp = 1
    for a in dp:
        n_dp *= (mesh_shape or {}).get(a, 1)

    def bdp(batch_size: int):
        """data axes for a batch dim, or None if not divisible."""
        if mesh_shape is not None and batch_size % max(n_dp, 1) != 0:
            return None
        return dp

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        nd = len(tree.shape)
        if prefix.endswith(("kv/k", "kv/v")):
            # (L, B, S, Hkv, Dh): head_dim over "model" (always divisible —
            # kv-head counts are not), batch or seq over the data axes
            if seq_shard:
                return P(None, None, dp, None, "model")
            return P(None, bdp(tree.shape[1]), None, None, "model")
        if prefix.endswith("kv/fill"):
            return P()
        if prefix.endswith("ssm/conv"):
            # (L, B, Kw-1, C)
            return P(None, bdp(tree.shape[1]), None, "model") \
                if not seq_shard else P(None, None, None, "model")
        if prefix.endswith("ssm/h"):
            # mamba1 (L,B,DI,N) / mamba2 (L,B,NH,P,N)
            base = [None] * nd
            base[1] = bdp(tree.shape[1]) if not seq_shard else None
            if nd >= 3:
                base[2] = "model"
            return P(*base)
        return P(*([None] * nd))

    return walk(cache_shape, "")


def logical_out_spec(mesh_axes) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    return P(dp, None, "model")
