"""State-space blocks: Mamba1 (selective scan) and Mamba2 (SSD, chunked).

Mamba1 (falcon-mamba): diagonal A (d_inner, d_state); the recurrence is
inherently elementwise (VPU-bound on TPU — no MXU structure exists; this is
the honest hardware story and partly why Mamba2 reformulates it). We scan
over sequence *chunks* with rematerialized inner position scans so backward
memory is O(S/Q · state) instead of O(S · state).

Mamba2 (zamba2): scalar-per-head A enables the SSD chunked algorithm —
intra-chunk work becomes attention-like matmuls (MXU-friendly) and
inter-chunk work a short scan over chunk states.

Both expose decode_step-compatible single-token recurrences with carried
(conv_state, ssm_state).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


# -------------------------------------------------------------- helpers ----

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None,
                  state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: (B,S,C), w: (Kw,C). state: (B,Kw-1,C) tail of
    previous inputs (decode). Returns (y, new_state)."""
    Kw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (Kw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(Kw))
    if b is not None:
        y = y + b[None, None, :]
    new_state = xp[:, -(Kw - 1):, :] if Kw > 1 else jnp.zeros_like(x[:, :0])
    return y, new_state


# --------------------------------------------------------------- Mamba1 ----

def mamba1_init(key, d_model: int, d_state: int, expand: int, d_conv: int,
                dtype=jnp.float32) -> Params:
    di = expand * d_model
    dt_rank = max(1, -(-d_model // 16))
    ks = jax.random.split(key, 7)
    s = 1.0 / np.sqrt(d_model)
    si = 1.0 / np.sqrt(di)
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (d_conv, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, dt_rank + 2 * d_state),
                                    dtype) * si,
        "dt_proj": jax.random.normal(ks[3], (dt_rank, di), dtype)
        * (1.0 / np.sqrt(dt_rank)),
        "dt_bias": jnp.zeros((di,), dtype) + np.log(np.expm1(0.01)),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=dtype), (di, d_state))),
        "D": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[4], (di, d_model), dtype) * si,
    }


def _mamba1_scan(xc, dt, Bm, Cm, A, h0, q_chunk: int, unroll=1):
    """Selective scan. xc,dt: (B,S,DI); Bm,Cm: (B,S,N); A: (DI,N); h0: (B,DI,N).
    Returns (y (B,S,DI), h_final).

    Within a chunk the diagonal recurrence h_t = d_t·h_{t-1} + u_t runs as a
    log-depth ``associative_scan`` (TPU-friendly: wide elementwise ops, no
    position-wise while loop — and visible to HLO cost analysis); chunks are
    chained by a short lax.scan carrying the boundary state.
    """
    B, S, DI = xc.shape
    Q = min(q_chunk, S)
    nq = -(-S // Q)
    pad = nq * Q - S
    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    def chunk_body(h, inp):
        xq, dtq, bq, cq = inp          # (B,Q,DI) / (B,Q,N)
        d = jnp.exp(dtq[..., None] * A[None, None])            # (B,Q,DI,N)
        u = (dtq * xq)[..., None] * bq[:, :, None, :]          # (B,Q,DI,N)

        def comb(a, b):
            d1, u1 = a
            d2, u2 = b
            return d1 * d2, d2 * u1 + u2

        dcum, ucum = jax.lax.associative_scan(comb, (d, u), axis=1)
        hs = dcum * h[:, None] + ucum                          # (B,Q,DI,N)
        yq = jnp.einsum("bqdn,bqn->bqd", hs, cq)
        return hs[:, -1], yq

    chunk_body = jax.checkpoint(chunk_body)
    xs = tuple(a.reshape(B, nq, Q, -1).transpose(1, 0, 2, 3)
               for a in (xc, dt, Bm, Cm))
    h, ys = jax.lax.scan(chunk_body, h0.astype(jnp.float32), xs,
                         unroll=unroll)
    y = ys.transpose(1, 0, 2, 3).reshape(B, nq * Q, DI)
    return y[:, :S], h


def mamba1_apply(p: Params, x: jnp.ndarray, cfg, *, state=None,
                 q_chunk: int = 64, unroll=1):
    """x: (B,S,D). state: (conv_state, h) for decode or None for train.
    Returns (out, new_state)."""
    B, S, D = x.shape
    di = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    dt_rank = max(1, -(-cfg.d_model // 16))

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state[0]
    xs, new_conv = causal_conv1d(xs, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    xs = jax.nn.silu(xs)

    proj = jnp.einsum("bse,ef->bsf", xs, p["x_proj"].astype(x.dtype))
    dt_in = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + N].astype(jnp.float32)
    Cm = proj[..., dt_rank + N:].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, p["dt_proj"].astype(x.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    h0 = (jnp.zeros((B, di, N), jnp.float32) if state is None
          else state[1].astype(jnp.float32))
    y, h = _mamba1_scan(xs.astype(jnp.float32), dt, Bm, Cm, A, h0, q_chunk,
                        unroll)
    y = y + p["D"].astype(jnp.float32)[None, None] * xs.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, (new_conv, h.astype(jnp.float32))


# --------------------------------------------------------------- Mamba2 ----

def mamba2_init(key, d_model: int, d_state: int, expand: int, d_conv: int,
                head_p: int = 64, dtype=jnp.float32) -> Params:
    di = expand * d_model
    nh = di // head_p
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(d_model)
    si = 1.0 / np.sqrt(di)
    # in_proj emits [x (di), z (di), B (N), C (N), dt (nh)]
    return {
        "in_proj": jax.random.normal(
            ks[0], (d_model, 2 * di + 2 * d_state + nh), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (d_conv, di + 2 * d_state),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((di + 2 * d_state,), dtype),
        "A_log": jnp.zeros((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype) + np.log(np.expm1(0.05)),
        "D": jnp.ones((nh,), dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[2], (di, d_model), dtype) * si,
    }


def _ssd_chunked(xh, dt, A, Bm, Cm, h0, q_chunk: int, unroll=1):
    """SSD. xh: (B,S,NH,P); dt: (B,S,NH); A: (NH,); Bm,Cm: (B,S,N);
    h0: (B,NH,P,N). Returns (y (B,S,NH,P), h_final)."""
    B, S, NH, P = xh.shape
    N = Bm.shape[-1]
    Q = min(q_chunk, S)
    nq = -(-S // Q)
    pad = nq * Q - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    # chunked views: (nq, B, Q, ...)
    xq = xh.reshape(B, nq, Q, NH, P).transpose(1, 0, 2, 3, 4)
    dtq = dt.reshape(B, nq, Q, NH).transpose(1, 0, 2, 3)
    bq = Bm.reshape(B, nq, Q, N).transpose(1, 0, 2, 3)
    cq = Cm.reshape(B, nq, Q, N).transpose(1, 0, 2, 3)

    def chunk_body(h, inp):
        xq_, dtq_, bq_, cq_ = inp
        da = dtq_ * A[None, None, :]                  # (B,Q,NH) negative
        cum = jnp.cumsum(da, axis=1)                  # (B,Q,NH)
        # intra-chunk: attention-like lower-triangular
        rel = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Qi,Qj,NH)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        cb = jnp.einsum("bin,bjn->bij", cq_, bq_)      # (B,Qi,Qj)
        w = cb[..., None] * L * dtq_[:, None, :, :]    # (B,Qi,Qj,NH)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xq_)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp",
                             cq_, h, jnp.exp(cum))
        # new chunk state
        tail = jnp.exp(cum[:, -1:, :] - cum)           # (B,Q,NH)
        h_new = jnp.einsum("bjn,bjhp,bjh->bhpn",
                           bq_, xq_, tail * dtq_)
        h = jnp.exp(da.sum(axis=1))[:, :, None, None] * h + h_new
        return h, y_intra + y_inter

    chunk_body = jax.checkpoint(chunk_body)
    h, ys = jax.lax.scan(chunk_body, h0.astype(jnp.float32),
                         (xq.astype(jnp.float32), dtq.astype(jnp.float32),
                          bq.astype(jnp.float32), cq.astype(jnp.float32)),
                         unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nq * Q, NH, P)
    return y[:, :S], h


def mamba2_apply(p: Params, x: jnp.ndarray, cfg, *, state=None,
                 q_chunk: int = 256, head_p: int = 64, unroll=1):
    """Mamba2/SSD block. x: (B,S,D); state: (conv_state, h) or None."""
    B, S, D = x.shape
    di = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    NH = di // head_p

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt_in = zxbcdt[..., di + di + 2 * N:]
    conv_state = None if state is None else state[0]
    xbc, new_conv = causal_conv1d(xbc, p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype), conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di]
    Bm = xbc[..., di:di + N].astype(jnp.float32)
    Cm = xbc[..., di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(B, S, NH, head_p)
    h0 = (jnp.zeros((B, NH, head_p, N), jnp.float32) if state is None
          else state[1].astype(jnp.float32))
    y, h = _ssd_chunked(xh, dt, A, Bm, Cm, h0, q_chunk, unroll)
    y = y + (p["D"].astype(jnp.float32)[None, None, :, None]
             * xh.astype(jnp.float32))
    y = y.reshape(B, S, di).astype(x.dtype)
    # gated RMSNorm (Mamba2)
    from repro.models.layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, (new_conv, h.astype(jnp.float32))


def ssm_state_shapes(cfg, batch: int, head_p: int = 64):
    """Decode-cache shapes per layer for a given config family."""
    di = cfg.ssm_expand * cfg.d_model
    if cfg.mamba_version == 1:
        conv = (batch, cfg.ssm_conv - 1, di)
        h = (batch, di, cfg.ssm_state)
    else:
        conv = (batch, cfg.ssm_conv - 1, di + 2 * cfg.ssm_state)
        h = (batch, di // head_p, head_p, cfg.ssm_state)
    return conv, h
