from repro.data.pipeline import (
    SyntheticTokens, BinaryTokenFile, Prefetcher, make_batches)

__all__ = ["SyntheticTokens", "BinaryTokenFile", "Prefetcher", "make_batches"]
