"""Data pipeline: deterministic, restart-safe, host-sharded, prefetched.

The key contract for fault tolerance and elasticity (DESIGN.md §8): a batch is
a pure function of ``(step, host_index, n_hosts)``. A restarted or resized
fleet replays exactly; no iterator state needs checkpointing beyond the step.

Sources:
  SyntheticTokens — counter-based PRNG (threefry via numpy reimplementation is
    overkill; we use SeedSequence(step, host) — deterministic and cheap).
  BinaryTokenFile — flat uint16/uint32 token file, strided window reads.
Prefetcher — background-thread double buffering ahead of the train loop.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticTokens:
    """Deterministic synthetic LM batches keyed by (step, host)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 host_index: int = 0, n_hosts: int = 1, seed: int = 0):
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.seq = seq_len
        self.local_batch = global_batch // n_hosts
        self.host = host_index
        self.n_hosts = n_hosts
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host]))
        # Zipf-skewed unigrams + partial bigram determinism: tiny training
        # runs show a real loss drop (unigram head learns in a few steps),
        # longer runs keep improving (bigram structure).
        b, s = self.local_batch, self.seq
        base = (rng.zipf(1.5, size=(b, s + 1)) - 1) % self.vocab
        base = base.astype(np.int32)
        follow = (base * 31 + 7) % self.vocab
        mix = rng.random((b, s + 1)) < 0.25
        toks = np.where(mix, np.roll(follow, 1, axis=1), base)
        return {"tokens": toks[:, :s], "labels": toks[:, 1:]}


class BinaryTokenFile:
    """Flat binary token file reader with (step, host)-keyed windows."""

    def __init__(self, path: str, vocab: int, seq_len: int,
                 global_batch: int, *, dtype=np.uint16, host_index: int = 0,
                 n_hosts: int = 1):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab
        self.seq = seq_len
        assert global_batch % n_hosts == 0
        self.local_batch = global_batch // n_hosts
        self.global_batch = global_batch
        self.host = host_index
        self.n_hosts = n_hosts
        self.n_windows = (len(self.tokens) - 1) // seq_len

    def batch_at(self, step: int) -> dict:
        idx0 = (step * self.global_batch
                + self.host * self.local_batch) % max(
                    1, self.n_windows - self.local_batch)
        rows = []
        for i in range(self.local_batch):
            w = (idx0 + i) % self.n_windows
            a = w * self.seq
            rows.append(np.asarray(self.tokens[a:a + self.seq + 1],
                                   dtype=np.int32))
        arr = np.stack(rows) % self.vocab
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


class Prefetcher:
    """Runs source.batch_at(step) for future steps on a background thread."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next = start_step

        def work():
            s = start_step
            while not self._stop.is_set():
                batch = self.source.batch_at(s)
                self._q.put((s, batch))
                s += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def get(self, expected_step: int) -> dict:
        step, batch = self._q.get()
        # after a restart mid-stream, fast-forward to the expected step
        while step < expected_step:
            step, batch = self._q.get()
        assert step == expected_step, (step, expected_step)
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def make_batches(source, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield source.batch_at(step)
        step += 1
