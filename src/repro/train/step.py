"""Training step: CE loss (+z-loss, MoE aux), grad clip, AdamW, microbatching.

``make_train_step`` builds the jitted, sharded step for a (config, mesh)
pair — the single artifact the launcher, the dry-run, and the real CPU
training example all share.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import ModelConfig, forward, init_params
from repro.models import sharding as shard_rules
from repro.optim.adamw import adamw_update, AdamWConfig

Params = dict[str, Any]


@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray
    params: Params
    opt: Any

    def tree_flatten(self):
        return (self.step, self.params, self.opt), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.step, s.params, s.opt), None),
    lambda _, c: TrainState(step=c[0], params=c[1], opt=c[2]),
)


def loss_fn(params: Params, cfg: ModelConfig, batch: dict, *,
            z_loss: float = 1e-4, moe_aux_w: float = 1e-2):
    logits, aux, _ = forward(params, cfg, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(ll)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum((logz - ll) * mask) / denom
    zl = jnp.sum(jnp.square(logz) * mask) / denom
    total = ce + z_loss * zl + moe_aux_w * aux["moe_aux"]
    return total, {"ce": ce, "z": zl, "moe_aux": aux["moe_aux"]}


def _clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def train_step(state: TrainState, batch: dict, cfg: ModelConfig,
               opt_cfg: AdamWConfig, *, microbatches: int = 1,
               cast_params_once: bool = True):
    """One optimizer step, optionally accumulating over microbatches.

    ``cast_params_once`` (§Perf iteration): cast fp32 master params to the
    compute dtype *before* the microbatch loop. The bf16 copy is
    loop-invariant, so XLA hoists its FSDP all-gathers out of the
    accumulation scan (1× bf16 gather per step instead of microbatches ×
    fp32), and the data-parallel gradient reduction runs in bf16; grads are
    accumulated in fp32 on the sharded layout.
    """
    if cast_params_once:
        def cast(p):
            if p.dtype == jnp.float32 and p.ndim >= 2:
                return p.astype(cfg.dtype)
            return p
        fwd_params = jax.tree.map(cast, state.params)
    else:
        fwd_params = state.params

    def grad_at(mbatch):
        g, m = jax.grad(loss_fn, has_aux=True)(fwd_params, cfg, mbatch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        return g, m

    if microbatches == 1:
        grads, metrics = grad_at(batch)
    else:
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def acc_body(carry, mbatch):
            g_acc, _ = carry
            g, m = grad_at(mbatch)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, m), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        m0 = {"ce": jnp.zeros(()), "z": jnp.zeros(()),
              "moe_aux": jnp.zeros(())}
        (grads, metrics), _ = jax.lax.scan(
            acc_body, (zeros, m0), mb,
            unroll=(True if getattr(cfg, "unroll_scans", False) else 1))
        grads = jax.tree.map(lambda g: g / microbatches, grads)

    grads, gn = _clip_by_global_norm(grads, opt_cfg.clip_norm)
    params, opt = adamw_update(state.params, grads, state.opt, state.step,
                               opt_cfg)
    metrics = dict(metrics, grad_norm=gn)
    return TrainState(step=state.step + 1, params=params, opt=opt), metrics


def make_train_step(cfg: ModelConfig, mesh: jax.sharding.Mesh,
                    opt_cfg: AdamWConfig | None = None, *,
                    microbatches: int = 1, fsdp_enabled: bool = True,
                    donate: bool = True):
    """Returns (jitted step, state_shardings, batch_shardings fn)."""
    opt_cfg = opt_cfg or AdamWConfig()
    axes = mesh.axis_names

    pshape = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))
    pspec = shard_rules.param_specs(cfg, pshape, axes,
                                    fsdp_enabled=fsdp_enabled)
    ospec = {"m": pspec, "v": pspec}
    state_spec = TrainState(step=P(), params=pspec, opt=ospec)
    state_sharding = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_spec,
        is_leaf=lambda x: isinstance(x, P))

    def batch_sharding(batch_shape: dict):
        spec = shard_rules.batch_specs(cfg, batch_shape, axes)
        return {k: NamedSharding(mesh, s) for k, s in spec.items()}

    step = functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                             microbatches=microbatches)
    jstep = jax.jit(
        step,
        donate_argnums=(0,) if donate else (),
    )
    return jstep, state_sharding, batch_sharding
