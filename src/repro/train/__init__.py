from repro.train.step import make_train_step, loss_fn, TrainState

__all__ = ["make_train_step", "loss_fn", "TrainState"]
