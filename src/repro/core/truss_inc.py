"""Incremental truss maintenance — local repair instead of full recompute.

The paper's PKT is a from-scratch decomposition; the serving workloads the
engine targets (per-user ego nets, rolling windows — DESIGN.md §7/§9) mutate
graphs by small edge batches, where a full recompute per update is the
dominant cost.  Following the streaming/local-repair line of work (Jakkula &
Karypis; Sarıyüce et al.; Huang et al.), this module absorbs a batch of edge
insertions and deletions with repair work bounded by the *affected region*
(the expensive parts — probing, peeling, incidence walks — stay
region-local; a few O(m) vectorized mask/bound passes per step remain):

  1. **Persistent triangle state** — besides CSR + trussness + support, a
     handle retains the graph's triangle list, maintained incrementally:
     deletions drop the rows containing a deleted edge, each insertion
     appends the rows it creates (enumerated by the same oriented-wedge
     probe the full pipeline uses, ``kernels/wedge_common``).  Support
     repair and affected-region search are then pure index operations — no
     per-update support pass.
  2. **Affected region** — trussness changes obey level-filtered triangle
     locality (Huang et al.): an edge at level k can *drop* only if it is
     triangle-connected in the old graph to a deleted edge through edges
     with ``T >= k`` (so deletions batch exactly; k = 2 can never drop), and
     can *rise* only if triangle-connected in the new graph to an inserted
     edge through edges whose new trussness reaches k+1.  Deletions batch
     exactly; for insertions the default ``insert_mode="batched"`` path
     (DESIGN.md §13, after Jakkula & Karypis) repairs the whole batch at
     once — the per-edge rise filter generalizes to the batch bound
     ``UB = min(S+2, T+b)`` and the per-edge candidate regions merge into
     one shared region re-peeled in a single dispatch — while
     ``insert_mode="sequential"`` keeps the one-at-a-time path (the tight
     ±1 filter, not-yet-inserted edges masked absent) as the bitwise
     parity oracle.
  3. **Local re-peel** — the region is re-peeled against a *pinned
     boundary*: exterior triangle partners are seeded at their known death
     level ``trussness − 2`` and shielded from decrements, replaying
     exactly the removal schedule the full peel would produce.  Small
     regions (the steady-state case) run a host-numpy mirror of the
     sub-level loop; larger ones run the live-edge compaction machinery
     (``core.pkt.peel_live_subset``, DESIGN.md §10): the region is gathered
     into a compacted pow2-bucketed edge space — device work bounded by the
     region, not the graph — and peeled there (all three peel executors
     support the pinned mask).
  4. **Fallback** — when a region exceeds ``local_frac`` of the edge set,
     local repair stops paying and the update falls back to the full
     (support + peel) pipeline, refreshing all retained state.

The serving layer wraps this in a persistent handle
(``TrussEngine.open / update / close`` in ``serve/truss_engine.py``);
``launch/truss.py --update-stream`` replays synthetic churn through it, and
``benchmarks/inc_bench.py`` measures update-vs-recompute speedup.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.graphs.csr import (CSRGraph, build_csr, canonical_edges_with_rows,
                              check_edge_array, degeneracy_order, edge_keys,
                              relabel)
from repro.core import support as support_mod
from repro.core.hierarchy import HIER_MODES, TrussHierarchy
from repro.core.pkt import (_COMPACT_FRAC, _COMPACT_MIN, PEEL_MODES,
                            align_to_input, peel_live_subset, pkt)
from repro.kernels import wedge_common
from repro.testing.chaos import fault_point

#: Insertion repair strategies (DESIGN.md §13): ``"batched"`` repairs the
#: whole insertion batch against one merged candidate region; ``"sequential"``
#: applies edges one at a time (the ±1 locality bound) and serves as the
#: bitwise parity oracle for the batched path.
INSERT_MODES = ("sequential", "batched")


class IntegrityError(RuntimeError):
    """Maintained incremental state failed a consistency check.

    Raised by the pinned-boundary replay invariant in ``_region_peel``
    (before any corrupt trussness could be committed) and by
    :meth:`IncrementalTruss.check_invariants` (after commit, on a sampled
    edge set).  The serving layer treats it as a self-healing trigger:
    quarantine the handle and rebuild from the retained CSR
    (:meth:`IncrementalTruss.rebuild`) rather than retry (DESIGN.md §15).
    """


@dataclasses.dataclass(frozen=True)
class UpdateStats:
    """Outcome of one ``IncrementalTruss.update`` call."""

    mode: str            # "noop" | "local" | "full"
    m_before: int
    m_after: int
    inserted: int        # edges actually added (not already present)
    deleted: int         # edges actually removed (were present)
    affected: int        # total edges locally re-peeled across the batch
    boundary: int        # total pinned schedule edges across the batch
    rounds: int          # level-filtered BFS passes executed
    changed: int         # current edges whose trussness is new or different
    seconds: float
    handle: object = None  # set by TrussEngine.update
    coalesced: int = 1   # queued batches merged into this repair (§12)
    insert_mode: str | None = None  # path insertions took (None: no inserts)


def compose_update_batches(batches) -> tuple[np.ndarray, np.ndarray]:
    """Collapse a sequence of update batches into one equivalent batch.

    One ``update`` batch maps ``E → (E − remove) ∪ add`` (set-wise, add
    wins on overlap).  That composition is closed: applying batches
    ``(a_1, r_1) … (a_k, r_k)`` in order equals applying the single batch
    ``(A, R)`` with ``A`` the surviving adds (each ``a_i`` minus every
    *later* remove) and ``R`` the union of all removes — the scheduler's
    coalescing rule (DESIGN.md §12).

    Args:
        batches: iterable of ``(add_edges, remove_edges)`` pairs in arrival
            order; either element may be ``None`` or empty.

    Returns:
        ``(add, remove)`` int64 ``(k, 2)`` canonical edge arrays such that
        one ``update(add_edges=add, remove_edges=remove)`` produces the
        same graph as applying the batches sequentially.

    Raises:
        ValueError: any batch fails edge validation (self-loops, negative
            or overflowing vertex ids).
    """
    A: set[tuple[int, int]] = set()
    R: set[tuple[int, int]] = set()
    empty = np.zeros((0, 2), np.int64)
    for add, rem in batches:
        a = check_edge_array(add if add is not None else empty)
        r = check_edge_array(rem if rem is not None else empty)
        a_set = {(min(int(u), int(v)), max(int(u), int(v))) for u, v in a}
        r_set = {(min(int(u), int(v)), max(int(u), int(v))) for u, v in r}
        A -= r_set
        A |= a_set
        R |= r_set
    def to_arr(s):
        return np.array(sorted(s), np.int64) if s else empty

    return to_arr(A), to_arr(R)


# --------------------------------------------------------------- triangles --

def wedge_subtable(g: CSRGraph, anchors: np.ndarray) -> support_mod.WedgeTable:
    """Peel-phase wedge table restricted to ``anchors`` (sorted edge ids).

    Same layout and min-degree orientation policy as
    ``support.build_peel_table``, but only the anchor edges get entries; the
    ``off`` array still spans all ``m`` edges (non-anchors carry empty
    ranges) so ``chunk_ranges`` and the masked peel loop work unchanged.
    """
    anchors = np.asarray(anchors, dtype=np.int64)
    if anchors.size == 0 or g.m == 0:
        return support_mod.WedgeTable(
            e1=np.zeros(0, np.int32), cand_slot=np.zeros(0, np.int32),
            lo=np.zeros(0, np.int32), hi=np.zeros(0, np.int32),
            off=np.zeros(g.m + 1, np.int64))
    Es = g.Es.astype(np.int64)
    deg = Es[1:] - Es[:-1]
    u = g.El[anchors, 0].astype(np.int64)
    v = g.El[anchors, 1].astype(np.int64)
    swap = deg[u] > deg[v]
    cand = np.where(swap, v, u)          # scan this side's full adjacency
    prob = np.where(swap, u, v)          # binary-search this side
    cnt = deg[cand]
    off = np.zeros(g.m + 1, np.int64)
    off[anchors + 1] = cnt
    np.cumsum(off, out=off)
    e1 = np.repeat(anchors, cnt)
    intra = np.arange(int(off[-1]), dtype=np.int64) - off[e1]
    cand_rep = np.repeat(cand, cnt)
    prob_rep = np.repeat(prob, cnt)
    return support_mod.WedgeTable(
        e1=e1.astype(np.int32),
        cand_slot=(Es[cand_rep] + intra).astype(np.int32),
        lo=Es[prob_rep].astype(np.int32),
        hi=Es[prob_rep + 1].astype(np.int32),
        off=off,
    )


def _probe_iters(g: CSRGraph) -> int:
    dmax = int(g.degrees.max(initial=1))
    return max(1, int(np.ceil(np.log2(dmax + 1))) + 1)


def triangles_through(g: CSRGraph,
                      anchors: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                                    np.ndarray]:
    """Every triangle through each anchor edge, as (anchor, e2, e3) id rows.

    A triangle through an anchor is reported exactly once *per anchor it
    contains*.  Runs on the host (``probe_np``) — update batches probe tiny,
    differently-shaped tables every call, the wrong regime for a jit trace.
    """
    anchors = np.asarray(anchors, dtype=np.int64)
    if anchors.size == 0 or g.m == 0:
        z = np.zeros(0, np.int64)
        return z, z.copy(), z.copy()
    tab = wedge_subtable(g, anchors)
    if tab.size == 0:
        z = np.zeros(0, np.int64)
        return z, z.copy(), z.copy()
    hit, safe = wedge_common.probe_np(
        g.N, tab.cand_slot.astype(np.int64), tab.lo, tab.hi,
        iters=_probe_iters(g))
    return (tab.e1[hit].astype(np.int64),
            g.Eid[tab.cand_slot[hit]].astype(np.int64),
            g.Eid[safe[hit]].astype(np.int64))


def triangle_list(g: CSRGraph) -> np.ndarray:
    """All triangles of ``g``, each exactly once, as a (T, 3) edge-id array.

    Enumerated with the full-adjacency wedge probe anchored at every edge
    (each triangle surfaces once per member edge) and kept at its lowest
    member id.  Built once per full decomposition; updates maintain the
    list incrementally.
    """
    if g.m == 0:
        return np.zeros((0, 3), np.int64)
    a, e2, e3 = triangles_through(g, np.arange(g.m, dtype=np.int64))
    keep = (a < e2) & (a < e3)
    return np.sort(np.stack([a[keep], e2[keep], e3[keep]], axis=1), axis=1)


class _Incidence:
    """Edge → triangle-row CSR over a fixed (T, 3) triangle list."""

    def __init__(self, tri: np.ndarray, m: int):
        self.tri = tri
        flat = tri.ravel()
        order = np.argsort(flat, kind="stable")
        counts = np.bincount(flat, minlength=m) if flat.size else \
            np.zeros(m, np.int64)
        self.off = np.zeros(m + 1, np.int64)
        np.cumsum(counts, out=self.off[1:])
        self.idx = order // 3

    def rows_of(self, edges: np.ndarray) -> np.ndarray:
        """Triangle-row indices incident to any of ``edges`` (with repeats)."""
        if edges.size == 0 or self.idx.size == 0:
            return np.zeros(0, np.int64)
        cnt = self.off[edges + 1] - self.off[edges]
        pos = np.repeat(self.off[edges], cnt) + \
            (np.arange(int(cnt.sum()), dtype=np.int64)
             - np.repeat(np.cumsum(cnt) - cnt, cnt))
        return self.idx[pos]


def _tri_bfs(inc: _Incidence, side: np.ndarray, seeds: np.ndarray,
             allowed: np.ndarray) -> np.ndarray:
    """Edges triangle-reachable from ``seeds`` through ``allowed`` edges.

    Traversal steps through triangles (static ``inc`` rows plus the ``side``
    rows of the in-flight insertion phase) *all three* of whose edges are
    allowed — the certificate subgraphs of the locality lemmas are closed
    under their own triangles, so the stricter rule loses nothing.  Returns
    the sorted reached edge ids (seeds outside ``allowed`` are dropped).
    """
    m = allowed.shape[0]
    visited = np.zeros(m, bool)
    frontier = np.unique(seeds[allowed[seeds]]) if seeds.size else \
        np.zeros(0, np.int64)
    visited[frontier] = True
    in_side = side.size > 0
    while frontier.size:
        rows = inc.tri[np.unique(inc.rows_of(frontier))] \
            if inc.tri.size else np.zeros((0, 3), np.int64)
        if in_side:
            hit = np.isin(side, frontier).any(axis=1)
            rows = np.concatenate([rows, side[hit]])
        if rows.size == 0:
            break
        ok = allowed[rows].all(axis=1)
        cand = rows[ok].ravel()
        cand = np.unique(cand[~visited[cand]]) if cand.size else cand
        visited[cand] = True
        frontier = cand
    return np.nonzero(visited)[0].astype(np.int64)


def _h_values(inc: _Incidence, tau: np.ndarray,
              work: np.ndarray) -> np.ndarray:
    """Truss h-operator for each edge in ``work``: 2 + (largest s such that
    the edge is in >= s triangles whose other two edges both have current
    value >= s + 2).  Vectorized over the incidence structure."""
    if work.size == 0:
        return np.zeros(0, np.int64)
    cnt = inc.off[work + 1] - inc.off[work]
    owner = np.repeat(np.arange(work.shape[0], dtype=np.int64), cnt)
    rows = inc.tri[inc.rows_of(work)]
    h = np.zeros(work.shape[0], np.int64)
    if rows.size:
        e = work[owner]
        # partner-min in rho (= tau - 2) space, per membership
        t0, t1, t2 = tau[rows[:, 0]], tau[rows[:, 1]], tau[rows[:, 2]]
        val = np.where(
            rows[:, 0] == e, np.minimum(t1, t2),
            np.where(rows[:, 1] == e, np.minimum(t0, t2),
                     np.minimum(t0, t1))) - 2
        order = np.lexsort((-val, owner))
        owner_s, val_s = owner[order], val[order]
        starts = np.nonzero(np.diff(owner_s, prepend=-1))[0]
        rank = np.arange(owner_s.shape[0], dtype=np.int64) \
            - np.repeat(starts, np.diff(np.append(starts, owner_s.shape[0])))
        score = np.minimum(val_s, rank + 1)
        np.maximum.at(h, owner_s, np.maximum(score, 0))
    return h + 2


def _h_descent(inc: _Incidence, tau: np.ndarray, seeds: np.ndarray,
               totals, limit: float) -> bool:
    """Chaotic descent of the truss h-operator from a valid upper bound.

    Exact when ``tau`` starts pointwise >= the true decomposition (any
    h-operator post-fixpoint is <= truth via its own >=k-subgraph
    certificate, and monotone descent never goes below truth), which holds
    for pure deletions: the pre-deletion trussness bounds the post-deletion
    one.  Work is proportional to the edges that actually drop plus their
    triangle neighborhoods — no a-priori region needed.  Mutates ``tau``;
    returns False (request full-recompute fallback, ``tau`` then discarded)
    once more than ``limit`` edges have dropped — the local_frac policy.
    """
    changed = np.zeros(tau.shape[0], bool)
    work = np.unique(seeds)
    while work.size:
        totals["passes"] += 1
        h = _h_values(inc, tau, work)
        dropped = work[h < tau[work]]
        tau[dropped] = h[h < tau[work]]
        changed[dropped] = True
        if dropped.size == 0:
            break
        if int(changed.sum()) > limit:
            totals["affected"] += int(changed.sum())
            return False
        rows = inc.tri[np.unique(inc.rows_of(dropped))]
        work = np.unique(rows.ravel()) if rows.size else \
            np.zeros(0, np.int64)
    totals["affected"] += int(changed.sum())
    return True


# -------------------------------------------------------------- local peel --

def _host_peel(n_loc: int, tri_loc: np.ndarray, S0: np.ndarray,
               live0: np.ndarray, pinned: np.ndarray) -> np.ndarray:
    """Host-numpy mirror of the ``_peel_loop`` sub-level fixed point.

    Operates on a compact local edge space (``n_loc`` slots): ``tri_loc``
    holds the region's triangles as local-id rows, ``S0`` the start support
    (pinned edges: their death level), ``live0`` the live slots.  Same
    decrement formulas and tie-break as ``core.pkt._peel_loop``; the final
    values agree because the peel fixed point is schedule-independent.
    """
    S = S0.astype(np.int64).copy()
    processed = ~live0.copy()
    if tri_loc.size:
        e1 = tri_loc.ravel()
        oth = np.stack([tri_loc[:, [1, 2]], tri_loc[:, [0, 2]],
                        tri_loc[:, [0, 1]]], axis=1).reshape(-1, 2)
        e2, e3 = oth[:, 0], oth[:, 1]
    else:
        e1 = e2 = e3 = np.zeros(0, np.int64)
    while not processed.all():
        l = S[~processed].min()
        inCurr = ~processed & (S == l)
        while inCurr.any():
            valid = inCurr[e1] & ~processed[e2] & ~processed[e3]
            dec2 = valid & (S[e2] > l) & (~inCurr[e3] | (e1 < e3)) \
                & ~pinned[e2]
            dec3 = valid & (S[e3] > l) & (~inCurr[e2] | (e1 < e2)) \
                & ~pinned[e3]
            dec = np.bincount(e2[dec2], minlength=n_loc) \
                + np.bincount(e3[dec3], minlength=n_loc)
            S = np.where(~processed & ~inCurr & (dec > 0),
                         np.maximum(S - dec, l), S)
            processed = processed | inCurr
            inCurr = ~processed & (S == l)
    return S


# --------------------------------------------------------------- the state --

class IncrementalTruss:
    """A decomposed graph that absorbs edge insertions/deletions in place.

    State held across updates: the CSR graph, per-edge trussness *and*
    support (both aligned to ``g.El`` row order, which is canonical-key
    order), the triangle list, and the vertex-id space ``n`` (grows
    monotonically as updates introduce new vertex ids).

    ``update(add_edges=…, remove_edges=…)`` applies one batch:
    ``E_new = (E_old − remove) ∪ add``.  Inserting an edge that already
    exists, or removing one that doesn't, is a no-op for that row (the
    batch semantics are set-wise; an edge in both batches ends up present).
    Returns :class:`UpdateStats`.

    Args:
        edges: initial (k, 2) integer edge array (validated like every
            batch entry point).
        n: vertex-space size (default: max id + 1; grows with updates).
        mode: peel executor (see ``core.pkt.pkt``).
        support_mode: support executor.
        table_mode: wedge-table builder ("device" / "numpy", §10).
        hier_mode: community-index builder ("device" / "host", §11).
        insert_mode: insertion repair strategy ("batched" / "sequential",
            §13) — one merged-region re-peel per batch vs one re-peel per
            inserted edge; bitwise-identical results.
        chunk: peel chunk size (pow2); ``None`` applies the tuned
            auto-chunk policy per table (``kernels.wedge_common``).
        local_frac: affected-region fraction above which an update falls
            back to full recompute.
        host_peel_max: region size ceiling for the host re-peel path;
            larger affected regions use the masked device re-peel.
        compact_frac: live-edge compaction threshold for full recomputes
            (``None`` disables; §10).
        compact_min: minimum live-edge count for compaction.
        interpret: force/forbid Pallas interpret mode.

    Raises:
        ValueError: unknown mode axis, invalid edge array, or
            out-of-range ``local_frac``.
    """

    def __init__(self, edges, *, n: int | None = None, mode: str = "chunked",
                 support_mode: str = "jnp", table_mode: str = "device",
                 hier_mode: str = "device", insert_mode: str = "batched",
                 chunk: int | None = None,
                 local_frac: float = 0.25, host_peel_max: int = 4096,
                 compact_frac: float | None = _COMPACT_FRAC,
                 compact_min: int = _COMPACT_MIN,
                 interpret: bool | None = None):
        if mode not in PEEL_MODES:
            raise ValueError(f"mode must be one of {PEEL_MODES}, got {mode!r}")
        if support_mode not in support_mod.SUPPORT_MODES:
            raise ValueError(
                f"support_mode must be one of {support_mod.SUPPORT_MODES}, "
                f"got {support_mode!r}")
        if table_mode not in support_mod.TABLE_MODES:
            raise ValueError(
                f"table_mode must be one of {support_mod.TABLE_MODES}, "
                f"got {table_mode!r}")
        if hier_mode not in HIER_MODES:
            raise ValueError(
                f"hier_mode must be one of {HIER_MODES}, got {hier_mode!r}")
        if insert_mode not in INSERT_MODES:
            raise ValueError(
                f"insert_mode must be one of {INSERT_MODES}, "
                f"got {insert_mode!r}")
        if chunk is not None and chunk < 1:
            raise ValueError("chunk must be positive")
        if not 0.0 <= local_frac <= 1.0:
            raise ValueError("local_frac must be in [0, 1]")
        self.mode = mode
        self.support_mode = support_mode
        self.table_mode = table_mode
        self.hier_mode = hier_mode
        self.insert_mode = insert_mode
        self._hier: TrussHierarchy | None = None
        self.compact_frac = compact_frac
        self.compact_min = int(compact_min)
        self.chunk = (None if chunk is None
                      else wedge_common.next_pow2(chunk))
        self.local_frac = float(local_frac)
        self.host_peel_max = int(host_peel_max)
        self.interpret = (wedge_common.interpret_default()
                          if interpret is None else interpret)
        self.stats = {"updates": 0, "local": 0, "full": 0, "noop": 0,
                      "update_seconds": 0.0, "last": None}
        E, _, _, n_seen = canonical_edges_with_rows(edges)
        self.n = max(int(n or 0), n_seen)
        self._full_rebuild(E)

    # ------------------------------------------------------------ queries --
    @property
    def m(self) -> int:
        """Current canonical edge count."""
        return self.g.m

    @property
    def edges(self) -> np.ndarray:
        """Current canonical (m, 2) int64 edge list (key-sorted)."""
        return self.g.El.astype(np.int64)

    @property
    def trussness(self) -> np.ndarray:
        """Per-edge trussness aligned to ``edges`` rows (int64)."""
        return self.T.copy()

    @property
    def support(self) -> np.ndarray:
        """Per-edge triangle count aligned to ``edges`` rows (int32)."""
        return self.S.copy()

    @property
    def triangles(self) -> np.ndarray:
        """Current (T, 3) triangle list (edge-id rows, each once)."""
        return self.tri.copy()

    def edge_ids(self, edges) -> np.ndarray:
        """Canonical row ids of specific edges, aligned to the given rows.

        Rows may be endpoint-swapped or duplicated; an edge not currently in
        the graph raises the descriptive ``align_to_input`` ValueError.
        """
        rows = check_edge_array(edges)
        if rows.size == 0:
            return np.zeros(0, np.int64)
        lo = np.minimum(rows[:, 0], rows[:, 1])
        hi = np.maximum(rows[:, 0], rows[:, 1])
        if int(rows.max()) >= self.n:
            i = int(np.argmax(hi >= self.n))
            raise ValueError(
                f"edge ({int(lo[i])}, {int(hi[i])}) not present in the "
                f"graph's edge list (vertex id beyond the graph)")
        return align_to_input(np.arange(self.g.m, dtype=np.int64), self.g,
                              None, self.n, keys=edge_keys(lo, hi, self.n))

    def query(self, edges) -> np.ndarray:
        """Trussness for specific edges, aligned to the given rows."""
        return self.T[self.edge_ids(edges)]

    def hierarchy(self, *, mode: str | None = None) -> TrussHierarchy:
        """The community index over the current decomposition (lazy, cached).

        Built from the handle's own trussness + maintained triangle list on
        first access; levels themselves materialize lazily inside the index.
        The cache survives *local* ``update`` batches (untouched levels are
        id-remapped, repaired levels come back dirty — see ``_hier_update``)
        and is dropped whole by full rebuilds.  ``mode`` overrides the
        handle's ``hier_mode``: a *different* mode returns a standalone
        (uncached) index, so parity-oracle reads never evict the serving
        cache.
        """
        mode = self.hier_mode if mode is None else mode
        if mode not in HIER_MODES:
            raise ValueError(
                f"mode must be one of {HIER_MODES}, got {mode!r}")
        if mode != self.hier_mode:
            return TrussHierarchy(self.T, self.tri, mode=mode,
                                  interpret=self.interpret)
        if self._hier is None:
            self._hier = TrussHierarchy(self.T, self.tri, mode=mode,
                                        interpret=self.interpret)
        return self._hier

    # ------------------------------------------------------------- update --
    def update_many(self, batches, *,
                    insert_mode: str | None = None) -> UpdateStats:
        """Apply several update batches as one composed repair.

        Args:
            batches: iterable of ``(add_edges, remove_edges)`` pairs in
                arrival order (either element may be ``None``).
            insert_mode: per-call override of the handle's insertion
                strategy (``None``: use the handle default).

        Returns:
            The :class:`UpdateStats` of the single composed ``update``,
            with ``coalesced`` set to the number of merged batches.  The
            final state is bitwise-identical to applying the batches one
            at a time (see :func:`compose_update_batches`).

        Raises:
            ValueError: any batch fails edge validation.
        """
        batches = list(batches)
        add, rem = compose_update_batches(batches)
        st = self.update(add_edges=add, remove_edges=rem,
                         insert_mode=insert_mode)
        st = dataclasses.replace(st, coalesced=max(1, len(batches)))
        self.stats["last"] = st
        return st

    def update(self, add_edges=None, remove_edges=None, *,
               insert_mode: str | None = None) -> UpdateStats:
        """Apply one insert/delete batch: ``E → (E − remove) ∪ add``.

        Args:
            add_edges: ``(k, 2)`` integer edge array to insert (either
                endpoint order; duplicates collapse; inserting a present
                edge is a no-op for that row).  ``None`` means none.
            remove_edges: ``(k, 2)`` integer edge array to delete (removing
                an absent edge is a no-op for that row).  An edge in both
                batches ends up present.
            insert_mode: per-call override of the handle's insertion
                strategy (``"batched"`` / ``"sequential"``, §13; ``None``:
                use the handle default).

        Returns:
            :class:`UpdateStats` — ``mode`` reports whether the batch was
            absorbed by local repair (``"local"``), fell back to a full
            recompute (``"full"``), or changed nothing (``"noop"``).

        Raises:
            ValueError: edge arrays fail validation (self-loops, negative
                or overflowing vertex ids), or unknown ``insert_mode``.
        """
        t0 = time.perf_counter()
        imode = self.insert_mode if insert_mode is None else insert_mode
        if imode not in INSERT_MODES:
            raise ValueError(
                f"insert_mode must be one of {INSERT_MODES}, got {imode!r}")
        add = check_edge_array(add_edges if add_edges is not None
                               else np.zeros((0, 2), np.int64))
        rem = check_edge_array(remove_edges if remove_edges is not None
                               else np.zeros((0, 2), np.int64))
        hi_seen = max(int(add.max(initial=-1)), int(rem.max(initial=-1)))
        if hi_seen >= self.n:
            self.n = hi_seen + 1          # vertex space grows monotonically
        n = self.n
        m_before = self.g.m

        old_keys = edge_keys(self.g.El[:, 0].astype(np.int64),
                             self.g.El[:, 1].astype(np.int64), n)
        add_keys = self._batch_keys(add, n)
        rem_keys = self._batch_keys(rem, n)
        new_keys = np.union1d(
            np.setdiff1d(old_keys, rem_keys, assume_unique=True), add_keys)
        I_keys = np.setdiff1d(new_keys, old_keys, assume_unique=True)
        D_keys = np.setdiff1d(old_keys, new_keys, assume_unique=True)

        totals = {"affected": 0, "boundary": 0, "passes": 0}
        T_old_ref = self.T      # for the changed count (old-id space)

        def done(mode):
            m_after = self.g.m
            if mode == "noop":
                changed = 0
            else:
                posn = np.searchsorted(
                    edge_keys(self.g.El[:, 0].astype(np.int64),
                              self.g.El[:, 1].astype(np.int64), n), old_keys)
                safe = np.minimum(posn, max(m_after - 1, 0))
                ok = np.zeros(m_before, bool)
                if m_after:
                    kn = edge_keys(self.g.El[:, 0].astype(np.int64),
                                   self.g.El[:, 1].astype(np.int64), n)
                    ok = (posn < m_after) & (kn[safe] == old_keys)
                changed = int((self.T[posn[ok]] != T_old_ref[ok]).sum()) \
                    + int(I_keys.size)
                if mode == "local" and self._hier is not None:
                    self._hier_update(old_keys, I_keys, T_old_ref, posn, ok,
                                      kn if m_after else None)
            st = UpdateStats(
                mode=mode, m_before=m_before, m_after=m_after,
                inserted=int(I_keys.size), deleted=int(D_keys.size),
                affected=totals["affected"], boundary=totals["boundary"],
                rounds=totals["passes"], changed=changed,
                seconds=time.perf_counter() - t0,
                insert_mode=imode if (I_keys.size and mode != "noop")
                else None)
            self.stats["updates"] += 1
            self.stats[mode] += 1
            self.stats["update_seconds"] += st.seconds
            self.stats["last"] = st
            return st

        if I_keys.size == 0 and D_keys.size == 0:
            return done("noop")

        E_new = np.stack([new_keys // n, new_keys % n], axis=1)
        limit = self.local_frac * max(1, new_keys.shape[0])

        # Both phases build the next state off to the side and it is
        # committed exactly once, after the whole batch has succeeded — an
        # exception mid-repair must leave the handle bitwise-untouched
        # (no half-applied batch, §13).
        state = (self.g, self.T, self.S, self.tri)

        # ---------------- phase D: all deletions as one exact batch -------
        if D_keys.size:
            state = self._apply_deletions(old_keys, D_keys, n, limit, totals)
            if state is None:
                self._full_rebuild(E_new)
                return done("full")

        # ---------------- phase I: insertions (batched or sequential) -----
        if I_keys.size:
            state = self._apply_insertions(state, new_keys, I_keys, n, limit,
                                           totals, imode)
            if state is None:
                self._full_rebuild(E_new)
                return done("full")

        self._commit(*state)
        return done("local")

    # ------------------------------------------------------- deletion phase --
    def _apply_deletions(self, old_keys, D_keys, n, limit, totals):
        """G → G − D, built off to the side (committed state untouched).

        Returns the repaired ``(g, T, S, tri)`` state tuple, or ``None`` to
        request full fallback.
        """
        g_old, T_old, S_old, tri_old = self.g, self.T, self.S, self.tri
        m_old = g_old.m
        del_old = np.searchsorted(old_keys, D_keys)
        is_del = np.zeros(m_old, bool)
        is_del[del_old] = True

        mid_keys = np.setdiff1d(old_keys, D_keys, assume_unique=True)
        E_mid = np.stack([mid_keys // n, mid_keys % n], axis=1)
        g_mid = build_csr(E_mid, n)
        m_mid = g_mid.m
        mid_of_old = np.full(m_old, -1, np.int64)
        mid_of_old[~is_del] = np.searchsorted(mid_keys, old_keys[~is_del])

        # triangle list and support delta (each lost row exactly once)
        lost_mask = is_del[tri_old].any(axis=1) if tri_old.size else \
            np.zeros(0, bool)
        lost = tri_old[lost_mask]
        tri_mid = mid_of_old[tri_old[~lost_mask]] if tri_old.size else \
            np.zeros((0, 3), np.int64)
        S_mid = S_old[~is_del].astype(np.int64)
        seeds = np.zeros(0, np.int64)
        if lost.size:
            members = lost.ravel()
            keep = ~is_del[members]
            seeds = mid_of_old[members[keep]]
            np.subtract.at(S_mid, seeds, 1)
        S_mid = S_mid.astype(np.int32)
        T_mid = T_old[~is_del].copy()

        # Deletions only lower trussness, so the old values are a valid
        # upper bound on the new decomposition and the local h-index
        # descent (Sarıyüce et al.) repairs exactly, discovering the
        # affected set lazily — the a-priori connectivity closure is far
        # too coarse on dense-core graphs, where every >=k level class is
        # one triangle-connected blob.
        if seeds.size:
            if np.unique(seeds).size > limit:
                return None         # repair would touch too much: recompute
            inc_mid = _Incidence(tri_mid, m_mid)
            if not _h_descent(inc_mid, T_mid, seeds, totals, limit):
                return None         # descent cascaded past local_frac
        return g_mid, T_mid, S_mid, tri_mid

    # ------------------------------------------------------ insertion phase --
    def _apply_insertions(self, state, new_keys, I_keys, n, limit, totals,
                          insert_mode):
        """G → G + I, built off to the side (committed state untouched).

        Builds the one new CSR, maps the mid-state values into the new edge
        space, and dispatches on ``insert_mode``: ``"sequential"`` repairs
        one edge at a time (the +1-per-insertion locality bound, with
        not-yet-inserted edges masked absent), ``"batched"`` repairs the
        whole batch against one merged candidate region (§13).  Returns the
        repaired ``(g, T, S, tri)`` state tuple, or ``None`` to request
        full fallback.
        """
        g_mid, T_mid, S_mid, tri_mid = state
        mid_keys = edge_keys(g_mid.El[:, 0].astype(np.int64),
                             g_mid.El[:, 1].astype(np.int64), n)
        E_new = np.stack([new_keys // n, new_keys % n], axis=1)
        g_new = build_csr(E_new, n)
        m_new = g_new.m
        new_of_mid = np.searchsorted(new_keys, mid_keys)
        ins_new = np.searchsorted(new_keys, I_keys)

        T_cur = np.full(m_new, -1, np.int64)
        T_cur[new_of_mid] = T_mid
        S_cur = np.zeros(m_new, np.int64)
        S_cur[new_of_mid] = S_mid
        present = np.zeros(m_new, bool)
        present[new_of_mid] = True

        tri_static = new_of_mid[tri_mid] if tri_mid.size else \
            np.zeros((0, 3), np.int64)
        inc_static = _Incidence(tri_static, m_new)
        if insert_mode == "batched":
            side_rows = self._insert_batched(
                g_new, inc_static, ins_new, T_cur, S_cur, present, limit,
                totals)
        else:
            side_rows = self._insert_sequential(
                g_new, inc_static, ins_new, T_cur, S_cur, present, limit,
                totals)
        if side_rows is None:
            return None
        tri_new = np.concatenate([tri_static, side_rows]) \
            if side_rows.size else tri_static
        return g_new, T_cur, S_cur.astype(np.int32), tri_new

    def _insert_sequential(self, g_new, inc_static, ins_new, T_cur, S_cur,
                           present, limit, totals):
        """One pinned-boundary re-peel per inserted edge (the parity oracle).

        Mutates ``T_cur``/``S_cur``/``present`` in the new edge space;
        returns the accumulated new triangle rows, or ``None`` to request
        full fallback.
        """
        m_new = g_new.m
        side_rows = np.zeros((0, 3), np.int64)

        for e_i in ins_new:
            present[e_i] = True
            # triangles gained by this one insertion (partners must already
            # be present — triangles with a not-yet-inserted edge are born
            # later, at that edge's own step)
            a, p2, p3 = triangles_through(g_new, np.array([e_i]))
            keep = present[p2] & present[p3]
            p2, p3 = p2[keep], p3[keep]
            S_cur[e_i] += p2.shape[0]
            np.add.at(S_cur, p2, 1)
            np.add.at(S_cur, p3, 1)
            if p2.size:
                rows = np.sort(np.stack(
                    [np.full(p2.shape[0], e_i, np.int64), p2, p3], axis=1),
                    axis=1)
                side_rows = np.concatenate([side_rows, rows])

            # affected region: one insertion moves any trussness by at most
            # one, so UB = min(S+2, T+1); an edge at level k can rise only
            # if connected to e_i through {UB >= k+1} — every such path
            # runs through e_i itself, so the levels to scan are capped by
            # e_i's own new trussness, bounded by its h-operator value
            # under UB (much tighter than S+2 in dense cores).
            UB = np.where(T_cur >= 0,
                          np.minimum(S_cur + 2, T_cur + 1), S_cur + 2)
            UB[~present] = 0             # absent edges block every path
            k_cap = int(self._h_cap(e_i, UB, inc_static, side_rows)) - 1
            cand = np.zeros(m_new, bool)
            for k in np.unique(T_cur[present & (T_cur >= 2)]):
                if k > k_cap:
                    break
                allowed = UB >= k + 1
                totals["passes"] += 1
                reach = _tri_bfs(inc_static, side_rows,
                                 np.array([e_i]), allowed)
                cand[reach[T_cur[reach] == k]] = True
                if int(cand.sum()) > limit:
                    return None
            cand[e_i] = True
            A = np.nonzero(cand)[0]
            if A.size > limit or totals["affected"] + A.size > limit:
                return None    # cumulative local work past paying: recompute
            tau = self._region_peel(g_new, inc_static, side_rows, A, S_cur,
                                    T_cur, totals, live_mask=present)
            T_cur[A] = tau

        return side_rows

    def _insert_batched(self, g_new, inc_static, ins_new, T_cur, S_cur,
                        present, limit, totals):
        """All insertions as one repair: one merged candidate region (§13).

        Every inserted edge goes present at once, the batch's new triangles
        land as one deduplicated support delta, and the per-edge
        level-filtered BFS regions are merged by seeding every inserted
        edge into the *same* traversal — one region, one pinned exterior
        boundary, one compacted re-peel dispatch.  The level filter uses
        the batch bound ``UB = min(S + 2, T + b)`` (a batch of ``b``
        insertions raises any trussness by at most ``b``), scanning levels
        up to the largest inserted-edge h-cap.  Mutates
        ``T_cur``/``S_cur``/``present``; returns the new triangle rows, or
        ``None`` to request full fallback.
        """
        m_new = g_new.m
        present[ins_new] = True

        # triangles born with the batch: every triangle of the new graph
        # through an inserted edge (all partners are present now), each
        # exactly once — triangles_through reports one row per inserted
        # member, so sort + unique dedupes multi-inserted-edge triangles
        a, p2, p3 = triangles_through(g_new, ins_new)
        keep = present[p2] & present[p3]
        a, p2, p3 = a[keep], p2[keep], p3[keep]
        if a.size:
            side_rows = np.unique(
                np.sort(np.stack([a, p2, p3], axis=1), axis=1), axis=0)
            np.add.at(S_cur, side_rows[:, 0], 1)
            np.add.at(S_cur, side_rows[:, 1], 1)
            np.add.at(S_cur, side_rows[:, 2], 1)
        else:
            side_rows = np.zeros((0, 3), np.int64)

        # batch bound: b insertions move any trussness up by at most b, so
        # UB = min(S+2, T+b) dominates every new value; an edge at level k
        # can rise only through a new-graph (k+1)-truss that contains an
        # inserted edge, so {UB >= k+1}-reachability from the batch merges
        # the per-edge candidate regions, and the levels to scan are capped
        # by the largest inserted-edge h-cap under UB.
        b = int(ins_new.shape[0])
        UB = np.where(T_cur >= 0, np.minimum(S_cur + 2, T_cur + b), S_cur + 2)
        UB[~present] = 0
        k_cap = max((int(self._h_cap(int(e_i), UB, inc_static, side_rows))
                     for e_i in ins_new), default=2) - 1
        cand = np.zeros(m_new, bool)
        for k in np.unique(T_cur[present & (T_cur >= 2)]):
            if k > k_cap:
                break
            allowed = UB >= k + 1
            totals["passes"] += 1
            reach = _tri_bfs(inc_static, side_rows, ins_new, allowed)
            cand[reach[T_cur[reach] == k]] = True
            if int(cand.sum()) > limit:
                return None
        cand[ins_new] = True
        A = np.nonzero(cand)[0]
        if A.size > limit or totals["affected"] + A.size > limit:
            return None        # merged region past paying: recompute
        tau = self._region_peel(g_new, inc_static, side_rows, A, S_cur,
                                T_cur, totals, live_mask=present)
        T_cur[A] = tau
        return side_rows

    @staticmethod
    def _h_cap(e_i: int, UB: np.ndarray, inc: _Incidence,
               side: np.ndarray) -> int:
        """Upper bound on the inserted edge's new trussness: its h-operator
        value under the per-edge upper bounds (h is monotone in partner
        values, so this dominates the true value)."""
        rows = inc.tri[inc.rows_of(np.array([e_i]))]
        if side.size:
            rows = np.concatenate([rows, side[(side == e_i).any(axis=1)]])
        if rows.size == 0:
            return 2
        others = rows[rows != e_i].reshape(-1, 2)
        val = np.sort(np.minimum(UB[others[:, 0]], UB[others[:, 1]]) - 2)[::-1]
        rank = np.arange(val.shape[0], dtype=np.int64) + 1
        return 2 + int(np.maximum(np.minimum(val, rank), 0).max(initial=0))

    # ------------------------------------------------------------ region peel --
    def _region_peel(self, g: CSRGraph, inc: _Incidence, side: np.ndarray,
                     A: np.ndarray, S_vec: np.ndarray, T_fix: np.ndarray,
                     totals, live_mask: np.ndarray | None = None):
        """Re-peel region ``A`` with its exterior triangle partners pinned
        at their known death level.  Returns the new peel values + 2 for
        ``A`` (same order).  ``live_mask`` masks absent edges (insertion
        phase).  Dispatches to the host mirror for small regions and to the
        compacted ``peel_live_subset`` above ``host_peel_max``."""
        m = g.m
        rows = inc.tri[np.unique(inc.rows_of(A))] if inc.tri.size else \
            np.zeros((0, 3), np.int64)
        if side.size:
            hit = np.isin(side, A).any(axis=1)
            rows = np.concatenate([rows, side[hit]])
        if live_mask is not None and rows.size:
            rows = rows[live_mask[rows].all(axis=1)]
        in_A = np.zeros(m, bool)
        in_A[A] = True
        flat = rows.ravel()
        boundary = np.unique(flat[~in_A[flat]]) if flat.size else \
            np.zeros(0, np.int64)
        totals["affected"] += int(A.size)
        totals["boundary"] += int(boundary.size)

        L = np.union1d(A, boundary)
        chaos = fault_point(
            "region",
            rung="host" if L.shape[0] <= self.host_peel_max else self.mode)
        if L.shape[0] <= self.host_peel_max:
            # compact host path: local ids preserve the global id order, so
            # the tie-break picks the same winners
            lmap = np.full(m, -1, np.int64)
            lmap[L] = np.arange(L.shape[0])
            n_loc = L.shape[0]
            S0 = np.where(in_A[L], S_vec[L], T_fix[L] - 2)
            live = np.ones(n_loc, bool)
            pinned = ~in_A[L]
            S_fin = _host_peel(n_loc, lmap[rows] if rows.size else
                               np.zeros((0, 3), np.int64),
                               S0, live, pinned)
            tau_L = S_fin + 2
        else:
            # larger regions reuse the live-edge compaction machinery
            # (core.pkt.peel_live_subset): the region is gathered into a
            # compacted pow2-bucketed edge space — work bounded by |L|, not
            # m — with boundary edges pinned at their death level, and the
            # driver keeps compacting as the region itself peels away
            S0 = np.where(in_A[L], S_vec[L], T_fix[L] - 2)
            S_fin = peel_live_subset(
                g.El, L, S0, ~in_A[L], chunk=self.chunk, mode=self.mode,
                interpret=self.interpret, table_mode=self.table_mode,
                compact_frac=self.compact_frac, compact_min=self.compact_min)
            tau_L = S_fin.astype(np.int64) + 2
        if chaos == "corrupt" and boundary.size:
            # injected corruption (testing/chaos.py): bump one pinned slot so
            # the replay invariant below is guaranteed to trip — exercising
            # the detect → quarantine → rebuild path without ever letting a
            # wrong value reach committed state
            tau_L = tau_L.copy()
            tau_L[np.searchsorted(L, boundary[0])] += 1
        # replay invariant: pinned edges must die exactly at their schedule.
        # A real raise (not a bare assert, which -O strips): a violation
        # means the re-peel would commit corrupt trussness into the handle.
        if not np.array_equal(tau_L[~in_A[L]], T_fix[boundary]):
            raise IntegrityError(
                "incremental re-peel integrity violation: a pinned boundary "
                "edge left its death level — please report this graph")
        return tau_L[np.searchsorted(L, A)]

    # ---------------------------------------------------------- internals --
    def _hier_update(self, old_keys, I_keys, T_old, posn, ok, kn) -> None:
        """Carry the community index across a *local* repair (DESIGN.md §11).

        Every edge the repair touched bounds the levels whose community
        structure can differ: ``k_hi`` is the maximum trussness involved in
        any insertion, deletion, or trussness change (old or new value).
        Levels above ``k_hi`` keep their exact partition — only edge ids
        shifted — so they are remapped in O(m); levels at or below come
        back dirty and rebuild lazily on next query.  Full rebuilds (the
        past-``local_frac`` path) drop the index in ``_full_rebuild``.
        """
        m_before = old_keys.shape[0]
        m_after = self.g.m
        if m_after == 0 or kn is None or self._hier is None:
            self._hier = None
            return
        k_hi = 1
        if (~ok).any():                      # deletions: old death levels
            k_hi = max(k_hi, int(T_old[~ok].max()))
        t_new = self.T[posn[ok]]
        t_old = T_old[ok]
        diff = t_new != t_old
        if diff.any():                       # changed: both old and new
            k_hi = max(k_hi, int(t_old[diff].max()), int(t_new[diff].max()))
        if I_keys.size:                      # insertions: their new levels
            k_hi = max(k_hi, int(self.T[np.searchsorted(kn, I_keys)].max()))
        old_to_new = np.full(m_before, -1, np.int64)
        old_to_new[np.nonzero(ok)[0]] = posn[ok]
        self._hier = self._hier.remapped(self.T, self.tri, old_to_new, k_hi)

    @staticmethod
    def _batch_keys(batch: np.ndarray, n: int) -> np.ndarray:
        if batch.size == 0:
            return np.zeros(0, np.int64)
        lo = np.minimum(batch[:, 0], batch[:, 1])
        hi = np.maximum(batch[:, 0], batch[:, 1])
        return np.unique(edge_keys(lo, hi, n))

    def _commit(self, g_new: CSRGraph, T_new: np.ndarray, S_new: np.ndarray,
                tri_new: np.ndarray) -> None:
        self.g = g_new
        self.T = T_new.astype(np.int64)
        self.S = S_new.astype(np.int32)
        self.tri = tri_new.astype(np.int64)

    def _full_rebuild(self, E: np.ndarray) -> None:
        """From-scratch decomposition through the standard (KCO) pipeline."""
        self._hier = None        # full rebuild: community index rebuilt lazily
        g = build_csr(E, self.n)
        if g.m == 0:
            self.open_phases = {}
            self._commit(g, np.zeros(0, np.int64), np.zeros(0, np.int32),
                         np.zeros((0, 3), np.int64))
            return
        perm = degeneracy_order(E, self.n)
        r_edges = relabel(E, perm)
        gr = build_csr(r_edges, self.n)
        res = pkt(gr, chunk=self.chunk, mode=self.mode,
                  support_mode=self.support_mode, table_mode=self.table_mode,
                  compact_frac=self.compact_frac,
                  compact_min=self.compact_min, interpret=self.interpret,
                  phase_timings=True)
        #: phase breakdown of the most recent full (re)build — the open
        #: path's table-build vs support vs peel cost (benchmarks read it)
        self.open_phases = dict(res.phases or {})
        u = g.El[:, 0].astype(np.int64)
        v = g.El[:, 1].astype(np.int64)
        rl, rh = perm[u], perm[v]
        keys = edge_keys(np.minimum(rl, rh), np.maximum(rl, rh), self.n)
        T = align_to_input(res.trussness, gr, None, self.n, keys=keys)
        S = align_to_input(res.support, gr, None, self.n, keys=keys)
        self._commit(g, T, S.astype(np.int32), triangle_list(g))

    def check_invariants(self, *, sample: int = 64, seed: int = 0) -> int:
        """Cheap consistency check over a sampled edge set (DESIGN.md §15).

        Verifies, for a deterministic sample of ``sample`` edges (all edges
        when ``sample >= m``):

        1. the maintained support ``S[e]`` equals the edge's row count in
           the maintained triangle list;
        2. trussness bounds ``2 <= T[e] <= S[e] + 2``;
        3. the truss h-operator fixpoint ``T[e] == h(T)[e]`` — a necessary
           condition of a correct decomposition that any single-edge
           corruption of ``T`` violates at the edge itself or a triangle
           partner;
        4. sampled triangle rows are strictly increasing and in-range.

        Cost is one incidence-CSR build (O(|tri|)) plus O(sample) work —
        orders of magnitude below a re-peel — so the scheduler runs it
        after every repair.  It is *sampled*, not a proof: ``verify()``
        remains the full oracle.

        Returns:
            The number of edges checked.

        Raises:
            IntegrityError: any check fails (the handle should be healed
                via :meth:`rebuild`).
        """
        m = self.g.m
        if m == 0:
            return 0
        if sample >= m:
            idx = np.arange(m, dtype=np.int64)
        else:
            # deterministic, seed-keyed sample without a bias toward low ids
            rng = np.random.default_rng(seed)
            idx = np.unique(rng.choice(m, size=sample, replace=False))
        inc = _Incidence(self.tri, m)
        cnt = inc.off[idx + 1] - inc.off[idx]
        if not np.array_equal(cnt, self.S[idx].astype(np.int64)):
            raise IntegrityError(
                "invariant violation: maintained support disagrees with the "
                "triangle list on the sampled edges")
        if (self.T[idx] < 2).any() or (self.T[idx] > self.S[idx] + 2).any():
            raise IntegrityError(
                "invariant violation: trussness outside [2, support + 2] on "
                "the sampled edges")
        if not np.array_equal(_h_values(inc, self.T, idx), self.T[idx]):
            raise IntegrityError(
                "invariant violation: trussness is not an h-operator "
                "fixpoint on the sampled edges")
        if self.tri.size:
            rows = self.tri[inc.rows_of(idx)] if cnt.sum() else \
                np.zeros((0, 3), np.int64)
            if rows.size and not (
                    (rows[:, 0] < rows[:, 1]).all()
                    and (rows[:, 1] < rows[:, 2]).all()
                    and rows.min() >= 0 and rows.max() < m):
                raise IntegrityError(
                    "invariant violation: malformed triangle rows incident "
                    "to the sampled edges")
        return int(idx.shape[0])

    def rebuild(self) -> None:
        """Self-healing hook: rediscover all state from the retained CSR.

        Discards trussness, support, triangle list, and the community-index
        cache, and recomputes them with a from-scratch ``pkt`` over the
        current edge list — the recovery action for integrity violations
        (DESIGN.md §15).  The edge set itself is preserved exactly.
        """
        self._full_rebuild(self.edges)

    def verify(self) -> bool:
        """Debug helper: does the maintained state match a from-scratch PKT?"""
        if self.g.m == 0:
            return True
        from repro.core.pkt import truss_pkt
        ref = truss_pkt(self.edges)
        S_ref = support_mod.compute_support(self.g)
        if self.tri.size:
            tri_ok = (self.tri.shape[0] == int(S_ref.sum()) // 3
                      and (self.tri[:, 0] < self.tri[:, 1]).all()
                      and (self.tri[:, 1] < self.tri[:, 2]).all())
        else:
            tri_ok = int(S_ref.sum()) == 0
        return (np.array_equal(self.T, ref)
                and np.array_equal(self.S, S_ref) and bool(tri_ok))
