"""Distributed PKT — shard_map bulk-synchronous truss decomposition.

The paper closes with: "porting this algorithm to GPU and distributed-memory
settings appears to be non-trivial." This module is that port, in the BSP
idiom natural to an SPMD mesh:

  * the flat peel-wedge table (the unit of peel work) is sharded across a mesh
    axis; each device computes decrement contributions for its slice;
  * edge state (S, processed, frontier) is replicated; one `psum` of the
    decrement vector per sub-level is the only communication — the distributed
    analogue of the paper's per-sub-level barrier;
  * support computation fans out the same way (shard the oriented wedge
    table, psum the partial supports once); per shard it runs either as the
    flat jnp program or — ``support_mode="pallas"`` — as the chunked VMEM
    kernel from ``kernels/support.py``, each device lowering the kernel over
    its own table slice.  Both modes are bitwise identical.

Work per sub-level per device: O(local_table) dense (each device scans its
slice with frontier masking). Communication per sub-level: one all-reduce of
an m-vector. This is exactly the cost model a 1000-node deployment needs to
reason about, and what launch/dryrun.py lowers for the production mesh.
"""

from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.graphs.csr import CSRGraph
from repro.core import support as support_mod
from repro.kernels import wedge_common

_SENT = jnp.int32(1 << 30)


def _dist_peel_body(N, Eid, S0, e1, cand, lo, hi, *, m: int, iters: int,
                    chunk: int, axes: Sequence[str]):
    """Runs inside shard_map: local table slices, replicated edge state."""
    local = e1.shape[0]
    n_chunks = max(1, local // chunk)

    def chunk_contrib(c, dec, S_ext, processed, inCurr, l):
        base = c * chunk
        ee1 = jax.lax.dynamic_slice(e1, (base,), (chunk,))
        cc = jax.lax.dynamic_slice(cand, (base,), (chunk,))
        ll = jax.lax.dynamic_slice(lo, (base,), (chunk,))
        hh = jax.lax.dynamic_slice(hi, (base,), (chunk,))
        in1 = inCurr[ee1]
        hit, safe = wedge_common.probe(N, cc, ll, hh, iters=iters)
        e2 = Eid[cc]
        e3 = Eid[safe]
        valid = in1 & hit & ~processed[e2] & ~processed[e3]
        dec2 = valid & (S_ext[e2] > l) & ((~inCurr[e3]) | (ee1 < e3))
        dec3 = valid & (S_ext[e3] > l) & ((~inCurr[e2]) | (ee1 < e2))
        dec = dec.at[jnp.where(dec2, e2, m)].add(dec2.astype(jnp.int32))
        dec = dec.at[jnp.where(dec3, e3, m)].add(dec3.astype(jnp.int32))
        return dec

    def sublevel(S_ext, processed, inCurr, l):
        def body(c, dec):
            return chunk_contrib(c, dec, S_ext, processed, inCurr, l)
        dec = jax.lax.fori_loop(0, n_chunks, body,
                                jnp.zeros((m + 1,), jnp.int32))
        for ax in axes:
            dec = jax.lax.psum(dec, ax)
        S_ext = jnp.where((~processed) & (~inCurr) & (dec > 0),
                          jnp.maximum(S_ext - dec, l), S_ext)
        processed = processed | inCurr
        inCurr = (~processed) & (S_ext == l)
        return S_ext, processed, inCurr

    S_ext0 = jnp.concatenate([S0.astype(jnp.int32), jnp.full((1,), _SENT)])
    processed0 = jnp.zeros((m + 1,), jnp.bool_).at[m].set(True)

    def level_body(state):
        S_ext, processed, todo, levels, subs = state
        l = jnp.min(jnp.where(processed, _SENT, S_ext))
        inCurr = (~processed) & (S_ext == l)

        def sub_cond(st):
            return jnp.any(st[2])

        def sub_body(st):
            S_ext, processed, inC, subs_ = st
            S_ext, processed, inC = sublevel(S_ext, processed, inC, l)
            return S_ext, processed, inC, subs_ + 1

        S_ext, processed, _, subs = jax.lax.while_loop(
            sub_cond, sub_body, (S_ext, processed, inCurr, subs))
        todo = (m + 1) - jnp.sum(processed.astype(jnp.int32))
        return S_ext, processed, todo, levels + 1, subs

    state = (S_ext0, processed0, jnp.int32(m), jnp.int32(0), jnp.int32(0))
    S_ext, _, _, levels, subs = jax.lax.while_loop(
        lambda st: st[2] > 0, level_body, state)
    return S_ext[:m], levels, subs


def _dist_support_body(N, Eid, e1, cand, lo, hi, *, m: int, iters: int,
                       axes: Sequence[str], mode: str = "jnp",
                       chunk: int = 0, interpret: bool = True):
    """Sharded AM4 support computation (inside shard_map).

    ``mode="pallas"`` evaluates the local table slice with the chunked
    support kernel (the caller guarantees the slice length is a multiple of
    ``chunk``); the folded scatter and one psum make the two modes bitwise
    identical.
    """
    if mode == "pallas":
        from repro.kernels.support import support_accumulate

        local = e1.shape[0]
        assert chunk >= 1 and local % chunk == 0, (local, chunk)
        S, _ = support_accumulate(
            e1, cand, lo, hi, N, Eid, chunk=chunk,
            n_chunks=local // chunk, iters=iters, m=m, interpret=interpret)
    else:
        hit, safe = wedge_common.probe(N, cand, lo, hi, iters=iters)
        # sentinel entries carry e1 == m
        inc = hit.astype(jnp.int32)
        S = jnp.zeros((m + 1,), jnp.int32)
        S = S.at[e1].add(inc)
        S = S.at[jnp.where(hit, Eid[cand], m)].add(inc)
        S = S.at[jnp.where(hit, Eid[safe], m)].add(inc)
    for ax in axes:
        S = jax.lax.psum(S, ax)
    return S[:m]


def make_pkt_dist(mesh: jax.sharding.Mesh, axes: Sequence[str], *, m: int,
                  two_m: int, table_size: int, iters: int,
                  chunk: int = 1 << 14):
    """Builds the jittable distributed PKT callable for dry-run or execution.

    Args are logical sizes; the returned fn takes
    (N, Eid, S0, e1, cand, lo, hi) full (global) arrays where the four table
    arrays are sharded over ``axes`` and the rest replicated.
    """
    spec_rep = P()
    spec_sh = P(tuple(axes))

    fn = shard_map(
        functools.partial(_dist_peel_body, m=m, iters=iters, chunk=chunk,
                          axes=axes),
        mesh=mesh,
        in_specs=(spec_rep, spec_rep, spec_rep, spec_sh, spec_sh, spec_sh,
                  spec_sh),
        out_specs=(spec_rep, spec_rep, spec_rep),
        check_vma=False,
    )
    return jax.jit(fn)


def make_support_dist(mesh: jax.sharding.Mesh, axes: Sequence[str], *, m: int,
                      iters: int, mode: str = "jnp", chunk: int = 0,
                      interpret: bool = True):
    """Jitted shard_map support computation over ``mesh`` (DESIGN.md §6).

    Wedge-table shards live per-device along ``axes``; each device counts
    triangles for its shard against the replicated CSR arrays and the
    results are psum-reduced to a replicated (m,) support vector.
    """
    spec_rep = P()
    spec_sh = P(tuple(axes))
    fn = shard_map(
        functools.partial(_dist_support_body, m=m, iters=iters, axes=axes,
                          mode=mode, chunk=chunk, interpret=interpret),
        mesh=mesh,
        in_specs=(spec_rep, spec_rep, spec_sh, spec_sh, spec_sh, spec_sh),
        out_specs=spec_rep,
        check_vma=False,
    )
    return jax.jit(fn)


def _pad_to(x: np.ndarray, size: int, fill) -> np.ndarray:
    out = np.full(size, fill, x.dtype)
    out[: x.shape[0]] = x
    return out


def pkt_dist(g: CSRGraph, mesh: jax.sharding.Mesh | None = None,
             axes: Sequence[str] = ("data",), chunk: int = 1 << 12,
             support_mode: str = "jnp", table_mode: str = "device",
             interpret: bool | None = None):
    """Run distributed PKT on the available devices. Returns trussness (m,).

    ``support_mode`` selects the per-shard support executor ("jnp" or
    "pallas", see ``core.support.SUPPORT_MODES``); the peel phase is the
    sharded BSP loop in either case.  ``table_mode="device"`` (the default)
    builds both wedge tables with the jitted XLA builders directly at the
    shard-rounded padded sizes — the shard_map then redistributes
    device-resident slices instead of uploading host tables several× the
    graph size; "numpy" keeps the host builders as the parity oracle.
    """
    if support_mode not in support_mod.SUPPORT_MODES:
        raise ValueError(f"support_mode must be one of "
                         f"{support_mod.SUPPORT_MODES}, got {support_mode!r}")
    if table_mode not in support_mod.TABLE_MODES:
        raise ValueError(f"table_mode must be one of "
                         f"{support_mod.TABLE_MODES}, got {table_mode!r}")
    if mesh is None:
        dev = np.array(jax.devices())
        mesh = jax.sharding.Mesh(dev, ("data",))
        axes = ("data",)
    if interpret is None:
        interpret = wedge_common.interpret_default()
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    iters = support_mod._search_iters(g)
    gdev = g.device_arrays()

    s_size = support_mod.support_table_size(g)
    per_shard = max(1, -(-max(s_size, 1) // n_shards))
    sup_chunk = 0
    if support_mode == "pallas":
        # each shard lowers the kernel over its slice: the slice must be a
        # whole number of chunks, so round the per-shard length up to one
        sup_chunk = wedge_common.pow2_chunk(1 << 13, chunk)
        per_shard = -(-per_shard // sup_chunk) * sup_chunk
    ssize = per_shard * n_shards
    if table_mode == "device":
        support_mod._check_table_size(ssize)
        s_e1, s_cand, s_lo, s_hi, _ = support_mod._build_support_table_dev(
            gdev["El"][:, 0], gdev["El"][:, 1], gdev["Es"], gdev["Eo"],
            jnp.int32(g.m), m=g.m, size=ssize)
    else:
        stab = support_mod.build_support_table(g)
        s_e1 = jnp.asarray(_pad_to(stab.e1, ssize, g.m))
        s_cand = jnp.asarray(_pad_to(stab.cand_slot, ssize, 0))
        s_lo = jnp.asarray(_pad_to(stab.lo, ssize, 0))
        s_hi = jnp.asarray(_pad_to(stab.hi, ssize, 0))
    sup_fn = make_support_dist(mesh, axes, m=g.m, iters=iters,
                               mode=support_mode, chunk=sup_chunk,
                               interpret=interpret)
    S0 = sup_fn(gdev["N"], gdev["Eid"], s_e1, s_cand, s_lo, s_hi)

    p_size = support_mod.peel_table_size(g)
    per = max(chunk, -(-max(p_size, 1) // n_shards))
    per = -(-per // chunk) * chunk           # round to chunk multiple
    psize = per * n_shards
    if table_mode == "device":
        support_mod._check_table_size(psize)
        p_e1, p_cand, p_lo, p_hi, _off, _cs, _ce, _has = \
            support_mod._build_peel_table_dev(
                gdev["El"][:, 0], gdev["El"][:, 1], gdev["Es"],
                jnp.int32(g.m), m=g.m, size=psize, chunk=chunk)
    else:
        ptab = support_mod.build_peel_table(g)
        p_e1 = jnp.asarray(_pad_to(ptab.e1, psize, g.m))
        p_cand = jnp.asarray(_pad_to(ptab.cand_slot, psize, 0))
        p_lo = jnp.asarray(_pad_to(ptab.lo, psize, 0))
        p_hi = jnp.asarray(_pad_to(ptab.hi, psize, 0))
    peel_fn = make_pkt_dist(mesh, axes, m=g.m, two_m=2 * g.m,
                            table_size=psize, iters=iters, chunk=chunk)
    S, levels, subs = peel_fn(gdev["N"], gdev["Eid"], S0,
                              p_e1, p_cand, p_lo, p_hi)
    return np.asarray(S).astype(np.int64) + 2
