"""Truss community index — the nested triangle-connected k-truss hierarchy.

The paper motivates truss decomposition by community detection, and Wang &
Cheng (Truss Decomposition in Massive Networks) define the query object that
serving actually needs: a *k-truss community* is a triangle-connected
component of the edges with trussness >= k — two edges belong together iff
they are linked by a chain of triangles all of whose edges survive at level
k.  Sariyuce et al. (Local Algorithms for Hierarchical Dense Subgraph
Discovery) observe these components nest as k grows, so the right serving
structure is a *hierarchy index* built once per decomposition and queried
many times (DESIGN.md §11):

  * **Per-level labels** — for each level k in [2, k_max], every live edge
    (trussness >= k) carries the id of the *minimum edge in its
    triangle-connected component*.  The min-id representative makes the
    labeling canonical: any correct builder produces bitwise-identical
    arrays, which is what the device/host parity gate checks.
  * **Parent links** — level-k communities refine level-(k-1) communities
    (every active-at-k triangle is active at k-1), so each community's
    parent is just the (k-1)-label of its representative edge.
  * **Two builders, one contract** (the PR-4 ``table_mode`` pattern):
    ``mode="device"`` floods min-labels over the triangle rows with a jitted
    scatter-min + pointer-jumping loop (O(log diameter) rounds);
    ``build_all`` runs a peel-order level sweep, finest level first, where
    each level warm-starts from the next-finer labels and a host-side
    convergence pre-check skips the dispatch entirely when the warm labels
    are already the fixed point (DESIGN.md §16 has the parity argument).
    ``mode="host"`` is an independent union-find oracle (union-by-min over
    triangles sorted by level, shared across levels top-down).  Both
    converge to the same canonical labels.

Triangle connectivity comes from the decomposition's triangle list — the
same (T, 3) edge-id rows the wedge-table pipeline enumerates
(``core.truss_inc.triangle_list``) and that incremental handles already
maintain across updates, so a handle's index build does zero extra triangle
work.

Levels build lazily and cache; ``core/truss_inc.py`` keeps a handle's index
alive across ``update`` batches by remapping the untouched high levels
(edge-id translation only) and marking the levels the repair could have
reached (k <= ``k_hi``) dirty for lazy rebuild — see
``TrussHierarchy.remapped``.  The serving wrapper is
``serve.truss_engine.TrussHandle.communities / community``.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.testing.chaos import fault_point

#: where per-level labels are computed: jitted label propagation on device
#: (the serving path) or the independent host union-find (the parity oracle)
HIER_MODES = ("device", "host")


# ------------------------------------------------------- device label flood --

def _labelprop_jit_factory():
    """Build the jitted per-level label-propagation function lazily so the
    module imports without jax (numpy-only contexts use mode="host")."""
    import jax
    import jax.numpy as jnp

    @functools.partial(jax.jit, static_argnames=("sz", "mp"))
    def _labelprop(tri_all, lvl_all, start, k, L0, *, sz: int, mp: int):
        """Min-label flood over the *representative graph* to the fixed point.

        ``tri_all``/``lvl_all`` are the full level-sorted triangle table and
        its per-row levels (min member trussness); the flood runs on the
        ``sz``-row window at dynamic offset ``start`` (every row that can
        still merge components at level ``k`` — see the stratum windowing
        in ``_build_device``; slicing in-jit saves two eager dispatches per
        level).  ``k`` is the dynamic level, ``L0`` the (mp,) initial
        labels (live edges: any in-component id <= their own — warm starts
        pass a finer level's *flat* component minima; dead and padding
        slots: themselves).

        Each round gathers every active row's current representatives
        ``r = L[tri]``, scatter-mins the row's 3-way representative-label
        minimum into ``L[r]`` — the union step, expressed on the component
        graph so already-merged rows are no-ops — then pointer-jumps
        ``L <- min(L, L[L])``.  Labels only decrease and always point at
        in-component edge ids, so the fixed point is exactly the flat
        component-minimum labeling: at convergence ``L[L[e]] == L[e]``
        (labels are roots) and every active row's members share one root
        (DESIGN.md §16 gives the argument).  Warm-started levels converge
        in O(log merge-chain) rounds over only their fresh stratum.
        """
        tri = jax.lax.dynamic_slice(tri_all, (start, 0), (sz, 3))
        act = jax.lax.dynamic_slice(lvl_all, (start,), (sz,)) >= k
        sink = jnp.int32(mp - 1)

        def body(state):
            L, _ = state
            r = L[tri]
            lm = jnp.min(L[r], axis=1)
            idx = jnp.where(act[:, None], r, sink)
            lmw = jnp.where(act, lm, sink)
            L2 = (L.at[idx[:, 0]].min(lmw)
                   .at[idx[:, 1]].min(lmw)
                   .at[idx[:, 2]].min(lmw))
            L2 = jnp.minimum(L2, L2[L2])
            return L2, L

        def cond(state):
            L, prev = state
            return jnp.any(L != prev)

        L, _ = jax.lax.while_loop(cond, body, (L0, jnp.full_like(L0, -1)))
        return L

    return _labelprop


# Host-side flood seeding: active sets up to _SEED_ROWS_MAX rows run up to
# _SEED_ROUNDS of the flood body on the host (np.minimum.at is ~100
# ns/element, so larger sets would pay more on the host than the device
# rounds they save), skipping the device dispatch entirely when the rounds
# reach the flood's fixed point.  Larger levels with a small *fresh* stratum
# still get one host round folded into their warm start.
_SEED_ROWS_MAX = 4096
_SEED_ROUNDS = 2

_LABELPROP = None


def _labelprop_fns():
    global _LABELPROP
    if _LABELPROP is None:
        _LABELPROP = _labelprop_jit_factory()
    return _LABELPROP


# ------------------------------------------------------ host union-find oracle

def _uf_find(parent: np.ndarray, x: int) -> int:
    while parent[x] != x:
        parent[x] = parent[parent[x]]
        x = parent[x]
    return int(x)


def _uf_union_min(parent: np.ndarray, a: int, b: int) -> None:
    """Union with the *smaller root winning* — the component root is then
    always the component's minimum edge id, the canonical representative."""
    ra, rb = _uf_find(parent, a), _uf_find(parent, b)
    if ra != rb:
        if ra < rb:
            parent[rb] = ra
        else:
            parent[ra] = rb


def _uf_roots(parent: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Vectorized root lookup for an index array (no mutation needed for
    correctness; unions keep doing their own path compression)."""
    r = parent[idx]
    while True:
        rr = parent[r]
        if np.array_equal(rr, r):
            return r
        r = rr


def host_level_labels(m: int, trussness: np.ndarray, tri: np.ndarray,
                      tri_lvl: np.ndarray, k: int) -> np.ndarray:
    """One level's labels by a fresh union-find — the standalone oracle."""
    labels = np.full(m, -1, np.int64)
    live = np.nonzero(trussness >= k)[0]
    if live.size == 0:
        return labels
    parent = np.arange(m, dtype=np.int64)
    for a, b, c in tri[tri_lvl >= k]:
        _uf_union_min(parent, int(a), int(b))
        _uf_union_min(parent, int(a), int(c))
    labels[live] = _uf_roots(parent, live)
    return labels


# --------------------------------------------------------------- the index --

class TrussHierarchy:
    """Nested k-truss community index over one finished decomposition.

    Construct from per-edge ``trussness`` (aligned to the graph's canonical
    edge rows) and the (T, 3) triangle list in the same edge-id space.
    Levels are k = 2 .. ``k_max``; each builds lazily on first access and is
    cached.  ``stats`` counts the work actually done (levels built per mode,
    levels carried across updates by remap, flood rounds are implicit in the
    device dispatch).
    """

    def __init__(self, trussness: np.ndarray, triangles: np.ndarray, *,
                 mode: str = "device", interpret: bool | None = None):
        if mode not in HIER_MODES:
            raise ValueError(
                f"mode must be one of {HIER_MODES}, got {mode!r}")
        self.mode = mode
        self.interpret = interpret  # accepted for symmetry; flood is pure XLA
        self.T = np.asarray(trussness, dtype=np.int64)
        self.m = int(self.T.shape[0])
        tri = np.asarray(triangles, dtype=np.int64)
        if tri.size == 0:
            tri = np.zeros((0, 3), np.int64)
        if tri.size and int(tri.max()) >= self.m:
            raise ValueError(
                f"triangle row references edge id {int(tri.max())} beyond "
                f"m={self.m}")
        self.tri = tri
        self.tri_lvl = (self.T[tri].min(axis=1) if tri.size
                        else np.zeros(0, np.int64))
        self.k_max = int(self.T.max(initial=1))
        self._labels: list[np.ndarray | None] = \
            [None] * max(0, self.k_max - 1)
        self._dev = None          # (tri_dev, lvl_dev, mp) device upload cache
        self._uf = None           # (parent, order, ptr, k_at) host UF state
        self.stats = {"device_levels": 0, "host_levels": 0,
                      "remapped_levels": 0, "converged_levels": 0,
                      "seeded_levels": 0}

    # ---------------------------------------------------------- level access

    @property
    def levels(self) -> range:
        """The populated levels: k = 2 .. k_max (empty when m == 0)."""
        return range(2, self.k_max + 1)

    def level_labels(self, k: int) -> np.ndarray:
        """(m,) int64 labels at level ``k``: for each edge with trussness
        >= k the minimum edge id of its triangle-connected component, else
        -1.  Built lazily (and cached) by the configured ``mode``."""
        k = int(k)
        if k < 2 or k > self.k_max:
            return np.full(self.m, -1, np.int64)
        li = k - 2
        if self._labels[li] is None:
            self._labels[li] = (self._build_device(k) if self.mode == "device"
                                else self._build_host(k))
        return self._labels[li]

    def build_all(self) -> "TrussHierarchy":
        """Materialize every level eagerly, finest (highest k) first.

        Both modes sweep the same peel order: device mode warm-starts every
        level from the next-finer labels and skips the dispatch when the
        convergence pre-check proves the warm start is already the fixed
        point (the index-build cost ``benchmarks/hier_bench.py`` measures);
        host mode extends the shared top-down union-find with exactly each
        level's own triangle stratum (never a fresh rebuild).
        """
        for k in sorted(self.levels, reverse=True):
            if self._labels[k - 2] is None:
                self.level_labels(k)
        return self

    # ------------------------------------------------------------- queries --

    def communities(self, k: int) -> list[np.ndarray]:
        """Sorted edge-id arrays of every level-``k`` community, ordered by
        representative (= minimum member) edge id."""
        labels = self.level_labels(k)
        live = np.nonzero(labels >= 0)[0]
        if live.size == 0:
            return []
        order = np.argsort(labels[live], kind="stable")
        live = live[order]
        cuts = np.nonzero(np.diff(labels[live]))[0] + 1
        return np.split(live, cuts)

    def community_of(self, edge_id: int, k: int) -> np.ndarray:
        """Edge ids of the level-``k`` community containing ``edge_id``
        (empty when the edge is below level k)."""
        labels = self.level_labels(k)
        edge_id = int(edge_id)
        if not 0 <= edge_id < self.m or labels[edge_id] < 0:
            return np.zeros(0, np.int64)
        return np.nonzero(labels == labels[edge_id])[0].astype(np.int64)

    def parents(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        """(reps, parent_reps): each level-``k`` community's representative
        and the representative of the level-(k-1) community containing it.
        At k == 2 the parents array equals the reps (no coarser level)."""
        labels = self.level_labels(k)
        reps = np.unique(labels[labels >= 0])
        if k <= 2 or reps.size == 0:
            return reps, reps.copy()
        return reps, self.level_labels(k - 1)[reps]

    # ------------------------------------------------------- device builder --

    def _pad_dims(self) -> tuple[int, int]:
        from repro.kernels.wedge_common import next_pow2

        # Labels are pure jnp (no pallas tiling), so the label array only
        # needs *size-class* padding for compile reuse, not a full pow2:
        # round m+1 up to the nearest of {0.75 * 2^b, 2^b}.  Half-step
        # classes keep the O(log m) distinct compiled shapes while capping
        # padding waste at 33% instead of 100% (m itself a pow2 is common).
        p = max(8, next_pow2(self.m + 1))
        mp = 3 * p // 4 if self.m + 1 <= 3 * p // 4 else p
        tp = max(8, next_pow2(max(1, self.tri.shape[0])))
        return mp, tp

    def _device_tables(self):
        """Upload the padded triangle table once per hierarchy.

        Rows are sorted by level *descending* (stable), so the rows active
        at any level ``k`` form a prefix — each flood then dispatches on the
        pow2-padded active prefix only, instead of streaming the whole
        table per level.  Scatter-min is order-insensitive, so the
        reordering cannot change any label.
        """
        if self._dev is None:
            import jax.numpy as jnp

            mp, tp = self._pad_dims()
            order = np.argsort(-self.tri_lvl, kind="stable")
            tri = np.full((tp, 3), mp - 1, np.int32)
            tri[: self.tri.shape[0]] = self.tri[order]
            lvl = np.full(tp, -1, np.int32)
            lvl[: self.tri.shape[0]] = self.tri_lvl[order]
            self._dev = (jnp.asarray(tri), jnp.asarray(lvl), mp)
        return self._dev

    def _warm_level(self, k: int) -> int:
        """Nearest already-built level finer than ``k`` (``k_max + 1`` when
        nothing finer is built — the cold, finest-level case)."""
        for jj in range(k + 1, self.k_max + 1):
            if self._labels[jj - 2] is not None:
                return jj
        return self.k_max + 1

    def _init_labels(self, k: int, mp: int, j: int) -> np.ndarray:
        """Initial (mp,) int32 labels for level ``k`` warm-started from
        level ``j`` (see ``_warm_level``): live edges take the finer
        level's labels where defined (in-component ids, so the flood only
        has fewer rounds to run); dead and padding slots point at
        themselves."""
        L0 = np.arange(mp, dtype=np.int32)
        if j <= self.k_max:
            warm = self._labels[j - 2]
            fine = warm >= 0
            L0[:self.m][fine] = warm[fine]
        dead = self.T < k
        L0[:self.m][dead] = np.nonzero(dead)[0]
        return L0

    def _build_device(self, k: int) -> np.ndarray:
        fault_point("hierarchy", rung="device")
        j = self._warm_level(k)
        fresh = (self.tri_lvl >= k) & (self.tri_lvl < j)
        if not fresh.any():
            # Empty-stratum shortcut: no triangle enters between j and k,
            # so no merge is possible — level k's labels are level j's plus
            # self-labels for the newly live (triangle-isolated at k)
            # edges.  Skips the O(m) label-array construction entirely.
            self.stats["converged_levels"] += 1
            if j <= self.k_max:
                labels = self._labels[j - 2].copy()
                newly = (self.T >= k) & (labels < 0)
            else:
                labels = np.full(self.m, -1, np.int64)
                newly = self.T >= k
            labels[newly] = np.nonzero(newly)[0]
            return labels
        mp, _ = self._pad_dims()
        L0 = self._init_labels(k, mp, j)
        hi = int(np.count_nonzero(self.tri_lvl >= k))
        if hi <= _SEED_ROWS_MAX:
            # Tiny active sets pay more in per-round device dispatch latency
            # than their arithmetic is worth, so run up to _SEED_ROUNDS of
            # the *exact* flood body on the host — gather representatives
            # ``r = L0[tri]``, scatter-min each row's 3-way representative-
            # label minimum into ``L0[r]``, pointer-jump — checking the
            # flood's own fixed-point condition between rounds (every active
            # row's representative labels homogeneous, L0 flat under the
            # jump).  When the check passes the while_loop body is the
            # identity, so skipping the dispatch returns bitwise-exactly
            # what the device would; when the rounds run out the seeded L0
            # ships to the device flood, which converges to the canonical
            # component minima from any in-component lower bound (§16).
            tra = self.tri[self.tri_lvl >= k]
            for seeds in range(_SEED_ROUNDS + 1):
                r = L0[tra]
                rl = L0[r]
                lm = rl.min(axis=1)
                if (bool((lm == rl.max(axis=1)).all())
                        and bool((L0[L0] >= L0).all())):
                    key = "seeded_levels" if seeds else "converged_levels"
                    self.stats[key] += 1
                    return self._finish(L0, k)
                if seeds == _SEED_ROUNDS:
                    break
                np.minimum.at(L0, r.ravel(), np.repeat(lm, 3))
                np.minimum(L0, L0[L0], out=L0)
        else:
            # Convergence pre-check (host, O(rows newly active since the
            # warm level)): rows active at the warm level j are triangle-
            # connected at j, so their three edges share one warm component
            # minimum; if every *newly* active row (k <= tri_lvl < j) is
            # also label-homogeneous under L0, the scatter-min pass cannot
            # change any label.  L0 is idempotent by construction (warm
            # labels are component minima at j, everything else
            # self-labels), so the pointer jump is a no-op too: L0 is the
            # flood's exact fixed point and the dispatch can be skipped
            # bitwise-safely (DESIGN.md §16).
            rows = L0[self.tri[fresh]]
            if bool((rows.min(axis=1) == rows.max(axis=1)).all()):
                self.stats["converged_levels"] += 1
                return self._finish(L0, k)
            if rows.shape[0] <= _SEED_ROWS_MAX:
                # Fold one flood round over the fresh stratum into the
                # warm start (the full active set is too large to check a
                # fixed point on, so no skip — the seed just spares the
                # device its first merge round).
                rl = L0[rows]
                lm = rl.min(axis=1)
                np.minimum.at(L0, rows.ravel(), np.repeat(lm, 3))
                np.minimum(L0, L0[L0], out=L0)
        import jax.numpy as jnp

        labelprop = _labelprop_fns()
        tri_dev, lvl_dev, _ = self._device_tables()
        # Dispatch on the *fresh stratum* window only: the device rows are
        # sorted by level descending, so rows entering between the warm
        # level j and this level k occupy positions [count(lvl >= j),
        # count(lvl >= k)).  Rows finer than the window are no-ops under a
        # warm start (their members already share a flat label) and rows
        # coarser than it are masked by the flood's own ``tri_lvl >= k``
        # predicate, so pow2-rounding the window backward is bitwise-safe
        # while bounding distinct compiled flood shapes to O(log T).
        from repro.kernels.wedge_common import next_pow2

        lo = int(np.count_nonzero(self.tri_lvl >= j))
        sz = min(int(tri_dev.shape[0]), max(8, next_pow2(hi - lo)))
        start = max(0, hi - sz)
        L = labelprop(tri_dev, lvl_dev, jnp.int32(start), jnp.int32(k),
                      jnp.asarray(L0), sz=sz, mp=mp)
        self.stats["device_levels"] += 1
        return self._finish(np.asarray(L), k)

    def _finish(self, L: np.ndarray, k: int) -> np.ndarray:
        labels = L[: self.m].astype(np.int64)
        labels[self.T < k] = -1
        return labels

    # --------------------------------------------------------- host builder --

    def _build_host(self, k: int) -> np.ndarray:
        """Shared top-down union-find: triangles sorted by level descending
        are unioned once in total across all levels; each level snapshot is
        a vectorized root lookup.  The shared state is only valid while
        requests descend — once it has advanced past level ``k`` its
        partition includes unions from coarser levels, so a request *above*
        the frontier answers from a fresh single-level union-find instead
        (``build_all`` walks levels coarse-to-fine, paying the shared cost
        exactly once)."""
        fault_point("hierarchy", rung="host")
        self.stats["host_levels"] += 1
        if self._uf is not None and k > self._uf["k_at"]:
            return host_level_labels(self.m, self.T, self.tri,
                                     self.tri_lvl, k)
        if self._uf is None:
            order = np.argsort(-self.tri_lvl, kind="stable")
            self._uf = {"parent": np.arange(self.m, dtype=np.int64),
                        "order": order, "ptr": 0,
                        "k_at": self.k_max + 1}
        uf = self._uf
        parent, order = uf["parent"], uf["order"]
        ptr = uf["ptr"]
        while ptr < order.size and self.tri_lvl[order[ptr]] >= k:
            a, b, c = self.tri[order[ptr]]
            _uf_union_min(parent, int(a), int(b))
            _uf_union_min(parent, int(a), int(c))
            ptr += 1
        uf["ptr"] = ptr
        uf["k_at"] = k
        labels = np.full(self.m, -1, np.int64)
        live = np.nonzero(self.T >= k)[0]
        if live.size:
            labels[live] = _uf_roots(parent, live)
        return labels

    # -------------------------------------------------- update survival ------

    def remapped(self, trussness: np.ndarray, triangles: np.ndarray,
                 old_to_new: np.ndarray, k_hi: int) -> "TrussHierarchy":
        """The index after a *local* repair touched nothing above ``k_hi``.

        ``old_to_new`` maps this index's edge ids to the post-update ids
        (-1 for deleted edges).  Levels k > ``k_hi`` have an unchanged
        edge set and active-triangle set — every inserted/deleted edge and
        every trussness change sits at or below ``k_hi``, and a triangle's
        level is the min over its members — so their partition survives
        verbatim; only the ids need translating.  Canonical-form bonus: the
        surviving edges keep their relative order under the key-sorted id
        space, so the old component minimum maps exactly onto the new one
        and the translated labels stay canonical without a re-scan.  Levels
        <= ``k_hi`` come back dirty and rebuild lazily.
        """
        h = TrussHierarchy(trussness, triangles, mode=self.mode,
                           interpret=self.interpret)
        old_to_new = np.asarray(old_to_new, dtype=np.int64)
        for k in range(max(int(k_hi) + 1, 2), h.k_max + 1):
            old = (self._labels[k - 2]
                   if k - 2 < len(self._labels) else None)
            if old is None:
                continue
            src = np.nonzero(old >= 0)[0]
            dst = old_to_new[src]
            if dst.size and dst.min(initial=0) < 0:
                # defensive: a live-above-k_hi edge vanished — the caller's
                # k_hi was wrong; fall back to a dirty level
                continue
            lab = np.full(h.m, -1, np.int64)
            lab[dst] = old_to_new[old[src]]
            h._labels[k - 2] = lab
            h.stats["remapped_levels"] += 1
        return h


def hierarchy_from_graph(g, trussness: np.ndarray, *,
                         mode: str = "device") -> TrussHierarchy:
    """Index a plain (graph, trussness) pair — enumerates the triangle list
    first.  Handles (``TrussEngine.open``) skip this: they already maintain
    the triangle list incrementally."""
    from repro.core.truss_inc import triangle_list

    return TrussHierarchy(trussness, triangle_list(g), mode=mode)
