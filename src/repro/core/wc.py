"""WC: the Wang–Cheng sequential truss decomposition (paper Algorithm 1).

This is the paper's sequential baseline: hash-table adjacency, bucket-sorted
edges with O(1) reordering (the Batagelj–Zaversnik trick), ascending-support
peeling one edge at a time. Implemented faithfully in numpy + dicts — it is
*meant* to exhibit the hash-table and sequential-processing costs that PKT
removes, and doubles as an independent oracle.
"""

from __future__ import annotations

import numpy as np

from repro.graphs.csr import CSRGraph


def truss_wc(g: CSRGraph) -> np.ndarray:
    """Returns trussness per edge id (aligned with g.El). O(m^1.5)."""
    m, n = g.m, g.n
    if m == 0:
        return np.zeros(0, np.int64)

    # hash table: (u, v) -> edge id, u < v   (paper's Eh)
    eh: dict[tuple[int, int], int] = {}
    for e in range(m):
        u, v = int(g.El[e, 0]), int(g.El[e, 1])
        eh[(u, v)] = e
    adj: list[set[int]] = [set() for _ in range(n)]
    for u, v in g.El:
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))

    # support via intersection (the WC paper computes it the same way)
    S = np.zeros(m, dtype=np.int64)
    for e in range(m):
        u, v = int(g.El[e, 0]), int(g.El[e, 1])
        if len(adj[u]) > len(adj[v]):
            u, v = v, u
        S[e] = sum(1 for w in adj[u] if w in adj[v])

    # bucket structure over support for O(1) "Reorder El"
    max_s = int(S.max(initial=0))
    bin_start = np.zeros(max_s + 2, dtype=np.int64)
    np.add.at(bin_start, S + 1, 1)
    bin_start = np.cumsum(bin_start)
    pos = np.zeros(m, dtype=np.int64)
    el_sorted = np.zeros(m, dtype=np.int64)
    fill = bin_start[:-1].copy()
    for e in range(m):
        pos[e] = fill[S[e]]
        el_sorted[pos[e]] = e
        fill[S[e]] += 1
    bin_ptr = bin_start[:-1].copy()  # current start of each bucket

    truss = np.zeros(m, dtype=np.int64)
    removed = np.zeros(m, dtype=bool)

    def decrease(e2: int, k: int) -> None:
        """S[e2] -= 1 with bucket maintenance, never below k."""
        if S[e2] <= k:
            return
        s2 = int(S[e2])
        p2 = int(pos[e2])
        pw = int(bin_ptr[s2])
        w_ = int(el_sorted[pw])
        if e2 != w_:
            el_sorted[p2], el_sorted[pw] = w_, e2
            pos[e2], pos[w_] = pw, p2
        bin_ptr[s2] += 1
        S[e2] -= 1

    for i in range(m):
        e = int(el_sorted[i])
        k = int(S[e])
        u, v = int(g.El[e, 0]), int(g.El[e, 1])
        if len(adj[u]) > len(adj[v]):
            u, v = v, u
        for w in list(adj[u]):
            if w in adj[v]:
                e2 = eh[(min(v, w), max(v, w))]
                e3 = eh[(min(u, w), max(u, w))]
                if removed[e2] or removed[e3]:
                    continue
                decrease(e2, k)
                decrease(e3, k)
        truss[e] = k + 2
        removed[e] = True
        adj[u].discard(v)
        adj[v].discard(u)

    return truss
