"""PKT — level-synchronous parallel truss decomposition (paper Algorithms 4+5).

JAX/TPU adaptation of the OpenMP original (see DESIGN.md §2 for the mapping):

  * SCAN            → dense masked compare over the support vector S
  * curr/next       → boolean frontier vectors (inCurr/processed); the "next"
                      buffer is recovered as  alive ∧ (S == l)  after update
  * atomicSub+clamp → masked per-wedge decrement contributions aggregated with
                      scatter-add, then  S ← max(S − dec, l)  (identical fixed
                      point, bitwise deterministic)
  * tie-break       → the paper's "lowest frontier edge id processes the
                      triangle" predicate evaluated vectorially per wedge hit
  * dynamic sched.  → chunk-skipping: the flat peel-wedge table is cut into
                      fixed chunks; a sub-level only visits chunks overlapping
                      frontier edges' ranges (work-efficiency: each triangle's
                      wedge entries are scanned O(1) times over the whole run)

Three peel modes (``mode`` / ``peel_mode``):
  mode="chunked" (default): work-efficient chunk-skipping while_loop.
  mode="dense":  every sub-level scans the whole wedge table with frontier
                 masking — the naive SPMD port, kept as a benchmark foil.
  mode="pallas": the chunk scan runs as a VMEM-blocked Pallas kernel
                 (kernels/peel.py) — one wedge-table chunk per grid step,
                 chunk-skipping degraded to compute masking (grids are
                 static).  Bitwise-identical results to the other two modes.

The support phase has its own independent executor axis
(``support_mode`` ∈ ``core.support.SUPPORT_MODES``): "jnp" is the flat XLA
program, "pallas" the chunked kernel in kernels/support.py.  Any
(support_mode × peel_mode) combination is valid and all six produce
bitwise-identical trussness (tests/test_parity_matrix.py asserts it).

The peel loop is written against *padded* edge state so the batched engine
(serve/truss_engine.py) can vmap it across many graphs of one size class:
slot ``m`` is the sentinel, and any edge slot marked processed in
``processed0`` with sentinel support in ``S_ext0`` is inert padding.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from repro.core import support as support_mod
from repro.kernels import wedge_common

_SENTINEL_S = jnp.int32(1 << 30)

PEEL_MODES = ("chunked", "dense", "pallas")


class PeelTables(NamedTuple):
    """Device-resident static tables for the peel phase (padded to chunks)."""

    e1: jnp.ndarray         # (n_chunks*C,) int32, sentinel m
    cand_slot: jnp.ndarray  # (n_chunks*C,) int32, sentinel 0
    lo: jnp.ndarray         # (n_chunks*C,) int32, sentinel 0
    hi: jnp.ndarray         # (n_chunks*C,) int32, sentinel 0  (lo==hi → miss)
    c_start: jnp.ndarray    # (m,) int32   first chunk containing edge e
    c_end: jnp.ndarray      # (m,) int32   last chunk containing edge e (inclusive)
    has_entries: jnp.ndarray  # (m,) bool


@dataclasses.dataclass(frozen=True)
class PKTResult:
    trussness: np.ndarray   # (m,) int32, >= 2
    support: np.ndarray     # (m,) int32 initial support
    levels: int             # number of peel levels executed
    sublevels: int          # total sub-level iterations (paper's S)


def chunk_ranges(off: np.ndarray, chunk: int,
                 m_out: int | None = None) -> tuple[np.ndarray, np.ndarray,
                                                    np.ndarray]:
    """Per-edge chunk-range bookkeeping from a wedge-table offset array.

    Returns (has_entries, c_start, c_end), each of length ``m_out`` (edges
    beyond ``off``'s m are inert padding: no entries, range 0).  Shared by
    the single-graph tables and the batched engine so the two paths cannot
    drift.
    """
    m = off.shape[0] - 1
    m_out = m if m_out is None else m_out
    has = np.zeros(m_out, bool)
    c_start = np.zeros(m_out, np.int32)
    c_end = np.zeros(m_out, np.int32)
    if m == 0 or off[-1] == 0:
        # explicit early-exit: empty graph, or a table with no entries
        # (triangle-free orientation) — every edge has an empty chunk range
        return has, c_start, c_end
    has[:m] = off[1:] > off[:-1]
    c_start[:m] = off[:-1] // chunk
    c_end[:m] = np.maximum(off[1:] - 1, 0) // chunk
    return has, c_start, c_end


def _pad_tables(tab: support_mod.WedgeTable, m: int, chunk: int,
                n_chunks: int) -> PeelTables:
    e1, cand, lo, hi = wedge_common.pad_chunked(
        tab.e1, tab.cand_slot, tab.lo, tab.hi,
        m=m, chunk=chunk, n_chunks=n_chunks)
    has, c_start, c_end = chunk_ranges(tab.off, chunk)
    return PeelTables(
        e1=jnp.asarray(e1), cand_slot=jnp.asarray(cand),
        lo=jnp.asarray(lo), hi=jnp.asarray(hi),
        c_start=jnp.asarray(c_start), c_end=jnp.asarray(c_end),
        has_entries=jnp.asarray(has),
    )


def prepare_peel(tab: support_mod.WedgeTable, m: int,
                 chunk: int) -> tuple[PeelTables, int, int]:
    """Clamp ``chunk`` to the table, pad, and derive ``n_chunks``.

    The single place where the chunk size is sanitized (the layout policy
    itself lives in ``kernels.wedge_common.chunk_layout``): a user-passed
    chunk larger than the (padded) table, zero, or negative is clamped so
    that ``n_chunks >= 1`` always holds — tiny graphs (m <= 2, a handful of
    wedge entries) used to be able to reach ``n_chunks == 0`` through the
    old call-site-local ``min(chunk, size)`` dance.

    A table with no entries at all — the empty graph (m == 0), or a support
    table of a triangle-free orientation — takes an explicit early-exit
    rather than relying on the clamping arithmetic: one all-padding chunk of
    size 1, every edge marked entry-less.
    """
    if tab.size == 0:
        tabs = PeelTables(
            e1=jnp.full((1,), m, jnp.int32),
            cand_slot=jnp.zeros((1,), jnp.int32),
            lo=jnp.zeros((1,), jnp.int32),
            hi=jnp.zeros((1,), jnp.int32),
            c_start=jnp.zeros((m,), jnp.int32),
            c_end=jnp.zeros((m,), jnp.int32),
            has_entries=jnp.zeros((m,), jnp.bool_),
        )
        return tabs, 1, 1
    chunk, n_chunks = wedge_common.chunk_layout(tab.size, chunk)
    tabs = _pad_tables(tab, m, chunk, n_chunks)
    assert tabs.e1.shape[0] == n_chunks * chunk
    return tabs, chunk, n_chunks


def _active_chunk_mask(inCurr, tabs: PeelTables, m: int, n_chunks: int):
    """Chunks overlapping any frontier edge's wedge-entry range (bool mask)."""
    curr_edges = inCurr[:m] & tabs.has_entries
    delta = jnp.zeros((n_chunks + 1,), jnp.int32)
    delta = delta.at[jnp.where(curr_edges, tabs.c_start, n_chunks)].add(
        curr_edges.astype(jnp.int32))
    delta = delta.at[jnp.where(curr_edges, tabs.c_end + 1, n_chunks)].add(
        -curr_edges.astype(jnp.int32))
    return jnp.cumsum(delta[:n_chunks]) > 0


def _peel_loop(N, Eid, S_ext0, processed0, tabs: PeelTables, *, m: int,
               chunk: int, n_chunks: int, iters: int, mode: str,
               interpret: bool = True, pinned=None):
    """Full level/sub-level peel over extended (m+1,) edge state.

    ``S_ext0``/``processed0`` define which slots are live: slot m must be the
    processed sentinel, and callers may pre-mark extra padding slots as
    processed (batched engine).  Returns (S_ext[:m], levels, sublevels).

    ``pinned`` (optional (m+1,) bool) marks *schedule* edges: they enter the
    frontier and process their triangles at exactly their initial support
    level, but never receive decrements themselves — the incremental layer
    (core/truss_inc.py) uses this to replay the known death level of
    boundary edges whose trussness is already final.  Slot m must be False.
    """

    def chunk_contrib(c, dec, S_ext, processed, inCurr, l):
        """Decrement contributions from one chunk of the wedge table."""
        base = c * chunk
        e1 = jax.lax.dynamic_slice(tabs.e1, (base,), (chunk,))
        cand = jax.lax.dynamic_slice(tabs.cand_slot, (base,), (chunk,))
        lo = jax.lax.dynamic_slice(tabs.lo, (base,), (chunk,))
        hi = jax.lax.dynamic_slice(tabs.hi, (base,), (chunk,))
        in1 = inCurr[e1]
        hit, safe = wedge_common.probe(N, cand, lo, hi, iters=iters)
        e2 = Eid[cand]
        e3 = Eid[safe]
        valid = in1 & hit & ~processed[e2] & ~processed[e3]
        s2 = S_ext[e2]
        s3 = S_ext[e3]
        in2 = inCurr[e2]
        in3 = inCurr[e3]
        dec2 = valid & (s2 > l) & ((~in3) | (e1 < e3))
        dec3 = valid & (s3 > l) & ((~in2) | (e1 < e2))
        if pinned is not None:
            dec2 = dec2 & ~pinned[e2]
            dec3 = dec3 & ~pinned[e3]
        dec = dec.at[jnp.where(dec2, e2, m)].add(dec2.astype(jnp.int32))
        dec = dec.at[jnp.where(dec3, e3, m)].add(dec3.astype(jnp.int32))
        return dec

    def sublevel(S_ext, processed, inCurr, l):
        """One ProcessSubLevel: aggregate decrements, apply, mark processed."""
        dec0 = jnp.zeros((m + 1,), jnp.int32)
        if mode == "dense":
            def body(c, dec):
                return chunk_contrib(c, dec, S_ext, processed, inCurr, l)
            dec = jax.lax.fori_loop(0, n_chunks, body, dec0)
        elif mode == "pallas":
            from repro.kernels.peel import peel_decrement_targets
            active = _active_chunk_mask(inCurr, tabs, m, n_chunks)
            tgt2, tgt3 = peel_decrement_targets(
                active.astype(jnp.int32),
                jnp.reshape(l, (1,)).astype(jnp.int32),
                tabs.e1, tabs.cand_slot, tabs.lo, tabs.hi, N, Eid,
                S_ext, processed.astype(jnp.int32),
                inCurr.astype(jnp.int32),
                chunk=chunk, n_chunks=n_chunks, iters=iters, m=m,
                interpret=interpret)
            if pinned is not None:
                # redirect suppressed targets to the absorbing sentinel slot
                tgt2 = jnp.where(pinned[tgt2], m, tgt2)
                tgt3 = jnp.where(pinned[tgt3], m, tgt3)
            dec = dec0.at[tgt2].add(1).at[tgt3].add(1)
        else:  # chunked: visit only chunks overlapping the frontier
            active = _active_chunk_mask(inCurr, tabs, m, n_chunks)
            n_active = jnp.sum(active.astype(jnp.int32))
            (ids,) = jnp.nonzero(active, size=n_chunks, fill_value=n_chunks - 1)

            def body(i, dec):
                return chunk_contrib(ids[i], dec, S_ext, processed, inCurr, l)

            def cond(state):
                i, _ = state
                return i < n_active

            def wbody(state):
                i, dec = state
                return i + 1, body(i, dec)

            _, dec = jax.lax.while_loop(cond, wbody, (jnp.int32(0), dec0))

        S_ext = jnp.where(
            (~processed) & (~inCurr) & (dec > 0),
            jnp.maximum(S_ext - dec, l), S_ext)
        processed = processed | inCurr
        inCurr = (~processed) & (S_ext == l)
        inCurr = inCurr.at[m].set(False)
        return S_ext, processed, inCurr

    def level_body(state):
        S_ext, processed, l_done, todo, levels, subs = state
        alive_S = jnp.where(processed, _SENTINEL_S, S_ext)
        l = jnp.min(alive_S)  # skip-ahead to next populated level
        inCurr = (~processed) & (S_ext == l)
        inCurr = inCurr.at[m].set(False)

        def sub_cond(st):
            _, _, inC, subs_ = st
            return jnp.any(inC)

        def sub_body(st):
            S_ext, processed, inC, subs_ = st
            S_ext, processed, inC = sublevel(S_ext, processed, inC, l)
            return S_ext, processed, inC, subs_ + 1

        S_ext, processed, _, subs = jax.lax.while_loop(
            sub_cond, sub_body, (S_ext, processed, inCurr, subs))
        todo = (m + 1) - jnp.sum(processed.astype(jnp.int32))
        return S_ext, processed, l, todo, levels + 1, subs

    def level_cond(state):
        return state[3] > 0

    todo0 = (m + 1) - jnp.sum(processed0.astype(jnp.int32))
    state = (S_ext0, processed0, jnp.int32(0), todo0, jnp.int32(0),
             jnp.int32(0))
    S_ext, _, _, _, levels, subs = jax.lax.while_loop(
        level_cond, level_body, state)
    return S_ext[:m], levels, subs


@functools.partial(
    jax.jit,
    static_argnames=("m", "chunk", "n_chunks", "iters", "mode", "interpret"),
)
def _pkt_peel_jit(N, Eid, S0, tabs: PeelTables, *, m: int, chunk: int,
                  n_chunks: int, iters: int, mode: str = "chunked",
                  interpret: bool = True):
    """Runs the full level/sub-level peel; returns (S_final, levels, sublevels)."""
    # extended edge state: slot m is a sentinel (processed, never in frontier)
    S_ext0 = jnp.concatenate([S0.astype(jnp.int32), jnp.full((1,), _SENTINEL_S)])
    processed0 = jnp.zeros((m + 1,), jnp.bool_).at[m].set(True)
    return _peel_loop(N, Eid, S_ext0, processed0, tabs, m=m, chunk=chunk,
                      n_chunks=n_chunks, iters=iters, mode=mode,
                      interpret=interpret)


def pkt(g: CSRGraph, *, chunk: int = 1 << 14, mode: str = "chunked",
        peel_mode: str | None = None, support_mode: str = "jnp",
        support_table: support_mod.WedgeTable | None = None,
        peel_table: support_mod.WedgeTable | None = None,
        interpret: bool | None = None) -> PKTResult:
    """Full PKT truss decomposition. Returns trussness per edge (S+2).

    ``mode`` (alias ``peel_mode``, which wins when both are given) selects
    the peel executor and ``support_mode`` the support executor — the two
    axes are independent (see module docstring); ``interpret``
    forces/forbids Pallas interpret mode (default: interpret off-TPU).
    """
    mode = mode if peel_mode is None else peel_mode
    if mode not in PEEL_MODES:
        raise ValueError(f"mode must be one of {PEEL_MODES}, got {mode!r}")
    if support_mode not in support_mod.SUPPORT_MODES:
        raise ValueError(f"support_mode must be one of "
                         f"{support_mod.SUPPORT_MODES}, got {support_mode!r}")
    if g.m == 0:
        return PKTResult(np.zeros(0, np.int32), np.zeros(0, np.int32), 0, 0)
    if interpret is None:
        interpret = wedge_common.interpret_default()
    S0 = support_mod.compute_support(g, support_table, mode=support_mode,
                                     chunk=chunk, interpret=interpret)
    ptab = peel_table if peel_table is not None else support_mod.build_peel_table(g)
    tabs, chunk, n_chunks = prepare_peel(ptab, g.m, chunk)
    S, levels, subs = _pkt_peel_jit(
        jnp.asarray(g.N), jnp.asarray(g.Eid), jnp.asarray(S0), tabs,
        m=g.m, chunk=chunk, n_chunks=n_chunks,
        iters=support_mod._search_iters(g), mode=mode, interpret=interpret,
    )
    return PKTResult(
        trussness=np.asarray(S) + 2,
        support=np.asarray(S0),
        levels=int(levels),
        sublevels=int(subs),
    )


def align_to_input(trussness: np.ndarray, g: CSRGraph,
                   edges: np.ndarray | None, n: int, *,
                   keys: np.ndarray | None = None) -> np.ndarray:
    """Map per-``g.El``-row trussness back to the caller's edge order.

    ``edges`` must be the canonical (u<v) edge array ``g`` was built from
    (possibly in a different row order); ``g.El`` rows are lexicographically
    sorted, so each input edge is located by key search.  Callers that
    already hold per-row keys (``u*n + v`` in g's id space) may pass ``keys``
    instead of ``edges``.

    Every requested edge must actually be present in ``g.El``: a missing key
    raises a descriptive ValueError (``np.searchsorted`` alone would silently
    return the *insertion point* — a neighboring edge's trussness — or an
    out-of-range index when the key sorts past the end of the table).
    """
    key_g = g.El[:, 0].astype(np.int64) * n + g.El[:, 1]
    if keys is None:
        keys = edges[:, 0].astype(np.int64) * n + edges[:, 1]
    keys = np.asarray(keys, dtype=np.int64)
    if key_g.shape[0] == 0:
        if keys.shape[0] == 0:
            return np.zeros(0, np.int64)
        raise ValueError(
            f"cannot align {keys.shape[0]} edge(s) to an empty graph")
    pos = np.searchsorted(key_g, keys)
    safe = np.minimum(pos, key_g.shape[0] - 1)
    bad = (pos >= key_g.shape[0]) | (key_g[safe] != keys)
    if bad.any():
        k = int(keys[bad][0])
        raise ValueError(
            f"{int(bad.sum())} edge(s) not present in the graph's edge list; "
            f"first missing: ({k // n}, {k % n})")
    return trussness[pos].astype(np.int64)


def truss_pkt(edges: np.ndarray, *, reorder: bool = True,
              chunk: int = 1 << 14, mode: str = "chunked",
              support_mode: str = "jnp") -> np.ndarray:
    """Convenience entry: undirected edges → trussness aligned to input order.

    ``edges`` is any (k, 2) integer array: endpoint order is free and
    duplicate rows are allowed — rows are canonicalized and deduped exactly
    like ``TrussEngine.submit`` before decomposition, and the result is
    mapped back so ``out[i]`` is the trussness of ``edges[i]`` whatever its
    form.  Self-loops, negative vertex ids, and ids beyond the int32 CSR /
    int64 key-packing bounds are rejected with a clear error (they used to
    corrupt the decomposition silently).

    With ``reorder`` (the paper's preprocessing) vertices are relabeled by
    increasing coreness before decomposition; results are mapped back.
    """
    from repro.graphs.csr import (build_csr, canonical_edges_with_rows,
                                  degeneracy_order, edge_keys, relabel)

    E, lo, hi, n = canonical_edges_with_rows(edges)
    if E.size == 0:
        return np.zeros(0, np.int64)
    if reorder:
        perm = degeneracy_order(E, n)
        r_edges = relabel(E, perm)
        rl, rh = perm[lo], perm[hi]
        row_keys = edge_keys(np.minimum(rl, rh), np.maximum(rl, rh), n)
    else:
        r_edges = E
        row_keys = edge_keys(lo, hi, n)
    g = build_csr(r_edges, n)
    res = pkt(g, chunk=chunk, mode=mode, support_mode=support_mode)
    return align_to_input(res.trussness, g, None, n, keys=row_keys)
