"""PKT — level-synchronous parallel truss decomposition (paper Algorithms 4+5).

JAX/TPU adaptation of the OpenMP original (see DESIGN.md §2 for the mapping):

  * SCAN            → dense masked compare over the support vector S
  * curr/next       → boolean frontier vectors (inCurr/processed); the "next"
                      buffer is recovered as  alive ∧ (S == l)  after update
  * atomicSub+clamp → masked per-wedge decrement contributions aggregated with
                      scatter-add, then  S ← max(S − dec, l)  (identical fixed
                      point, bitwise deterministic)
  * tie-break       → the paper's "lowest frontier edge id processes the
                      triangle" predicate evaluated vectorially per wedge hit
  * dynamic sched.  → chunk-skipping: the flat peel-wedge table is cut into
                      fixed chunks; a sub-level only visits chunks overlapping
                      frontier edges' ranges (work-efficiency: each triangle's
                      wedge entries are scanned O(1) times over the whole run)

Three peel modes (``mode`` / ``peel_mode``):
  mode="chunked" (default): work-efficient chunk-skipping while_loop.
  mode="dense":  every sub-level scans the whole wedge table with frontier
                 masking — the naive SPMD port, kept as a benchmark foil.
  mode="pallas": the chunk scan runs as a VMEM-blocked Pallas kernel
                 (kernels/peel.py) — one wedge-table chunk per grid step,
                 chunk-skipping degraded to compute masking (grids are
                 static).  Bitwise-identical results to the other two modes.

The support phase has its own independent executor axis
(``support_mode`` ∈ ``core.support.SUPPORT_MODES``): "jnp" is the flat XLA
program, "pallas" the chunked kernel in kernels/support.py.  Any
(support_mode × peel_mode) combination is valid and all six produce
bitwise-identical trussness (tests/test_parity_matrix.py asserts it).

The peel loop is written against *padded* edge state so the batched engine
(serve/truss_engine.py) can vmap it across many graphs of one size class:
slot ``m`` is the sentinel, and any edge slot marked processed in
``processed0`` with sentinel support in ``S_ext0`` is inert padding.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph, edge_keys
from repro.core import support as support_mod
from repro.kernels import wedge_common
from repro.testing.chaos import fault_point

_SENTINEL_S = jnp.int32(1 << 30)

PEEL_MODES = ("chunked", "dense", "pallas")


class PeelTables(NamedTuple):
    """Device-resident static tables for the peel phase (padded to chunks)."""

    e1: jnp.ndarray         # (n_chunks*C,) int32, sentinel m
    cand_slot: jnp.ndarray  # (n_chunks*C,) int32, sentinel 0
    lo: jnp.ndarray         # (n_chunks*C,) int32, sentinel 0
    hi: jnp.ndarray         # (n_chunks*C,) int32, sentinel 0  (lo==hi → miss)
    c_start: jnp.ndarray    # (m,) int32   first chunk containing edge e
    c_end: jnp.ndarray      # (m,) int32   last chunk containing edge e (inclusive)
    has_entries: jnp.ndarray  # (m,) bool


@dataclasses.dataclass(frozen=True)
class PKTResult:
    """Full output of one ``pkt`` decomposition, with phase accounting."""

    trussness: np.ndarray   # (m,) int32, >= 2
    support: np.ndarray     # (m,) int32 initial support
    levels: int             # number of peel levels executed
    sublevels: int          # total sub-level iterations (paper's S)
    compactions: int = 0    # live-edge compactions performed (DESIGN.md §10)
    #: phase wall-times {tables, support, peel, compact} — populated only
    #: when ``pkt(..., phase_timings=True)`` (each phase is synced before
    #: the clock is read, so attribution is honest but adds barriers)
    phases: dict | None = None


def chunk_ranges(off: np.ndarray, chunk: int,
                 m_out: int | None = None) -> tuple[np.ndarray, np.ndarray,
                                                    np.ndarray]:
    """Per-edge chunk-range bookkeeping from a wedge-table offset array.

    Returns (has_entries, c_start, c_end), each of length ``m_out`` (edges
    beyond ``off``'s m are inert padding: no entries, range 0).  Shared by
    the single-graph tables and the batched engine so the two paths cannot
    drift.
    """
    m = off.shape[0] - 1
    m_out = m if m_out is None else m_out
    has = np.zeros(m_out, bool)
    c_start = np.zeros(m_out, np.int32)
    c_end = np.zeros(m_out, np.int32)
    if m == 0 or off[-1] == 0:
        # explicit early-exit: empty graph, or a table with no entries
        # (triangle-free orientation) — every edge has an empty chunk range
        return has, c_start, c_end
    has[:m] = off[1:] > off[:-1]
    c_start[:m] = off[:-1] // chunk
    c_end[:m] = np.maximum(off[1:] - 1, 0) // chunk
    return has, c_start, c_end


def _pad_tables(tab: support_mod.WedgeTable, m: int, chunk: int,
                n_chunks: int) -> PeelTables:
    e1, cand, lo, hi = wedge_common.pad_chunked(
        tab.e1, tab.cand_slot, tab.lo, tab.hi,
        m=m, chunk=chunk, n_chunks=n_chunks)
    has, c_start, c_end = chunk_ranges(tab.off, chunk)
    return PeelTables(
        e1=jnp.asarray(e1), cand_slot=jnp.asarray(cand),
        lo=jnp.asarray(lo), hi=jnp.asarray(hi),
        c_start=jnp.asarray(c_start), c_end=jnp.asarray(c_end),
        has_entries=jnp.asarray(has),
    )


def prepare_peel(tab: support_mod.WedgeTable, m: int,
                 chunk: int | None) -> tuple[PeelTables, int, int]:
    """Clamp ``chunk`` to the table, pad, and derive ``n_chunks``.

    The single place where the chunk size is sanitized (the layout policy
    itself lives in ``kernels.wedge_common.chunk_layout``): a user-passed
    chunk larger than the (padded) table, zero, or negative is clamped so
    that ``n_chunks >= 1`` always holds — tiny graphs (m <= 2, a handful of
    wedge entries) used to be able to reach ``n_chunks == 0`` through the
    old call-site-local ``min(chunk, size)`` dance.

    A table with no entries at all — the empty graph (m == 0), or a support
    table of a triangle-free orientation — takes an explicit early-exit
    rather than relying on the clamping arithmetic: one all-padding chunk of
    size 1, every edge marked entry-less.
    """
    if tab.size == 0:
        return _empty_peel_tables(m), 1, 1
    chunk, n_chunks = wedge_common.chunk_layout(tab.size, chunk)
    tabs = _pad_tables(tab, m, chunk, n_chunks)
    assert tabs.e1.shape[0] == n_chunks * chunk
    return tabs, chunk, n_chunks


def _empty_peel_tables(m: int) -> PeelTables:
    """One all-padding chunk of size 1; every edge entry-less."""
    return PeelTables(
        e1=jnp.full((1,), m, jnp.int32),
        cand_slot=jnp.zeros((1,), jnp.int32),
        lo=jnp.zeros((1,), jnp.int32),
        hi=jnp.zeros((1,), jnp.int32),
        c_start=jnp.zeros((m,), jnp.int32),
        c_end=jnp.zeros((m,), jnp.int32),
        has_entries=jnp.zeros((m,), jnp.bool_),
    )


def prepare_peel_device(g: CSRGraph, chunk: int | None, *,
                        m_out: int | None = None,
                        m_real: int | None = None) -> tuple[PeelTables, int,
                                                            int]:
    """Device-built peel tables for ``g``, pow2-padded (DESIGN.md §10).

    The device counterpart of ``build_peel_table`` + ``prepare_peel``: the
    table entry count is bounded on host (O(m)), rows are materialized on
    device to the next power of two, and the chunk-range metadata is
    computed in the same jit.  ``m_out`` (default ``g.m``) sizes the edge
    state space (the batched/compacted callers pad it to a pow2 bucket);
    ``m_real`` marks how many leading edge slots are real.
    """
    m_out = g.m if m_out is None else m_out
    m_real = g.m if m_real is None else m_real
    size = support_mod.peel_table_size(g)
    if size == 0:
        return _empty_peel_tables(m_out), 1, 1
    size_pad = wedge_common.next_pow2(size)
    support_mod._check_table_size(size_pad)
    chunk_eff = wedge_common.pow2_chunk(size_pad, chunk, size=size)
    n_chunks = size_pad // chunk_eff
    if m_out != g.m:
        # pow2 bucket (batched/compacted callers): pad the edge *and* vertex
        # dimensions so the builder's compiled shapes are bucket-keyed.
        # The padded copies are uploaded directly — no device_arrays() cache
        # for a throwaway compaction subgraph.
        u = jnp.asarray(wedge_common.pad1(g.El[:, 0], m_out, 0))
        v = jnp.asarray(wedge_common.pad1(g.El[:, 1], m_out, 0))
        n_es = wedge_common.next_pow2(g.n + 1)
        Es = jnp.asarray(wedge_common.pad1(g.Es, n_es, 2 * g.m))
    else:
        dev = g.device_arrays()
        u, v, Es = dev["El"][:, 0], dev["El"][:, 1], dev["Es"]
    e1, cand, lo, hi, _off, c_start, c_end, has = \
        support_mod._build_peel_table_dev(
            u, v, Es, jnp.int32(m_real), m=m_out, size=size_pad,
            chunk=chunk_eff)
    tabs = PeelTables(e1=e1, cand_slot=cand, lo=lo, hi=hi, c_start=c_start,
                      c_end=c_end, has_entries=has)
    return tabs, chunk_eff, n_chunks


def _active_chunk_mask(inCurr, tabs: PeelTables, m: int, n_chunks: int):
    """Chunks overlapping any frontier edge's wedge-entry range (bool mask)."""
    curr_edges = inCurr[:m] & tabs.has_entries
    delta = jnp.zeros((n_chunks + 1,), jnp.int32)
    delta = delta.at[jnp.where(curr_edges, tabs.c_start, n_chunks)].add(
        curr_edges.astype(jnp.int32))
    delta = delta.at[jnp.where(curr_edges, tabs.c_end + 1, n_chunks)].add(
        -curr_edges.astype(jnp.int32))
    return jnp.cumsum(delta[:n_chunks]) > 0


def _peel_loop(N, Eid, S_ext0, processed0, tabs: PeelTables, *, m: int,
               chunk: int, n_chunks: int, iters: int, mode: str,
               interpret: bool = True, pinned=None, stop_live=None):
    """Full level/sub-level peel over extended (m+1,) edge state.

    ``S_ext0``/``processed0`` define which slots are live: slot m must be the
    processed sentinel, and callers may pre-mark extra padding slots as
    processed (batched engine).  Returns (S_ext, processed, levels,
    sublevels) — the full extended state, so segmented callers can resume.

    ``pinned`` (optional (m+1,) bool) marks *schedule* edges: they enter the
    frontier and process their triangles at exactly their initial support
    level, but never receive decrements themselves — the incremental layer
    (core/truss_inc.py) uses this to replay the known death level of
    boundary edges whose trussness is already final.  Slot m must be False.

    ``stop_live`` (optional dynamic scalar) is the live-edge compaction
    early-exit (DESIGN.md §10): the level loop returns once the number of
    unprocessed edges drops to or below it — always at a level boundary, so
    the caller can gather survivors into a compacted edge space and re-enter
    with bitwise-identical continuation.
    """
    def chunk_contrib(c, dec, S_ext, processed, inCurr, l):
        """Decrement contributions from one chunk of the wedge table."""
        base = c * chunk
        e1 = jax.lax.dynamic_slice(tabs.e1, (base,), (chunk,))
        cand = jax.lax.dynamic_slice(tabs.cand_slot, (base,), (chunk,))
        lo = jax.lax.dynamic_slice(tabs.lo, (base,), (chunk,))
        hi = jax.lax.dynamic_slice(tabs.hi, (base,), (chunk,))
        in1 = inCurr[e1]
        hit, safe = wedge_common.probe(N, cand, lo, hi, iters=iters)
        e2 = Eid[cand]
        e3 = Eid[safe]
        valid = in1 & hit & ~processed[e2] & ~processed[e3]
        s2 = S_ext[e2]
        s3 = S_ext[e3]
        in2 = inCurr[e2]
        in3 = inCurr[e3]
        dec2 = valid & (s2 > l) & ((~in3) | (e1 < e3))
        dec3 = valid & (s3 > l) & ((~in2) | (e1 < e2))
        if pinned is not None:
            dec2 = dec2 & ~pinned[e2]
            dec3 = dec3 & ~pinned[e3]
        dec = dec.at[jnp.where(dec2, e2, m)].add(dec2.astype(jnp.int32))
        dec = dec.at[jnp.where(dec3, e3, m)].add(dec3.astype(jnp.int32))
        return dec

    def sublevel(S_ext, processed, inCurr, l):
        """One ProcessSubLevel: aggregate decrements, apply, mark processed."""
        dec0 = jnp.zeros((m + 1,), jnp.int32)
        if mode == "dense":
            def body(c, dec):
                return chunk_contrib(c, dec, S_ext, processed, inCurr, l)
            dec = jax.lax.fori_loop(0, n_chunks, body, dec0)
        elif mode == "pallas":
            from repro.kernels.peel import peel_decrement_fold
            active = _active_chunk_mask(inCurr, tabs, m, n_chunks)
            pin = (jnp.zeros((m + 1,), jnp.int32) if pinned is None
                   else pinned.astype(jnp.int32))
            dec = peel_decrement_fold(
                active.astype(jnp.int32),
                jnp.reshape(l, (1,)).astype(jnp.int32),
                tabs.e1, tabs.cand_slot, tabs.lo, tabs.hi, N, Eid,
                S_ext, processed.astype(jnp.int32),
                inCurr.astype(jnp.int32), pin,
                chunk=chunk, n_chunks=n_chunks, iters=iters, m=m,
                interpret=interpret)
        else:  # chunked: visit only chunks overlapping the frontier
            active = _active_chunk_mask(inCurr, tabs, m, n_chunks)
            n_active = jnp.sum(active.astype(jnp.int32))
            (ids,) = jnp.nonzero(active, size=n_chunks, fill_value=n_chunks - 1)

            def body(i, dec):
                return chunk_contrib(ids[i], dec, S_ext, processed, inCurr, l)

            def cond(state):
                i, _ = state
                return i < n_active

            def wbody(state):
                i, dec = state
                return i + 1, body(i, dec)

            _, dec = jax.lax.while_loop(cond, wbody, (jnp.int32(0), dec0))

        S_ext = jnp.where(
            (~processed) & (~inCurr) & (dec > 0),
            jnp.maximum(S_ext - dec, l), S_ext)
        processed = processed | inCurr
        inCurr = (~processed) & (S_ext == l)
        inCurr = inCurr.at[m].set(False)
        return S_ext, processed, inCurr

    def level_body(state):
        S_ext, processed, l_done, todo, levels, subs = state
        alive_S = jnp.where(processed, _SENTINEL_S, S_ext)
        l = jnp.min(alive_S)  # skip-ahead to next populated level
        inCurr = (~processed) & (S_ext == l)
        inCurr = inCurr.at[m].set(False)

        def sub_cond(st):
            _, _, inC, subs_ = st
            return jnp.any(inC)

        def sub_body(st):
            S_ext, processed, inC, subs_ = st
            S_ext, processed, inC = sublevel(S_ext, processed, inC, l)
            return S_ext, processed, inC, subs_ + 1

        S_ext, processed, _, subs = jax.lax.while_loop(
            sub_cond, sub_body, (S_ext, processed, inCurr, subs))
        todo = (m + 1) - jnp.sum(processed.astype(jnp.int32))
        return S_ext, processed, l, todo, levels + 1, subs

    stop = jnp.int32(0) if stop_live is None else stop_live

    def level_cond(state):
        return state[3] > stop

    todo0 = (m + 1) - jnp.sum(processed0.astype(jnp.int32))
    state = (S_ext0, processed0, jnp.int32(0), todo0, jnp.int32(0),
             jnp.int32(0))
    S_ext, processed, _, _, levels, subs = jax.lax.while_loop(
        level_cond, level_body, state)
    return S_ext, processed, levels, subs


@functools.partial(
    jax.jit,
    static_argnames=("m", "chunk", "n_chunks", "iters", "mode", "interpret"),
    donate_argnums=(2,),  # S0: consumed into the peel state, never reread
)
def _pkt_peel_jit(N, Eid, S0, tabs: PeelTables, *, m: int, chunk: int,
                  n_chunks: int, iters: int, mode: str = "chunked",
                  interpret: bool = True):
    """Runs the full level/sub-level peel; returns (S_final, levels, sublevels)."""
    # extended edge state: slot m is a sentinel (processed, never in frontier)
    S_ext0 = jnp.concatenate([S0.astype(jnp.int32), jnp.full((1,), _SENTINEL_S)])
    processed0 = jnp.zeros((m + 1,), jnp.bool_).at[m].set(True)
    S_ext, _, levels, subs = _peel_loop(
        N, Eid, S_ext0, processed0, tabs, m=m, chunk=chunk,
        n_chunks=n_chunks, iters=iters, mode=mode, interpret=interpret)
    return S_ext[:m], levels, subs


@functools.partial(
    jax.jit,
    static_argnames=("m", "chunk", "n_chunks", "iters", "mode", "interpret"),
    donate_argnums=(2, 3),  # peel-state buffers: never reread by the driver
)
def _peel_segment_jit(N, Eid, S_ext0, processed0, stop_live, pinned,
                      tabs: PeelTables, *, m: int, chunk: int, n_chunks: int,
                      iters: int, mode: str, interpret: bool):
    """One compaction segment: peel until done or ≤ ``stop_live`` edges live.

    The peel-state buffers are donated — each segment consumes its inputs,
    so the driver's peak device memory is one state generation, not two.
    """
    return _peel_loop(N, Eid, S_ext0, processed0, tabs, m=m, chunk=chunk,
                      n_chunks=n_chunks, iters=iters, mode=mode,
                      interpret=interpret, pinned=pinned,
                      stop_live=stop_live)


# --- live-edge compaction (DESIGN.md §10) -----------------------------------
#
# Wang & Cheng's improved in-memory algorithm wins by *shrinking the graph*
# as edges are peeled; the level-synchronous port above instead scans a
# fixed-size table whose entries go dead as their edges process.  The driver
# below restores the shrink: segments of the peel run under a live-edge
# early-exit, and between segments the surviving edges are gathered into a
# compacted edge space — vertices rank-relabeled, CSR rebuilt, the peel
# table rebuilt (on device) over only live edges at the next pow2 size
# class, and the (S, processed, pinned) state remapped.  The relabeling is
# order-preserving, so the paper's lowest-edge-id tie-break picks the same
# winners and the continuation is bitwise identical — levels, sub-levels
# and the fixed point all match the uncompacted run; only dead wedge
# entries are dropped.  pow2 bucketing of (m, n, table, chunk) bounds
# recompiles exactly like the batched engine's size classes.

#: default compaction policy: compact when the live fraction drops below
#: ``_COMPACT_FRAC``, but never bother below ``_COMPACT_MIN`` live edges
#: (table rebuild + dispatch overhead beats the dead-scan savings there)
_COMPACT_FRAC = 0.25
_COMPACT_MIN = 1 << 11
_MIN_M_PAD = 8


def _make_subproblem(El_rows: np.ndarray, ids: np.ndarray,
                     S_rows: np.ndarray, pinned_rows: np.ndarray | None, *,
                     chunk_req: int | None, table_mode: str) -> dict:
    """Compact ``El_rows`` (live edges, ascending original order) into a
    fresh pow2-bucketed peel problem.

    ``ids`` maps each row to the caller's output slot; ``S_rows`` carries
    the live supports (the continuation state), ``pinned_rows`` the pinned
    schedule marks (or None).  Vertex ids are rank-relabeled —
    order-preserving, so ``build_csr``'s lexicographic edge ids keep the
    input row order and the peel tie-break is unchanged.
    """
    from repro.graphs.csr import build_csr

    m_sub = El_rows.shape[0]
    verts = np.unique(El_rows)
    E_sub = np.searchsorted(verts, El_rows).astype(np.int64)
    g_sub = build_csr(E_sub, verts.shape[0])
    m_pad = max(_MIN_M_PAD, wedge_common.next_pow2(m_sub))

    if table_mode == "device":
        tabs, chunk_eff, n_chunks = prepare_peel_device(
            g_sub, chunk_req, m_out=m_pad, m_real=m_sub)
    else:
        tab = support_mod.build_peel_table(g_sub)
        if tab.size == 0:
            tabs, chunk_eff, n_chunks = _empty_peel_tables(m_pad), 1, 1
        else:
            size_pad = wedge_common.next_pow2(tab.size)
            chunk_eff = wedge_common.pow2_chunk(size_pad, chunk_req,
                                                size=tab.size)
            n_chunks = size_pad // chunk_eff
            e1, cand, lo, hi = wedge_common.pad_chunked(
                tab.e1, tab.cand_slot, tab.lo, tab.hi,
                m=m_pad, chunk=chunk_eff, n_chunks=n_chunks)
            has, c_start, c_end = chunk_ranges(tab.off, chunk_eff,
                                               m_out=m_pad)
            tabs = PeelTables(
                e1=jnp.asarray(e1), cand_slot=jnp.asarray(cand),
                lo=jnp.asarray(lo), hi=jnp.asarray(hi),
                c_start=jnp.asarray(c_start), c_end=jnp.asarray(c_end),
                has_entries=jnp.asarray(has))

    S_ext0 = np.full(m_pad + 1, int(_SENTINEL_S), np.int32)
    S_ext0[:m_sub] = S_rows
    processed0 = np.ones(m_pad + 1, bool)
    processed0[:m_sub] = False
    ids_pad = np.full(m_pad, -1, np.int64)
    ids_pad[:m_sub] = ids
    pinned = None
    pinned_np = None
    if pinned_rows is not None and pinned_rows.any():
        pinned_np = np.zeros(m_pad + 1, bool)
        pinned_np[:m_sub] = pinned_rows
        pinned = jnp.asarray(pinned_np)
    return dict(
        N=jnp.asarray(wedge_common.pad1(g_sub.N, 2 * m_pad,
                                        wedge_common.PAD_N)),
        Eid=jnp.asarray(wedge_common.pad1(g_sub.Eid, 2 * m_pad, m_pad)),
        tabs=tabs, chunk=chunk_eff, n_chunks=n_chunks,
        iters=int(np.ceil(np.log2(2 * m_pad + 1))) + 1, m=m_pad, live=m_sub,
        S_ext0=jnp.asarray(S_ext0), processed0=jnp.asarray(processed0),
        pinned=pinned, pinned_np=pinned_np, El=g_sub.El, ids=ids_pad)


def _segmented_peel(problem: dict, out: np.ndarray, *, mode: str,
                    interpret: bool, table_mode: str,
                    compact_frac: float | None, compact_min: int,
                    chunk_req: int | None,
                    timings: dict | None = None) -> tuple[int, int, int]:
    """Run ``problem`` to the fixed point, compacting between segments.

    Each segment peels until ≤ ``compact_frac · m`` edges remain live (or to
    completion when compaction is off / the problem is below
    ``compact_min``); finished edges scatter their final S into ``out`` (at
    ``problem['ids']`` slots) and survivors are re-bucketed via
    ``_make_subproblem``.  Returns (levels, sublevels, compactions).
    """
    import time as _time

    levels = subs = compactions = 0
    while True:
        m = problem["m"]
        n_live = problem["live"]
        live_target = 0
        if compact_frac and n_live > compact_min:
            # clamp below the live count so every segment must retire at
            # least one level before the driver considers compacting again
            live_target = min(int(compact_frac * m), n_live - 1)
        t0 = _time.perf_counter()
        S_ext, processed, lv, sb = _peel_segment_jit(
            problem["N"], problem["Eid"], problem["S_ext0"],
            problem["processed0"], jnp.int32(live_target), problem["pinned"],
            problem["tabs"], m=m, chunk=problem["chunk"],
            n_chunks=problem["n_chunks"], iters=problem["iters"], mode=mode,
            interpret=interpret)
        S_np = np.asarray(S_ext)[:m]
        proc_np = np.asarray(processed)[:m]
        levels += int(lv)
        subs += int(sb)
        if timings is not None:
            timings["peel"] = timings.get("peel", 0.0) + \
                (_time.perf_counter() - t0)
        ids = problem["ids"]
        live = ~proc_np
        dead = proc_np & (ids >= 0)
        out[ids[dead]] = S_np[dead]
        if not live.any():
            return levels, subs, compactions
        # ≤ live_target survivors: gather them into a compacted edge space
        t0 = _time.perf_counter()
        compactions += 1
        live_idx = np.nonzero(live)[0]
        pin_np = problem["pinned_np"]
        problem = _make_subproblem(
            problem["El"][live_idx], ids[live_idx], S_np[live_idx],
            None if pin_np is None else pin_np[:m][live_idx],
            chunk_req=chunk_req, table_mode=table_mode)
        assert problem["live"] < n_live  # compaction must strictly shrink
        if timings is not None:
            timings["compact"] = timings.get("compact", 0.0) + \
                (_time.perf_counter() - t0)


def peel_live_subset(El: np.ndarray, live_ids: np.ndarray,
                     S0_live: np.ndarray,
                     pinned_live: np.ndarray | None = None, *,
                     chunk: int | None = None, mode: str = "chunked",
                     interpret: bool | None = None,
                     table_mode: str = "device",
                     compact_frac: float | None = _COMPACT_FRAC,
                     compact_min: int = _COMPACT_MIN) -> np.ndarray:
    """Peel a subset of a graph's edges in a compacted edge space.

    The compaction machinery as a standalone entry: ``live_ids`` (sorted
    edge ids into ``El``) are gathered into a compact pow2-bucketed
    subproblem — only their induced subgraph is materialized, so work is
    bounded by the subset, not the host graph — and peeled to the fixed
    point (with further compaction as the subset shrinks).  ``S0_live``
    seeds the per-edge state; ``pinned_live`` marks schedule edges exactly
    as in ``_peel_loop``.  Returns the final S per ``live_ids`` row.  Used
    by ``core/truss_inc.py``'s masked re-peel regions.
    """
    live_ids = np.asarray(live_ids, dtype=np.int64)
    k = live_ids.shape[0]
    if k == 0:
        return np.zeros(0, np.int32)
    if k > 1 and not (np.diff(live_ids) > 0).all():
        # ascending ids are what make the compacted relabeling
        # order-preserving — the tie-break replay is silently wrong otherwise
        raise ValueError("live_ids must be strictly increasing edge ids")
    if interpret is None:
        interpret = wedge_common.interpret_default()
    out = np.zeros(k, np.int32)
    problem = _make_subproblem(
        np.asarray(El)[live_ids], np.arange(k, dtype=np.int64),
        np.asarray(S0_live, dtype=np.int32),
        None if pinned_live is None else np.asarray(pinned_live, bool),
        chunk_req=chunk, table_mode=table_mode)
    _segmented_peel(problem, out, mode=mode, interpret=interpret,
                    table_mode=table_mode, compact_frac=compact_frac,
                    compact_min=compact_min, chunk_req=chunk)
    return out


def pkt(g: CSRGraph, *, chunk: int | None = None, mode: str = "chunked",
        peel_mode: str | None = None, support_mode: str = "jnp",
        table_mode: str | None = None,
        support_table: support_mod.WedgeTable | None = None,
        peel_table: support_mod.WedgeTable | None = None,
        interpret: bool | None = None,
        compact_frac: float | None = _COMPACT_FRAC,
        compact_min: int = _COMPACT_MIN,
        phase_timings: bool = False) -> PKTResult:
    """Full PKT truss decomposition of one CSR graph.

    Every executor pairing produces bitwise-identical trussness
    (``tests/test_parity_matrix.py``).

    Args:
        g: the graph as a :class:`~repro.graphs.csr.CSRGraph`.
        chunk: wedge-table chunk size (pow2; ``None`` derives it from the
            table size, see ``kernels.wedge_common.auto_chunk``).
        mode: peel executor — one of ``PEEL_MODES`` ("chunked", "dense",
            "pallas"); alias ``peel_mode`` wins when both are given.
        peel_mode: alias for ``mode``.
        support_mode: support executor — one of
            ``support.SUPPORT_MODES`` ("jnp", "pallas"); the two executor
            axes are independent (see module docstring).
        table_mode: where the wedge tables are built
            (``support.TABLE_MODES``): "device" — the default, unless
            prebuilt host tables are passed — constructs them as jitted XLA
            programs over the (cached) device CSR arrays, so no table bytes
            cross the host boundary; "numpy" is the original host builder,
            kept as the parity oracle.
        support_table: optional prebuilt host support table (implies
            ``table_mode="numpy"`` unless overridden).
        peel_table: optional prebuilt host peel table (same implication).
        interpret: force/forbid Pallas interpret mode (default: interpret
            when not on a TPU).
        compact_frac: live-edge compaction threshold (DESIGN.md §10): once
            a peel segment leaves fewer than ``compact_frac · m`` edges
            live (and more than ``compact_min``), survivors are gathered
            into a compacted pow2-bucketed subproblem and peeling re-enters
            there.  ``None`` disables compaction; results are bitwise
            identical either way.
        compact_min: minimum live-edge count for compaction to trigger.
        phase_timings: populate ``PKTResult.phases`` with a
            {tables, support, peel, compact} wall-time split (adds sync
            barriers between phases).

    Returns:
        :class:`PKTResult` — per-edge trussness (support + 2, aligned to
        ``g.El`` rows), initial support, and loop/compaction counters.

    Raises:
        ValueError: unknown ``mode`` / ``support_mode`` / ``table_mode``.
    """
    import time as _time

    mode = mode if peel_mode is None else peel_mode
    if mode not in PEEL_MODES:
        raise ValueError(f"mode must be one of {PEEL_MODES}, got {mode!r}")
    if support_mode not in support_mod.SUPPORT_MODES:
        raise ValueError(f"support_mode must be one of "
                         f"{support_mod.SUPPORT_MODES}, got {support_mode!r}")
    if table_mode is None:
        table_mode = ("numpy" if (support_table is not None
                                  or peel_table is not None) else "device")
    if table_mode not in support_mod.TABLE_MODES:
        raise ValueError(f"table_mode must be one of "
                         f"{support_mod.TABLE_MODES}, got {table_mode!r}")
    timings: dict | None = {} if phase_timings else None
    if g.m == 0:
        return PKTResult(np.zeros(0, np.int32), np.zeros(0, np.int32), 0, 0,
                         phases=timings)
    if interpret is None:
        interpret = wedge_common.interpret_default()

    # ---- support phase -----------------------------------------------------
    fault_point("support", rung=f"{support_mode}/{table_mode}")
    if table_mode == "device" and support_table is None:
        S0_dev = support_mod._support_device(
            g, mode=support_mode, chunk=chunk, interpret=interpret,
            timings=timings)
        S0 = np.asarray(S0_dev)
    else:
        t0 = _time.perf_counter()
        stab = (support_table if support_table is not None
                else support_mod.build_support_table(g))
        if timings is not None:
            timings["tables"] = timings.get("tables", 0.0) + \
                (_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        S0 = support_mod.compute_support(
            g, stab, mode=support_mode, chunk=chunk, interpret=interpret)
        S0_dev = jnp.asarray(S0)
        if timings is not None:
            timings["support"] = timings.get("support", 0.0) + \
                (_time.perf_counter() - t0)

    # ---- peel tables -------------------------------------------------------
    t0 = _time.perf_counter()
    if table_mode == "device" and peel_table is None:
        tabs, chunk_eff, n_chunks = prepare_peel_device(g, chunk)
        if timings is not None:
            tabs.e1.block_until_ready()
    else:
        ptab = (peel_table if peel_table is not None
                else support_mod.build_peel_table(g))
        tabs, chunk_eff, n_chunks = prepare_peel(ptab, g.m, chunk)
    if timings is not None:
        timings["tables"] = timings.get("tables", 0.0) + \
            (_time.perf_counter() - t0)

    # ---- segmented peel with live-edge compaction --------------------------
    dev = g.device_arrays()
    m = g.m
    S_ext0 = jnp.concatenate(
        [S0_dev.astype(jnp.int32), jnp.full((1,), _SENTINEL_S)])
    processed0 = jnp.zeros((m + 1,), jnp.bool_).at[m].set(True)
    problem = dict(
        N=dev["N"], Eid=dev["Eid"], tabs=tabs, chunk=chunk_eff,
        n_chunks=n_chunks, iters=support_mod._search_iters(g), m=m, live=m,
        S_ext0=S_ext0, processed0=processed0, pinned=None, pinned_np=None,
        El=g.El, ids=np.arange(m, dtype=np.int64))
    S_out = np.zeros(m, np.int32)
    levels, subs, compactions = _segmented_peel(
        problem, S_out, mode=mode, interpret=interpret,
        table_mode=table_mode, compact_frac=compact_frac,
        compact_min=compact_min, chunk_req=chunk, timings=timings)
    return PKTResult(
        trussness=S_out.astype(np.int32) + 2,
        support=S0,
        levels=levels,
        sublevels=subs,
        compactions=compactions,
        phases=timings,
    )


def align_to_input(trussness: np.ndarray, g: CSRGraph,
                   edges: np.ndarray | None, n: int, *,
                   keys: np.ndarray | None = None) -> np.ndarray:
    """Map per-``g.El``-row trussness back to the caller's edge order.

    ``edges`` must be the canonical (u<v) edge array ``g`` was built from
    (possibly in a different row order); ``g.El`` rows are lexicographically
    sorted, so each input edge is located by key search.  Callers that
    already hold per-row keys (``u*n + v`` in g's id space) may pass ``keys``
    instead of ``edges``.

    Every requested edge must actually be present in ``g.El``: a missing key
    raises a descriptive ValueError (``np.searchsorted`` alone would silently
    return the *insertion point* — a neighboring edge's trussness — or an
    out-of-range index when the key sorts past the end of the table).
    """
    key_g = edge_keys(g.El[:, 0], g.El[:, 1], n)
    if keys is None:
        keys = edge_keys(edges[:, 0], edges[:, 1], n)
    keys = np.asarray(keys, dtype=np.int64)
    if key_g.shape[0] == 0:
        if keys.shape[0] == 0:
            return np.zeros(0, np.int64)
        raise ValueError(
            f"cannot align {keys.shape[0]} edge(s) to an empty graph")
    pos = np.searchsorted(key_g, keys)
    safe = np.minimum(pos, key_g.shape[0] - 1)
    bad = (pos >= key_g.shape[0]) | (key_g[safe] != keys)
    if bad.any():
        k = int(keys[bad][0])
        raise ValueError(
            f"{int(bad.sum())} edge(s) not present in the graph's edge list; "
            f"first missing: ({k // n}, {k % n})")
    return trussness[pos].astype(np.int64)


def truss_pkt(edges: np.ndarray, *, reorder: bool = True,
              chunk: int | None = None, mode: str = "chunked",
              support_mode: str = "jnp",
              table_mode: str | None = None,
              compact_frac: float | None = _COMPACT_FRAC,
              compact_min: int = _COMPACT_MIN) -> np.ndarray:
    """Convenience entry: undirected edges → trussness aligned to input order.

    ``edges`` is any (k, 2) integer array: endpoint order is free and
    duplicate rows are allowed — rows are canonicalized and deduped exactly
    like ``TrussEngine.submit`` before decomposition, and the result is
    mapped back so ``out[i]`` is the trussness of ``edges[i]`` whatever its
    form.  Self-loops, negative vertex ids, and ids beyond the int32 CSR /
    int64 key-packing bounds are rejected with a clear error (they used to
    corrupt the decomposition silently).

    With ``reorder`` (the paper's preprocessing) vertices are relabeled by
    increasing coreness before decomposition; results are mapped back.
    """
    from repro.graphs.csr import (build_csr, canonical_edges_with_rows,
                                  degeneracy_order, edge_keys, relabel)

    E, lo, hi, n = canonical_edges_with_rows(edges)
    if E.size == 0:
        return np.zeros(0, np.int64)
    if reorder:
        perm = degeneracy_order(E, n)
        r_edges = relabel(E, perm)
        rl, rh = perm[lo], perm[hi]
        row_keys = edge_keys(np.minimum(rl, rh), np.maximum(rl, rh), n)
    else:
        r_edges = E
        row_keys = edge_keys(lo, hi, n)
    g = build_csr(r_edges, n)
    res = pkt(g, chunk=chunk, mode=mode, support_mode=support_mode,
              table_mode=table_mode, compact_frac=compact_frac,
              compact_min=compact_min)
    return align_to_input(res.trussness, g, None, n, keys=row_keys)
