"""PKT — level-synchronous parallel truss decomposition (paper Algorithms 4+5).

JAX/TPU adaptation of the OpenMP original (see DESIGN.md §2 for the mapping):

  * SCAN            → dense masked compare over the support vector S
  * curr/next       → boolean frontier vectors (inCurr/processed); the "next"
                      buffer is recovered as  alive ∧ (S == l)  after update
  * atomicSub+clamp → masked per-wedge decrement contributions aggregated with
                      scatter-add, then  S ← max(S − dec, l)  (identical fixed
                      point, bitwise deterministic)
  * tie-break       → the paper's "lowest frontier edge id processes the
                      triangle" predicate evaluated vectorially per wedge hit
  * dynamic sched.  → chunk-skipping: the flat peel-wedge table is cut into
                      fixed chunks; a sub-level only visits chunks overlapping
                      frontier edges' ranges (work-efficiency: each triangle's
                      wedge entries are scanned O(1) times over the whole run)

Two modes:
  mode="chunked" (default): work-efficient chunk-skipping while_loop.
  mode="dense":  every sub-level scans the whole wedge table with frontier
                 masking — the naive SPMD port, kept as a benchmark foil.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from repro.core import support as support_mod

_SENTINEL_S = jnp.int32(1 << 30)


class PeelTables(NamedTuple):
    """Device-resident static tables for the peel phase (padded to chunks)."""

    e1: jnp.ndarray         # (n_chunks*C,) int32, sentinel m
    cand_slot: jnp.ndarray  # (n_chunks*C,) int32, sentinel 0
    lo: jnp.ndarray         # (n_chunks*C,) int32, sentinel 0
    hi: jnp.ndarray         # (n_chunks*C,) int32, sentinel 0  (lo==hi → miss)
    c_start: jnp.ndarray    # (m,) int32   first chunk containing edge e
    c_end: jnp.ndarray      # (m,) int32   last chunk containing edge e (inclusive)
    has_entries: jnp.ndarray  # (m,) bool


@dataclasses.dataclass(frozen=True)
class PKTResult:
    trussness: np.ndarray   # (m,) int32, >= 2
    support: np.ndarray     # (m,) int32 initial support
    levels: int             # number of peel levels executed
    sublevels: int          # total sub-level iterations (paper's S)


def _pad_tables(tab: support_mod.WedgeTable, m: int, chunk: int) -> PeelTables:
    nw = tab.size
    n_chunks = max(1, -(-nw // chunk))
    pad = n_chunks * chunk - nw
    e1 = np.concatenate([tab.e1, np.full(pad, m, np.int32)])
    cand = np.concatenate([tab.cand_slot, np.zeros(pad, np.int32)])
    lo = np.concatenate([tab.lo, np.zeros(pad, np.int32)])
    hi = np.concatenate([tab.hi, np.zeros(pad, np.int32)])
    off = tab.off
    has = off[1:] > off[:-1]
    c_start = (off[:-1] // chunk).astype(np.int32)
    c_end = (np.maximum(off[1:] - 1, 0) // chunk).astype(np.int32)
    return PeelTables(
        e1=jnp.asarray(e1), cand_slot=jnp.asarray(cand),
        lo=jnp.asarray(lo), hi=jnp.asarray(hi),
        c_start=jnp.asarray(c_start), c_end=jnp.asarray(c_end),
        has_entries=jnp.asarray(has),
    )


@functools.partial(
    jax.jit,
    static_argnames=("m", "chunk", "n_chunks", "iters", "dense"),
)
def _pkt_peel_jit(N, Eid, S0, tabs: PeelTables, *, m: int, chunk: int,
                  n_chunks: int, iters: int, dense: bool):
    """Runs the full level/sub-level peel; returns (S_final, levels, sublevels)."""
    two_m = N.shape[0]

    # extended edge state: slot m is a sentinel (processed, never in frontier)
    S_ext0 = jnp.concatenate([S0.astype(jnp.int32), jnp.full((1,), _SENTINEL_S)])
    processed0 = jnp.zeros((m + 1,), jnp.bool_).at[m].set(True)

    def chunk_contrib(c, dec, S_ext, processed, inCurr, l):
        """Decrement contributions from one chunk of the wedge table."""
        base = c * chunk
        e1 = jax.lax.dynamic_slice(tabs.e1, (base,), (chunk,))
        cand = jax.lax.dynamic_slice(tabs.cand_slot, (base,), (chunk,))
        lo = jax.lax.dynamic_slice(tabs.lo, (base,), (chunk,))
        hi = jax.lax.dynamic_slice(tabs.hi, (base,), (chunk,))
        in1 = inCurr[e1]
        w = N[cand]
        idx = support_mod.ranged_searchsorted(N, w, lo, hi, iters)
        safe = jnp.minimum(idx, two_m - 1)
        hit = (idx < hi) & (N[safe] == w)
        e2 = Eid[cand]
        e3 = Eid[safe]
        valid = in1 & hit & ~processed[e2] & ~processed[e3]
        s2 = S_ext[e2]
        s3 = S_ext[e3]
        in2 = inCurr[e2]
        in3 = inCurr[e3]
        dec2 = valid & (s2 > l) & ((~in3) | (e1 < e3))
        dec3 = valid & (s3 > l) & ((~in2) | (e1 < e2))
        dec = dec.at[jnp.where(dec2, e2, m)].add(dec2.astype(jnp.int32))
        dec = dec.at[jnp.where(dec3, e3, m)].add(dec3.astype(jnp.int32))
        return dec

    def sublevel(S_ext, processed, inCurr, l):
        """One ProcessSubLevel: aggregate decrements, apply, mark processed."""
        dec0 = jnp.zeros((m + 1,), jnp.int32)
        if dense:
            def body(c, dec):
                return chunk_contrib(c, dec, S_ext, processed, inCurr, l)
            dec = jax.lax.fori_loop(0, n_chunks, body, dec0)
        else:
            # mark chunks overlapping any frontier edge's entry range
            curr_edges = inCurr[:m] & tabs.has_entries
            delta = jnp.zeros((n_chunks + 1,), jnp.int32)
            delta = delta.at[jnp.where(curr_edges, tabs.c_start, n_chunks)].add(
                curr_edges.astype(jnp.int32))
            delta = delta.at[jnp.where(curr_edges, tabs.c_end + 1, n_chunks)].add(
                -curr_edges.astype(jnp.int32))
            active = jnp.cumsum(delta[:n_chunks]) > 0
            n_active = jnp.sum(active.astype(jnp.int32))
            (ids,) = jnp.nonzero(active, size=n_chunks, fill_value=n_chunks - 1)

            def body(i, dec):
                return chunk_contrib(ids[i], dec, S_ext, processed, inCurr, l)

            def cond(state):
                i, _ = state
                return i < n_active

            def wbody(state):
                i, dec = state
                return i + 1, body(i, dec)

            _, dec = jax.lax.while_loop(cond, wbody, (jnp.int32(0), dec0))

        S_ext = jnp.where(
            (~processed) & (~inCurr) & (dec > 0),
            jnp.maximum(S_ext - dec, l), S_ext)
        processed = processed | inCurr
        inCurr = (~processed) & (S_ext == l)
        inCurr = inCurr.at[m].set(False)
        return S_ext, processed, inCurr

    def level_body(state):
        S_ext, processed, l_done, todo, levels, subs = state
        alive_S = jnp.where(processed, _SENTINEL_S, S_ext)
        l = jnp.min(alive_S)  # skip-ahead to next populated level
        inCurr = (~processed) & (S_ext == l)
        inCurr = inCurr.at[m].set(False)

        def sub_cond(st):
            _, _, inC, subs_ = st
            return jnp.any(inC)

        def sub_body(st):
            S_ext, processed, inC, subs_ = st
            S_ext, processed, inC = sublevel(S_ext, processed, inC, l)
            return S_ext, processed, inC, subs_ + 1

        S_ext, processed, _, subs = jax.lax.while_loop(
            sub_cond, sub_body, (S_ext, processed, inCurr, subs))
        todo = (m + 1) - jnp.sum(processed.astype(jnp.int32))
        return S_ext, processed, l, todo, levels + 1, subs

    def level_cond(state):
        return state[3] > 0

    state = (S_ext0, processed0, jnp.int32(0), jnp.int32(m), jnp.int32(0),
             jnp.int32(0))
    S_ext, _, _, _, levels, subs = jax.lax.while_loop(
        level_cond, level_body, state)
    return S_ext[:m], levels, subs


def pkt(g: CSRGraph, *, chunk: int = 1 << 14, mode: str = "chunked",
        support_table: support_mod.WedgeTable | None = None,
        peel_table: support_mod.WedgeTable | None = None) -> PKTResult:
    """Full PKT truss decomposition. Returns trussness per edge (S+2)."""
    if g.m == 0:
        return PKTResult(np.zeros(0, np.int32), np.zeros(0, np.int32), 0, 0)
    S0 = support_mod.compute_support(g, support_table)
    ptab = peel_table if peel_table is not None else support_mod.build_peel_table(g)
    chunk = min(chunk, max(1, ptab.size))
    tabs = _pad_tables(ptab, g.m, chunk)
    n_chunks = tabs.e1.shape[0] // chunk
    S, levels, subs = _pkt_peel_jit(
        jnp.asarray(g.N), jnp.asarray(g.Eid), jnp.asarray(S0), tabs,
        m=g.m, chunk=chunk, n_chunks=n_chunks,
        iters=support_mod._search_iters(g), dense=(mode == "dense"),
    )
    return PKTResult(
        trussness=np.asarray(S) + 2,
        support=np.asarray(S0),
        levels=int(levels),
        sublevels=int(subs),
    )


def truss_pkt(edges: np.ndarray, *, reorder: bool = True,
              chunk: int = 1 << 14, mode: str = "chunked") -> np.ndarray:
    """Convenience entry: canonical edges → trussness aligned to input order.

    With ``reorder`` (the paper's preprocessing) vertices are relabeled by
    increasing coreness before decomposition; results are mapped back.
    """
    from repro.graphs.csr import build_csr, degeneracy_order, relabel

    edges = np.asarray(edges, dtype=np.int64)
    if edges.size == 0:
        return np.zeros(0, np.int64)
    n = int(edges.max()) + 1
    if reorder:
        perm = degeneracy_order(edges, n)
        r_edges = relabel(edges, perm)
    else:
        r_edges = edges
    g = build_csr(r_edges, n)
    res = pkt(g, chunk=chunk, mode=mode)
    # map back: g.El rows are sorted lexicographically; locate each input edge
    key_g = g.El[:, 0].astype(np.int64) * n + g.El[:, 1]
    key_in = r_edges[:, 0] * n + r_edges[:, 1]
    pos = np.searchsorted(key_g, key_in)
    return res.trussness[pos].astype(np.int64)
