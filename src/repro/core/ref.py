"""Trivially-correct truss decomposition oracle (numpy + python sets).

Definitionally faithful and slow: for k = 3, 4, ... repeatedly delete edges
whose support inside the remaining subgraph is < k-2; edges deleted while
moving to k have trussness k-1. Used as the ground truth for property tests.
"""

from __future__ import annotations

import numpy as np


def support_naive(edges: np.ndarray, alive: np.ndarray) -> np.ndarray:
    """Support of each alive edge within the alive subgraph (set intersection)."""
    adj: dict[int, set[int]] = {}
    for (u, v), a in zip(edges, alive):
        if a:
            adj.setdefault(int(u), set()).add(int(v))
            adj.setdefault(int(v), set()).add(int(u))
    S = np.zeros(edges.shape[0], dtype=np.int64)
    for e, ((u, v), a) in enumerate(zip(edges, alive)):
        if a:
            S[e] = len(adj.get(int(u), set()) & adj.get(int(v), set()))
    return S


def truss_numpy(edges: np.ndarray) -> np.ndarray:
    """Returns trussness (>= 2) per edge of a canonical u<v edge array."""
    m = edges.shape[0]
    truss = np.full(m, 2, dtype=np.int64)
    alive = np.ones(m, dtype=bool)
    k = 3
    while alive.any():
        while True:
            S = support_naive(edges, alive)
            drop = alive & (S < k - 2)
            if not drop.any():
                break
            truss[drop] = k - 1
            alive &= ~drop
        # all remaining edges are in a k-truss (support-wise); bump k
        truss[alive] = k
        k += 1
    return truss


def max_truss(edges: np.ndarray) -> int:
    """Largest k such that the k-truss is non-empty (numpy oracle)."""
    t = truss_numpy(edges)
    return int(t.max(initial=2))
