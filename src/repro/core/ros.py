"""Ros baseline (paper Algorithm 2 + sequential peel).

Rossi's algorithm parallelizes *only* the support computation (edge-based full
intersection, work ∝ Σ d(v)² — no orientation win), then peels sequentially
with the same bucket structure as WC but hash-free (CSR + Eid). This is the
paper's strongest prior shared-memory baseline (Tables 3–4).
"""

from __future__ import annotations

import numpy as np

from repro.graphs.csr import CSRGraph
from repro.core.support import compute_support_ros


def truss_ros(g: CSRGraph) -> np.ndarray:
    """Trussness per edge id; support in parallel (JAX), peel sequential."""
    m = g.m
    if m == 0:
        return np.zeros(0, np.int64)
    S = compute_support_ros(g).astype(np.int64)

    Es, N, Eid, El = g.Es, g.N, g.Eid, g.El

    max_s = int(S.max(initial=0))
    bin_start = np.zeros(max_s + 2, dtype=np.int64)
    np.add.at(bin_start, S + 1, 1)
    bin_start = np.cumsum(bin_start)
    pos = np.zeros(m, dtype=np.int64)
    el_sorted = np.zeros(m, dtype=np.int64)
    fill = bin_start[:-1].copy()
    for e in range(m):
        pos[e] = fill[S[e]]
        el_sorted[pos[e]] = e
        fill[S[e]] += 1
    bin_ptr = bin_start[:-1].copy()

    truss = np.zeros(m, dtype=np.int64)
    removed = np.zeros(m, dtype=bool)

    def decrease(e2: int, k: int) -> None:
        if S[e2] <= k:
            return
        s2 = int(S[e2]); p2 = int(pos[e2])
        pw = int(bin_ptr[s2]); w_ = int(el_sorted[pw])
        if e2 != w_:
            el_sorted[p2], el_sorted[pw] = w_, e2
            pos[e2], pos[w_] = pw, p2
        bin_ptr[s2] += 1
        S[e2] -= 1

    for i in range(m):
        e = int(el_sorted[i])
        k = int(S[e])
        u, v = int(El[e, 0]), int(El[e, 1])
        if Es[u + 1] - Es[u] > Es[v + 1] - Es[v]:
            u, v = v, u
        row_v = N[Es[v]:Es[v + 1]]
        eid_v = Eid[Es[v]:Es[v + 1]]
        for j in range(Es[u], Es[u + 1]):
            w = N[j]
            t = np.searchsorted(row_v, w)
            if t < row_v.shape[0] and row_v[t] == w:
                e2 = int(Eid[j])            # (u, w)
                e3 = int(eid_v[t])          # (v, w)
                if removed[e2] or removed[e3]:
                    continue
                decrease(e2, k)
                decrease(e3, k)
        truss[e] = k + 2
        removed[e] = True

    return truss
