"""Core: the paper's contribution — PKT truss decomposition and its relatives."""

from repro.core.pkt import pkt, truss_pkt, PKTResult, peel_live_subset
from repro.core.truss_inc import IncrementalTruss, UpdateStats
from repro.core.hierarchy import (TrussHierarchy, HIER_MODES,
                                  hierarchy_from_graph)
from repro.core.support import (
    compute_support,
    compute_support_ros,
    triangle_count,
    build_support_table,
    build_peel_table,
    support_table_size,
    peel_table_size,
    TABLE_MODES,
)
from repro.core.wc import truss_wc
from repro.core.ros import truss_ros
from repro.core.ref import truss_numpy
from repro.core.triangle_list import truss_trilist, enumerate_triangles
from repro.core.kcore import kcore_numpy, kcore_park
from repro.core.pkt_dist import pkt_dist, make_pkt_dist, make_support_dist

__all__ = [
    "pkt", "truss_pkt", "PKTResult", "peel_live_subset",
    "IncrementalTruss", "UpdateStats",
    "TrussHierarchy", "HIER_MODES", "hierarchy_from_graph",
    "compute_support", "compute_support_ros", "triangle_count",
    "build_support_table", "build_peel_table",
    "support_table_size", "peel_table_size", "TABLE_MODES",
    "truss_wc", "truss_ros", "truss_numpy",
    "truss_trilist", "enumerate_triangles",
    "kcore_numpy", "kcore_park",
    "pkt_dist", "make_pkt_dist", "make_support_dist",
]
