"""Triangle-list truss decomposition — the O(|△|)-memory comparator.

Zhang–Parthasarathy-style: enumerate every triangle once up front, then peel
level-synchronously over the static triangle list. The paper deliberately does
NOT parallelize this family because of its O(|△|) memory; we implement it as
the *beyond-paper* bracketing point: it trades the paper's O(m) memory claim
for a peel phase with perfectly regular (dense, segment-sum) data flow — on a
TPU this regularity is worth measuring (EXPERIMENTS.md §Perf, truss side).

The per-sub-level rule collapses beautifully here: a triangle "dies" the first
sub-level any of its edges is in the frontier, and contributes exactly one
decrement to each of its other, still-alive, not-in-frontier edges with
S > l — which *is* the paper's tie-break, stated triangle-centrically.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from repro.core import support as support_mod


def enumerate_triangles(g: CSRGraph) -> np.ndarray:
    """All triangles as an (t, 3) int32 array of edge ids (canonical order)."""
    if g.m == 0:
        return np.zeros((0, 3), np.int32)
    tab = support_mod.build_support_table(g)
    N = jnp.asarray(g.N)
    Eid = jnp.asarray(g.Eid)
    iters = support_mod._search_iters(g, oriented=True)

    @jax.jit
    def find(e1, cand_slot, lo, hi):
        w = N[cand_slot]
        idx = support_mod.ranged_searchsorted(N, w, lo, hi, iters)
        safe = jnp.minimum(idx, N.shape[0] - 1)
        hit = (idx < hi) & (N[safe] == w)
        return hit, Eid[cand_slot], Eid[safe]

    hit, e2, e3 = find(jnp.asarray(tab.e1), jnp.asarray(tab.cand_slot),
                       jnp.asarray(tab.lo), jnp.asarray(tab.hi))
    hit = np.asarray(hit)
    tri = np.stack([tab.e1[hit], np.asarray(e2)[hit], np.asarray(e3)[hit]],
                   axis=1)
    return tri.astype(np.int32)


@functools.partial(jax.jit, static_argnames=("m",))
def _peel_trilist_jit(tri, S0, *, m: int):
    """Dense level-synchronous peel over the triangle list."""
    t = tri.shape[0]
    SENT = jnp.int32(1 << 30)
    S0 = S0.astype(jnp.int32)

    def level_body(state):
        S, processed, tri_alive, levels, subs = state
        l = jnp.min(jnp.where(processed, SENT, S))
        inCurr = (~processed) & (S == l)

        def sub_cond(st):
            _, _, _, inC, subs_ = st
            return jnp.any(inC)

        def sub_body(st):
            S, processed, tri_alive, inC, subs_ = st
            f0 = inC[tri[:, 0]]
            f1 = inC[tri[:, 1]]
            f2 = inC[tri[:, 2]]
            dies = tri_alive & (f0 | f1 | f2)

            def contrib(dec, col, fcol):
                e = tri[:, col]
                mask = dies & (~fcol) & (S[e] > l)
                return dec.at[jnp.where(mask, e, m)].add(mask.astype(jnp.int32))

            dec = jnp.zeros((m + 1,), jnp.int32)
            dec = contrib(dec, 0, f0)
            dec = contrib(dec, 1, f1)
            dec = contrib(dec, 2, f2)
            dec = dec[:m]
            S = jnp.where((~processed) & (~inC) & (dec > 0),
                          jnp.maximum(S - dec, l), S)
            tri_alive = tri_alive & ~dies
            processed = processed | inC
            inC = (~processed) & (S == l)
            return S, processed, tri_alive, inC, subs_ + 1

        S, processed, tri_alive, _, subs = jax.lax.while_loop(
            sub_cond, sub_body, (S, processed, tri_alive, inCurr, subs))
        return S, processed, tri_alive, levels + 1, subs

    def level_cond(state):
        return ~jnp.all(state[1])

    state = (S0, jnp.zeros((m,), jnp.bool_), jnp.ones((t,), jnp.bool_),
             jnp.int32(0), jnp.int32(0))
    S, _, _, levels, subs = jax.lax.while_loop(level_cond, level_body, state)
    return S, levels, subs


def truss_trilist(g: CSRGraph) -> np.ndarray:
    """Trussness per edge via the triangle-list variant."""
    if g.m == 0:
        return np.zeros(0, np.int64)
    S0 = support_mod.compute_support(g)
    tri = enumerate_triangles(g)
    if tri.shape[0] == 0:
        return np.full(g.m, 2, np.int64)
    S, _, _ = _peel_trilist_jit(jnp.asarray(tri), jnp.asarray(S0), m=g.m)
    return np.asarray(S).astype(np.int64) + 2
