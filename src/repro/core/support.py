"""Parallel edge-support computation — the AM4 (Algorithm 3) TPU adaptation.

The paper orients edges by increasing k-core vertex order and counts each
triangle once in canonical order, using a thread-local size-n scratch array X
for O(1) membership tests. On TPU there is no per-thread random-access scratch;
the adaptation (DESIGN.md §2) replaces X with:

  * a *flat oriented wedge table* built once per graph: one entry per
    (oriented edge (u→v), candidate w ∈ N⁺(v)) pair — exactly the wedges the
    AM4 loop nest inspects, Θ(Σ_v d⁻(v)·d⁺(v)) entries;
  * a vectorized *ranged binary search* of w in N⁺(u) (sorted CSR rows) —
    the membership test, O(log d⁺) gathers per probe;
  * scatter-adds into S — the deterministic analogue of the three AtomicAdds.

Each triangle u<v<w is discovered exactly once, anchored at its lowest-vertex
edge (u,v) with w scanned from N⁺(v). Work: Θ(m + Σ_v d⁻(v)·d⁺(v)·log d⁺) —
the ordering-dependence (Table 2) is preserved: relabeling by coreness shrinks
d⁺ exactly as in the paper.

Two execution modes (``compute_support(mode=...)``), bitwise identical:

  mode="jnp" (default): the wedge table is evaluated as one flat jnp
      gather/search/scatter program (``_support_jit``) — XLA fuses it, but
      every probe round-trips through HBM.
  mode="pallas": the table is cut into fixed chunks and evaluated by the
      Pallas kernel in ``kernels/support.py`` (DESIGN.md §2) — one chunk per
      grid step, the candidate gather fused with the ranged binary search in
      VMEM, per-chunk triangle partials accumulated on-chip.  The kernel
      emits increment-target streams; the support scatter-add happens once
      outside, so integer-exact addition makes the two modes agree bitwise.
      Off-TPU the kernel runs in interpret mode (CI lowers it on every PR).

The peel phase has the same split (``core.pkt.pkt(mode=...)``); the two
kernels share layout and search machinery via ``kernels/wedge_common.py``.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from repro.kernels.wedge_common import (chunk_layout, interpret_default,
                                        next_pow2, pad_chunked, pow2_chunk,
                                        probe)
# re-export: the triangle-list engine binary-searches through this module's
# namespace (kernels.wedge_common is the canonical home)
from repro.kernels.wedge_common import ranged_searchsorted  # noqa: F401

#: executors for the support phase; "pallas" = kernels/support.py
SUPPORT_MODES = ("jnp", "pallas")

#: where wedge tables are constructed: "numpy" is the original host builder
#: (kept as the parity oracle), "device" the jitted XLA builder below —
#: tables never round-trip through host memory
TABLE_MODES = ("numpy", "device")


@dataclasses.dataclass(frozen=True)
class WedgeTable:
    """Flat (edge, candidate-slot) table + per-query search ranges."""

    e1: np.ndarray       # (Nw,) int32 — edge id of (u, v)
    cand_slot: np.ndarray  # (Nw,) int32 — CSR slot of w (gives w and Eid e2)
    lo: np.ndarray       # (Nw,) int32 — probe range start in N
    hi: np.ndarray       # (Nw,) int32 — probe range end in N
    off: np.ndarray      # (m+1,) int64 — entries of edge e at [off[e], off[e+1])

    @property
    def size(self) -> int:
        """Number of wedge entries (Nw)."""
        return int(self.e1.shape[0])


def build_support_table(g: CSRGraph) -> WedgeTable:
    """Oriented wedge table: for edge (u,v), candidates w ∈ N⁺(v), probe N⁺(u)."""
    u = g.El[:, 0].astype(np.int64)
    v = g.El[:, 1].astype(np.int64)
    Es = g.Es.astype(np.int64)
    Eo = g.Eo.astype(np.int64)
    cnt = Es[v + 1] - Eo[v]                      # |N⁺(v)| per edge
    off = np.zeros(g.m + 1, dtype=np.int64)
    np.cumsum(cnt, out=off[1:])
    Nw = int(off[-1])
    e1 = np.repeat(np.arange(g.m, dtype=np.int64), cnt)
    intra = np.arange(Nw, dtype=np.int64) - off[e1]
    cand_slot = Eo[v[e1]] + intra
    lo = Eo[u[e1]]
    hi = Es[u[e1] + 1]
    return WedgeTable(
        e1=e1.astype(np.int32),
        cand_slot=cand_slot.astype(np.int32),
        lo=lo.astype(np.int32),
        hi=hi.astype(np.int32),
        off=off,
    )


def build_peel_table(g: CSRGraph) -> WedgeTable:
    """Full-adjacency wedge table used by the peel phase.

    For edge e=(u,v): candidates w from the *smaller*-degree endpoint's full
    adjacency, probed against the other endpoint's full adjacency — the
    ProcessSubLevel loop nest of Algorithm 5 with the cheap side chosen
    (the paper marks N(u) and scans N(v); we pick min-degree for the scan).
    """
    u = g.El[:, 0].astype(np.int64)
    v = g.El[:, 1].astype(np.int64)
    Es = g.Es.astype(np.int64)
    deg = (Es[1:] - Es[:-1])
    swap = deg[u] > deg[v]
    cand = np.where(swap, v, u)                  # scan this side
    probe = np.where(swap, u, v)                 # binary-search this side
    cnt = deg[cand]
    off = np.zeros(g.m + 1, dtype=np.int64)
    np.cumsum(cnt, out=off[1:])
    Nw = int(off[-1])
    e1 = np.repeat(np.arange(g.m, dtype=np.int64), cnt)
    intra = np.arange(Nw, dtype=np.int64) - off[e1]
    cand_slot = Es[cand[e1]] + intra
    lo = Es[probe[e1]]
    hi = Es[probe[e1] + 1]
    return WedgeTable(
        e1=e1.astype(np.int32),
        cand_slot=cand_slot.astype(np.int32),
        lo=lo.astype(np.int32),
        hi=hi.astype(np.int32),
        off=off,
    )


# --- device-side table construction (DESIGN.md §10) -------------------------
#
# The builders above materialize Θ(Σ d·d)-entry tables in host numpy and pay
# a host→device transfer several× the graph size on every decomposition.  The
# jitted XLA mirrors below build the same rows *on device* from the CSR
# arrays alone: per-edge candidate counts, segment offsets via cumsum, and
# the row→edge assignment as one vectorized ``searchsorted`` over the offset
# array (the segment-expansion idiom).  Rows are materialized to a *static*
# pow2-padded ``size`` (the exact entry count is data-dependent; the cheap
# O(m) host calculators below bound it before the jit runs), with the same
# inert-padding contract as ``wedge_common.pad_chunked``: anchor sentinel
# ``m``, empty probe range ``lo == hi == 0``.  ``m_real`` is a dynamic
# scalar so the batched engine can reuse one compiled builder for every
# graph of a size class — and vmap it across the class.

#: device tables carry int32 offsets; reject anything larger outright
_MAX_TABLE = np.iinfo(np.int32).max


def support_table_size(g: CSRGraph) -> int:
    """Exact entry count of ``build_support_table(g)`` — O(m) host work."""
    if g.m == 0:
        return 0
    v = g.El[:, 1].astype(np.int64)
    return int((g.Es.astype(np.int64)[v + 1] - g.Eo.astype(np.int64)[v]).sum())


def peel_table_size(g: CSRGraph) -> int:
    """Exact entry count of ``build_peel_table(g)`` — O(m) host work."""
    if g.m == 0:
        return 0
    Es = g.Es.astype(np.int64)
    deg = Es[1:] - Es[:-1]
    return int(np.minimum(deg[g.El[:, 0]], deg[g.El[:, 1]]).sum())


def _check_table_size(size: int) -> None:
    """Guard the int32 device-table layout.

    ``size`` must be the number of rows the builder will *materialize* —
    i.e. the padded size (pow2, or shard-rounded), not the raw entry count:
    a raw count just under 2^31 still pads past the int32 range.
    """
    if size > _MAX_TABLE:
        raise ValueError(
            f"wedge table of {size} (padded) entries exceeds the int32 "
            f"device-table layout; use table_mode='numpy' (int64 host "
            f"offsets)")


def _expand_segments(off, size: int, m: int):
    """Row → segment assignment for a cumsum offset array ``off`` (m+1,).

    Returns ``(e1, e1c, intra, valid)``: the owning segment of each of the
    ``size`` rows (``m`` for rows beyond ``off[m]``), a clamped variant safe
    as a gather index, the offset within the segment, and the validity mask.
    """
    idx = jnp.arange(size, dtype=jnp.int32)
    e1 = jnp.searchsorted(off[1:], idx, side="right").astype(jnp.int32)
    e1c = jnp.minimum(e1, m - 1)
    valid = idx < off[m]
    intra = idx - off[e1c]
    return jnp.where(valid, e1, m), e1c, intra, valid


@functools.partial(jax.jit, static_argnames=("m", "size"))
def _build_support_table_dev(u, v, Es, Eo, m_real, *, m: int, size: int):
    """Device mirror of ``build_support_table`` at static padded ``size``.

    ``u``/``v``: (m,) edge endpoints (rows >= ``m_real`` are inert padding);
    ``Es``: (n_pad+1,) CSR offsets; ``Eo``: (n_pad,).  Returns
    ``(e1, cand_slot, lo, hi, off)`` with the pad_chunked sentinel contract.
    """
    ar = jnp.arange(m, dtype=jnp.int32)
    cnt = jnp.where(ar < m_real, Es[v + 1] - Eo[v], 0)
    off = jnp.zeros((m + 1,), jnp.int32).at[1:].set(jnp.cumsum(cnt))
    e1, e1c, intra, valid = _expand_segments(off, size, m)
    cand = jnp.where(valid, Eo[v[e1c]] + intra, 0)
    lo = jnp.where(valid, Eo[u[e1c]], 0)
    hi = jnp.where(valid, Es[u[e1c] + 1], 0)
    return e1, cand, lo, hi, off


@functools.partial(jax.jit, static_argnames=("m", "size", "chunk"))
def _build_peel_table_dev(u, v, Es, m_real, *, m: int, size: int, chunk: int):
    """Device mirror of ``build_peel_table`` + per-edge chunk-range metadata.

    Same row semantics as the host builder (candidates from the
    min-degree endpoint's full adjacency, probes against the other); also
    emits the ``chunk_ranges`` bookkeeping for the given static ``chunk`` so
    the peel loop's chunk-skipping needs no host pass.  Returns
    ``(e1, cand_slot, lo, hi, off, c_start, c_end, has_entries)``.
    """
    deg = Es[1:] - Es[:-1]
    swap = deg[u] > deg[v]
    cand_v = jnp.where(swap, v, u)               # scan this side
    prob_v = jnp.where(swap, u, v)               # binary-search this side
    ar = jnp.arange(m, dtype=jnp.int32)
    cnt = jnp.where(ar < m_real, deg[cand_v], 0)
    off = jnp.zeros((m + 1,), jnp.int32).at[1:].set(jnp.cumsum(cnt))
    e1, e1c, intra, valid = _expand_segments(off, size, m)
    cand = jnp.where(valid, Es[cand_v[e1c]] + intra, 0)
    lo = jnp.where(valid, Es[prob_v[e1c]], 0)
    hi = jnp.where(valid, Es[prob_v[e1c] + 1], 0)
    has = off[1:] > off[:-1]
    c_start = off[:-1] // chunk
    c_end = jnp.maximum(off[1:] - 1, 0) // chunk
    return e1, cand, lo, hi, off, c_start, c_end, has


def support_from_table_arrays(e1, cand, lo, hi, N, Eid, *, m: int, mode: str,
                              chunk: int, n_chunks: int, iters: int,
                              interpret: bool):
    """Run the selected support executor over prepared table arrays → (m,) S.

    Trace-level helper (call inside a jit): the single home of the
    executor dispatch + sentinel/target-folding contract, shared by the
    fused single-graph program below and the batched engine
    (``serve.truss_engine._batched_truss_dev``).  Table arrays follow the
    ``pad_chunked`` convention and must span ``n_chunks * chunk`` rows.
    """
    if mode == "pallas":
        from repro.kernels.support import support_accumulate

        S, _ = support_accumulate(
            e1, cand, lo, hi, N, Eid, chunk=chunk, n_chunks=n_chunks,
            iters=iters, m=m, interpret=interpret)
        return S[:m]
    return _support_jit(N, Eid, e1, cand, lo, hi, iters, m)


@functools.partial(jax.jit, static_argnames=("m", "size", "mode", "chunk",
                                             "n_chunks", "iters",
                                             "interpret"))
def _support_device_jit(u, v, Es, Eo, N, Eid, m_real, *, m: int, size: int,
                        mode: str, chunk: int, n_chunks: int, iters: int,
                        interpret: bool):
    """Fused device program: build the oriented table *and* run the support
    executor in one jit — one compile on the open path, and in jnp mode XLA
    can fuse the row construction into the probe (the table is never
    materialized to HBM)."""
    e1, cand, lo, hi, _ = _build_support_table_dev(
        u, v, Es, Eo, m_real, m=m, size=size)
    return support_from_table_arrays(
        e1, cand, lo, hi, N, Eid, m=m, mode=mode, chunk=chunk,
        n_chunks=n_chunks, iters=iters, interpret=interpret)


def _support_device(g: CSRGraph, *, mode: str, chunk: int | None,
                    interpret: bool, timings: dict | None = None):
    """Support phase with the table built on device; returns a (m,) device
    array (no host round-trip — ``pkt`` feeds it straight to the peel).

    Table construction and the probe run as one fused jit, so with
    ``timings`` the combined cost is attributed to "support" ("tables"
    then covers only the peel-table build)."""
    import time as _time

    size = support_table_size(g)
    if size == 0:
        return jnp.zeros((g.m,), jnp.int32)
    size_pad = next_pow2(size)
    _check_table_size(size_pad)
    dev = g.device_arrays()
    chunk_eff = pow2_chunk(size_pad, chunk, size=size)
    t0 = _time.perf_counter()
    S = _support_device_jit(
        dev["El"][:, 0], dev["El"][:, 1], dev["Es"], dev["Eo"],
        dev["N"], dev["Eid"], jnp.int32(g.m), m=g.m, size=size_pad,
        mode=mode, chunk=chunk_eff, n_chunks=size_pad // chunk_eff,
        iters=_search_iters(g, oriented=True), interpret=interpret)
    if timings is not None:
        S.block_until_ready()
        timings["support"] = timings.get("support", 0.0) + \
            (_time.perf_counter() - t0)
    return S


# ``ranged_searchsorted`` lives in kernels/wedge_common.py (shared with the
# Pallas kernels) and is re-exported here for its established call sites
# (core/pkt.py, core/pkt_dist.py, core/triangle_list.py, benchmarks).


def _search_iters(g: CSRGraph, *, oriented: bool = False) -> int:
    """Binary-search iteration bound = log2(max probe-range length).

    The support path probes only N⁺(u) ranges, whose length is bounded by
    the degeneracy after KCO relabeling — this is where the paper's
    ordering win lands in our adaptation (17 → ~6 iterations on skewed
    graphs). The peel path probes full adjacencies."""
    d = g.dplus if oriented else g.degrees
    dmax = int(d.max(initial=1))
    return max(1, int(np.ceil(np.log2(dmax + 1))) + 1)


@functools.partial(jax.jit, static_argnames=("iters", "m"))
def _support_jit(N, Eid, e1, cand_slot, lo, hi, iters: int, m: int):
    hit, safe = probe(N, cand_slot, lo, hi, iters=iters)
    e2 = Eid[cand_slot]
    e3 = Eid[safe]
    inc = hit.astype(jnp.int32)
    S = jnp.zeros((m,), jnp.int32)
    S = S.at[e1].add(inc)
    S = S.at[jnp.where(hit, e2, 0)].add(inc)  # masked: inc==0 adds nothing
    S = S.at[jnp.where(hit, e3, 0)].add(inc)
    return S


def compute_support(g: CSRGraph, table: WedgeTable | None = None, *,
                    mode: str = "jnp", chunk: int | None = None,
                    interpret: bool | None = None,
                    table_mode: str | None = None) -> np.ndarray:
    """Edge support (triangles per edge) via the AM4 adaptation. Returns (m,).

    ``mode`` selects the executor (see module docstring): "jnp" is the flat
    XLA program, "pallas" the chunked VMEM kernel (``chunk`` entries per grid
    step, auto-derived from the table size when None; ``interpret``
    forces/forbids interpret mode, default off-TPU).  ``table_mode`` selects
    where the wedge table is constructed (``TABLE_MODES``): "device" (the
    default when no prebuilt ``table`` is passed) runs the jitted XLA
    builder, "numpy" the original host builder.
    """
    if mode not in SUPPORT_MODES:
        raise ValueError(f"mode must be one of {SUPPORT_MODES}, got {mode!r}")
    if table_mode is None:
        table_mode = "numpy" if table is not None else "device"
    if table_mode not in TABLE_MODES:
        raise ValueError(
            f"table_mode must be one of {TABLE_MODES}, got {table_mode!r}")
    if g.m == 0:
        return np.zeros(0, np.int32)
    if table_mode == "device" and table is None:
        if interpret is None:
            interpret = interpret_default()
        return np.asarray(
            _support_device(g, mode=mode, chunk=chunk, interpret=interpret))
    if table is None:
        table = build_support_table(g)
    if table.size == 0:
        # triangle-free under the orientation (e.g. stars): nothing to probe
        return np.zeros(g.m, np.int32)
    if mode == "pallas":
        from repro.kernels.support import support_counts

        if interpret is None:
            interpret = interpret_default()
        chunk_eff, n_chunks = chunk_layout(table.size, chunk)
        e1, cand, lo, hi = pad_chunked(
            table.e1, table.cand_slot, table.lo, table.hi,
            m=g.m, chunk=chunk_eff, n_chunks=n_chunks)
        S_ext, _ = support_counts(
            jnp.asarray(e1), jnp.asarray(cand), jnp.asarray(lo),
            jnp.asarray(hi), jnp.asarray(g.N), jnp.asarray(g.Eid),
            chunk=chunk_eff, n_chunks=n_chunks,
            iters=_search_iters(g, oriented=True), m=g.m,
            interpret=interpret)
        return np.asarray(S_ext)[: g.m]
    S = _support_jit(
        jnp.asarray(g.N), jnp.asarray(g.Eid),
        jnp.asarray(table.e1), jnp.asarray(table.cand_slot),
        jnp.asarray(table.lo), jnp.asarray(table.hi),
        _search_iters(g, oriented=True), g.m,
    )
    return np.asarray(S)


def triangle_count(g: CSRGraph) -> int:
    """Total triangles = sum(S)/3."""
    S = compute_support(g)
    return int(S.sum()) // 3


# --- Ros (Algorithm 2) support computation: edge-based, unordered -----------
#
# For each edge (u,v) the FULL adjacencies are intersected (no orientation),
# so every triangle is counted once *per edge* (3x total work vs AM4 — the
# paper's Σ d(v)^2 vs Σ d⁺(v)^2 gap). Kept as the baseline for Table 2/3.

@functools.partial(jax.jit, static_argnames=("iters", "m"))
def _support_ros_jit(N, e1, cand_slot, lo, hi, iters: int, m: int):
    hit, _ = probe(N, cand_slot, lo, hi, iters=iters)
    S = jnp.zeros((m,), jnp.int32)
    S = S.at[e1].add(hit.astype(jnp.int32))
    return S


def compute_support_ros(g: CSRGraph, table: WedgeTable | None = None) -> np.ndarray:
    """Ros-style support: per-edge full intersection (work ∝ Σ d(v)^2)."""
    if g.m == 0:
        return np.zeros(0, np.int32)
    if table is None:
        table = build_peel_table(g)
    S = _support_ros_jit(
        jnp.asarray(g.N),
        jnp.asarray(table.e1), jnp.asarray(table.cand_slot),
        jnp.asarray(table.lo), jnp.asarray(table.hi),
        _search_iters(g), g.m,
    )
    return np.asarray(S)
