"""Parallel edge-support computation — the AM4 (Algorithm 3) TPU adaptation.

The paper orients edges by increasing k-core vertex order and counts each
triangle once in canonical order, using a thread-local size-n scratch array X
for O(1) membership tests. On TPU there is no per-thread random-access scratch;
the adaptation (DESIGN.md §2) replaces X with:

  * a *flat oriented wedge table* built once per graph: one entry per
    (oriented edge (u→v), candidate w ∈ N⁺(v)) pair — exactly the wedges the
    AM4 loop nest inspects, Θ(Σ_v d⁻(v)·d⁺(v)) entries;
  * a vectorized *ranged binary search* of w in N⁺(u) (sorted CSR rows) —
    the membership test, O(log d⁺) gathers per probe;
  * scatter-adds into S — the deterministic analogue of the three AtomicAdds.

Each triangle u<v<w is discovered exactly once, anchored at its lowest-vertex
edge (u,v) with w scanned from N⁺(v). Work: Θ(m + Σ_v d⁻(v)·d⁺(v)·log d⁺) —
the ordering-dependence (Table 2) is preserved: relabeling by coreness shrinks
d⁺ exactly as in the paper.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class WedgeTable:
    """Flat (edge, candidate-slot) table + per-query search ranges."""

    e1: np.ndarray       # (Nw,) int32 — edge id of (u, v)
    cand_slot: np.ndarray  # (Nw,) int32 — CSR slot of w (gives w and Eid e2)
    lo: np.ndarray       # (Nw,) int32 — probe range start in N
    hi: np.ndarray       # (Nw,) int32 — probe range end in N
    off: np.ndarray      # (m+1,) int64 — entries of edge e at [off[e], off[e+1])

    @property
    def size(self) -> int:
        return int(self.e1.shape[0])


def build_support_table(g: CSRGraph) -> WedgeTable:
    """Oriented wedge table: for edge (u,v), candidates w ∈ N⁺(v), probe N⁺(u)."""
    u = g.El[:, 0].astype(np.int64)
    v = g.El[:, 1].astype(np.int64)
    Es = g.Es.astype(np.int64)
    Eo = g.Eo.astype(np.int64)
    cnt = Es[v + 1] - Eo[v]                      # |N⁺(v)| per edge
    off = np.zeros(g.m + 1, dtype=np.int64)
    np.cumsum(cnt, out=off[1:])
    Nw = int(off[-1])
    e1 = np.repeat(np.arange(g.m, dtype=np.int64), cnt)
    intra = np.arange(Nw, dtype=np.int64) - off[e1]
    cand_slot = Eo[v[e1]] + intra
    lo = Eo[u[e1]]
    hi = Es[u[e1] + 1]
    return WedgeTable(
        e1=e1.astype(np.int32),
        cand_slot=cand_slot.astype(np.int32),
        lo=lo.astype(np.int32),
        hi=hi.astype(np.int32),
        off=off,
    )


def build_peel_table(g: CSRGraph) -> WedgeTable:
    """Full-adjacency wedge table used by the peel phase.

    For edge e=(u,v): candidates w from the *smaller*-degree endpoint's full
    adjacency, probed against the other endpoint's full adjacency — the
    ProcessSubLevel loop nest of Algorithm 5 with the cheap side chosen
    (the paper marks N(u) and scans N(v); we pick min-degree for the scan).
    """
    u = g.El[:, 0].astype(np.int64)
    v = g.El[:, 1].astype(np.int64)
    Es = g.Es.astype(np.int64)
    deg = (Es[1:] - Es[:-1])
    swap = deg[u] > deg[v]
    cand = np.where(swap, v, u)                  # scan this side
    probe = np.where(swap, u, v)                 # binary-search this side
    cnt = deg[cand]
    off = np.zeros(g.m + 1, dtype=np.int64)
    np.cumsum(cnt, out=off[1:])
    Nw = int(off[-1])
    e1 = np.repeat(np.arange(g.m, dtype=np.int64), cnt)
    intra = np.arange(Nw, dtype=np.int64) - off[e1]
    cand_slot = Es[cand[e1]] + intra
    lo = Es[probe[e1]]
    hi = Es[probe[e1] + 1]
    return WedgeTable(
        e1=e1.astype(np.int32),
        cand_slot=cand_slot.astype(np.int32),
        lo=lo.astype(np.int32),
        hi=hi.astype(np.int32),
        off=off,
    )


def ranged_searchsorted(N: jnp.ndarray, w: jnp.ndarray, lo: jnp.ndarray,
                        hi: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Vectorized lower-bound binary search of w in sorted N[lo:hi).

    Returns the insertion index (== hi when all elements < w). ``iters`` must
    be >= ceil(log2(max(hi - lo) + 1)).
    """
    def body(_, state):
        lo_, hi_ = state
        mid = (lo_ + hi_) >> 1
        val = N[mid]
        go_right = val < w
        lo_ = jnp.where(go_right & (lo_ < hi_), mid + 1, lo_)
        hi_ = jnp.where((~go_right) & (lo_ < hi_), mid, hi_)
        return lo_, hi_

    lo_f, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo_f


def _search_iters(g: CSRGraph, *, oriented: bool = False) -> int:
    """Binary-search iteration bound = log2(max probe-range length).

    The support path probes only N⁺(u) ranges, whose length is bounded by
    the degeneracy after KCO relabeling — this is where the paper's
    ordering win lands in our adaptation (17 → ~6 iterations on skewed
    graphs). The peel path probes full adjacencies."""
    d = g.dplus if oriented else g.degrees
    dmax = int(d.max(initial=1))
    return max(1, int(np.ceil(np.log2(dmax + 1))) + 1)


@functools.partial(jax.jit, static_argnames=("iters", "m"))
def _support_jit(N, Eid, e1, cand_slot, lo, hi, iters: int, m: int):
    w = N[cand_slot]
    idx = ranged_searchsorted(N, w, lo, hi, iters)
    safe = jnp.minimum(idx, N.shape[0] - 1)
    hit = (idx < hi) & (N[safe] == w)
    e2 = Eid[cand_slot]
    e3 = Eid[safe]
    inc = hit.astype(jnp.int32)
    S = jnp.zeros((m,), jnp.int32)
    S = S.at[e1].add(inc)
    S = S.at[jnp.where(hit, e2, 0)].add(inc)  # masked: inc==0 adds nothing
    S = S.at[jnp.where(hit, e3, 0)].add(inc)
    return S


def compute_support(g: CSRGraph, table: WedgeTable | None = None) -> np.ndarray:
    """Edge support (triangles per edge) via the AM4 adaptation. Returns (m,)."""
    if g.m == 0:
        return np.zeros(0, np.int32)
    if table is None:
        table = build_support_table(g)
    S = _support_jit(
        jnp.asarray(g.N), jnp.asarray(g.Eid),
        jnp.asarray(table.e1), jnp.asarray(table.cand_slot),
        jnp.asarray(table.lo), jnp.asarray(table.hi),
        _search_iters(g, oriented=True), g.m,
    )
    return np.asarray(S)


def triangle_count(g: CSRGraph) -> int:
    """Total triangles = sum(S)/3."""
    S = compute_support(g)
    return int(S.sum()) // 3


# --- Ros (Algorithm 2) support computation: edge-based, unordered -----------
#
# For each edge (u,v) the FULL adjacencies are intersected (no orientation),
# so every triangle is counted once *per edge* (3x total work vs AM4 — the
# paper's Σ d(v)^2 vs Σ d⁺(v)^2 gap). Kept as the baseline for Table 2/3.

@functools.partial(jax.jit, static_argnames=("iters", "m"))
def _support_ros_jit(N, e1, cand_slot, lo, hi, iters: int, m: int):
    w = N[cand_slot]
    idx = ranged_searchsorted(N, w, lo, hi, iters)
    safe = jnp.minimum(idx, N.shape[0] - 1)
    hit = (idx < hi) & (N[safe] == w)
    S = jnp.zeros((m,), jnp.int32)
    S = S.at[e1].add(hit.astype(jnp.int32))
    return S


def compute_support_ros(g: CSRGraph, table: WedgeTable | None = None) -> np.ndarray:
    """Ros-style support: per-edge full intersection (work ∝ Σ d(v)^2)."""
    if g.m == 0:
        return np.zeros(0, np.int32)
    if table is None:
        table = build_peel_table(g)
    S = _support_ros_jit(
        jnp.asarray(g.N),
        jnp.asarray(table.e1), jnp.asarray(table.cand_slot),
        jnp.asarray(table.lo), jnp.asarray(table.hi),
        _search_iters(g), g.m,
    )
    return np.asarray(S)
