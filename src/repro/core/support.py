"""Parallel edge-support computation — the AM4 (Algorithm 3) TPU adaptation.

The paper orients edges by increasing k-core vertex order and counts each
triangle once in canonical order, using a thread-local size-n scratch array X
for O(1) membership tests. On TPU there is no per-thread random-access scratch;
the adaptation (DESIGN.md §2) replaces X with:

  * a *flat oriented wedge table* built once per graph: one entry per
    (oriented edge (u→v), candidate w ∈ N⁺(v)) pair — exactly the wedges the
    AM4 loop nest inspects, Θ(Σ_v d⁻(v)·d⁺(v)) entries;
  * a vectorized *ranged binary search* of w in N⁺(u) (sorted CSR rows) —
    the membership test, O(log d⁺) gathers per probe;
  * scatter-adds into S — the deterministic analogue of the three AtomicAdds.

Each triangle u<v<w is discovered exactly once, anchored at its lowest-vertex
edge (u,v) with w scanned from N⁺(v). Work: Θ(m + Σ_v d⁻(v)·d⁺(v)·log d⁺) —
the ordering-dependence (Table 2) is preserved: relabeling by coreness shrinks
d⁺ exactly as in the paper.

Two execution modes (``compute_support(mode=...)``), bitwise identical:

  mode="jnp" (default): the wedge table is evaluated as one flat jnp
      gather/search/scatter program (``_support_jit``) — XLA fuses it, but
      every probe round-trips through HBM.
  mode="pallas": the table is cut into fixed chunks and evaluated by the
      Pallas kernel in ``kernels/support.py`` (DESIGN.md §2) — one chunk per
      grid step, the candidate gather fused with the ranged binary search in
      VMEM, per-chunk triangle partials accumulated on-chip.  The kernel
      emits increment-target streams; the support scatter-add happens once
      outside, so integer-exact addition makes the two modes agree bitwise.
      Off-TPU the kernel runs in interpret mode (CI lowers it on every PR).

The peel phase has the same split (``core.pkt.pkt(mode=...)``); the two
kernels share layout and search machinery via ``kernels/wedge_common.py``.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from repro.kernels.wedge_common import (chunk_layout, interpret_default,
                                        pad_chunked, probe,
                                        ranged_searchsorted)

#: executors for the support phase; "pallas" = kernels/support.py
SUPPORT_MODES = ("jnp", "pallas")


@dataclasses.dataclass(frozen=True)
class WedgeTable:
    """Flat (edge, candidate-slot) table + per-query search ranges."""

    e1: np.ndarray       # (Nw,) int32 — edge id of (u, v)
    cand_slot: np.ndarray  # (Nw,) int32 — CSR slot of w (gives w and Eid e2)
    lo: np.ndarray       # (Nw,) int32 — probe range start in N
    hi: np.ndarray       # (Nw,) int32 — probe range end in N
    off: np.ndarray      # (m+1,) int64 — entries of edge e at [off[e], off[e+1])

    @property
    def size(self) -> int:
        return int(self.e1.shape[0])


def build_support_table(g: CSRGraph) -> WedgeTable:
    """Oriented wedge table: for edge (u,v), candidates w ∈ N⁺(v), probe N⁺(u)."""
    u = g.El[:, 0].astype(np.int64)
    v = g.El[:, 1].astype(np.int64)
    Es = g.Es.astype(np.int64)
    Eo = g.Eo.astype(np.int64)
    cnt = Es[v + 1] - Eo[v]                      # |N⁺(v)| per edge
    off = np.zeros(g.m + 1, dtype=np.int64)
    np.cumsum(cnt, out=off[1:])
    Nw = int(off[-1])
    e1 = np.repeat(np.arange(g.m, dtype=np.int64), cnt)
    intra = np.arange(Nw, dtype=np.int64) - off[e1]
    cand_slot = Eo[v[e1]] + intra
    lo = Eo[u[e1]]
    hi = Es[u[e1] + 1]
    return WedgeTable(
        e1=e1.astype(np.int32),
        cand_slot=cand_slot.astype(np.int32),
        lo=lo.astype(np.int32),
        hi=hi.astype(np.int32),
        off=off,
    )


def build_peel_table(g: CSRGraph) -> WedgeTable:
    """Full-adjacency wedge table used by the peel phase.

    For edge e=(u,v): candidates w from the *smaller*-degree endpoint's full
    adjacency, probed against the other endpoint's full adjacency — the
    ProcessSubLevel loop nest of Algorithm 5 with the cheap side chosen
    (the paper marks N(u) and scans N(v); we pick min-degree for the scan).
    """
    u = g.El[:, 0].astype(np.int64)
    v = g.El[:, 1].astype(np.int64)
    Es = g.Es.astype(np.int64)
    deg = (Es[1:] - Es[:-1])
    swap = deg[u] > deg[v]
    cand = np.where(swap, v, u)                  # scan this side
    probe = np.where(swap, u, v)                 # binary-search this side
    cnt = deg[cand]
    off = np.zeros(g.m + 1, dtype=np.int64)
    np.cumsum(cnt, out=off[1:])
    Nw = int(off[-1])
    e1 = np.repeat(np.arange(g.m, dtype=np.int64), cnt)
    intra = np.arange(Nw, dtype=np.int64) - off[e1]
    cand_slot = Es[cand[e1]] + intra
    lo = Es[probe[e1]]
    hi = Es[probe[e1] + 1]
    return WedgeTable(
        e1=e1.astype(np.int32),
        cand_slot=cand_slot.astype(np.int32),
        lo=lo.astype(np.int32),
        hi=hi.astype(np.int32),
        off=off,
    )


# ``ranged_searchsorted`` lives in kernels/wedge_common.py (shared with the
# Pallas kernels) and is re-exported here for its established call sites
# (core/pkt.py, core/pkt_dist.py, core/triangle_list.py, benchmarks).


def _search_iters(g: CSRGraph, *, oriented: bool = False) -> int:
    """Binary-search iteration bound = log2(max probe-range length).

    The support path probes only N⁺(u) ranges, whose length is bounded by
    the degeneracy after KCO relabeling — this is where the paper's
    ordering win lands in our adaptation (17 → ~6 iterations on skewed
    graphs). The peel path probes full adjacencies."""
    d = g.dplus if oriented else g.degrees
    dmax = int(d.max(initial=1))
    return max(1, int(np.ceil(np.log2(dmax + 1))) + 1)


@functools.partial(jax.jit, static_argnames=("iters", "m"))
def _support_jit(N, Eid, e1, cand_slot, lo, hi, iters: int, m: int):
    hit, safe = probe(N, cand_slot, lo, hi, iters=iters)
    e2 = Eid[cand_slot]
    e3 = Eid[safe]
    inc = hit.astype(jnp.int32)
    S = jnp.zeros((m,), jnp.int32)
    S = S.at[e1].add(inc)
    S = S.at[jnp.where(hit, e2, 0)].add(inc)  # masked: inc==0 adds nothing
    S = S.at[jnp.where(hit, e3, 0)].add(inc)
    return S


def compute_support(g: CSRGraph, table: WedgeTable | None = None, *,
                    mode: str = "jnp", chunk: int = 1 << 14,
                    interpret: bool | None = None) -> np.ndarray:
    """Edge support (triangles per edge) via the AM4 adaptation. Returns (m,).

    ``mode`` selects the executor (see module docstring): "jnp" is the flat
    XLA program, "pallas" the chunked VMEM kernel (``chunk`` entries per grid
    step; ``interpret`` forces/forbids interpret mode, default off-TPU).
    """
    if mode not in SUPPORT_MODES:
        raise ValueError(f"mode must be one of {SUPPORT_MODES}, got {mode!r}")
    if g.m == 0:
        return np.zeros(0, np.int32)
    if table is None:
        table = build_support_table(g)
    if table.size == 0:
        # triangle-free under the orientation (e.g. stars): nothing to probe
        return np.zeros(g.m, np.int32)
    if mode == "pallas":
        from repro.kernels.support import support_counts

        if interpret is None:
            interpret = interpret_default()
        chunk_eff, n_chunks = chunk_layout(table.size, chunk)
        e1, cand, lo, hi = pad_chunked(
            table.e1, table.cand_slot, table.lo, table.hi,
            m=g.m, chunk=chunk_eff, n_chunks=n_chunks)
        S_ext, _ = support_counts(
            jnp.asarray(e1), jnp.asarray(cand), jnp.asarray(lo),
            jnp.asarray(hi), jnp.asarray(g.N), jnp.asarray(g.Eid),
            chunk=chunk_eff, n_chunks=n_chunks,
            iters=_search_iters(g, oriented=True), m=g.m,
            interpret=interpret)
        return np.asarray(S_ext)[: g.m]
    S = _support_jit(
        jnp.asarray(g.N), jnp.asarray(g.Eid),
        jnp.asarray(table.e1), jnp.asarray(table.cand_slot),
        jnp.asarray(table.lo), jnp.asarray(table.hi),
        _search_iters(g, oriented=True), g.m,
    )
    return np.asarray(S)


def triangle_count(g: CSRGraph) -> int:
    """Total triangles = sum(S)/3."""
    S = compute_support(g)
    return int(S.sum()) // 3


# --- Ros (Algorithm 2) support computation: edge-based, unordered -----------
#
# For each edge (u,v) the FULL adjacencies are intersected (no orientation),
# so every triangle is counted once *per edge* (3x total work vs AM4 — the
# paper's Σ d(v)^2 vs Σ d⁺(v)^2 gap). Kept as the baseline for Table 2/3.

@functools.partial(jax.jit, static_argnames=("iters", "m"))
def _support_ros_jit(N, e1, cand_slot, lo, hi, iters: int, m: int):
    hit, _ = probe(N, cand_slot, lo, hi, iters=iters)
    S = jnp.zeros((m,), jnp.int32)
    S = S.at[e1].add(hit.astype(jnp.int32))
    return S


def compute_support_ros(g: CSRGraph, table: WedgeTable | None = None) -> np.ndarray:
    """Ros-style support: per-edge full intersection (work ∝ Σ d(v)^2)."""
    if g.m == 0:
        return np.zeros(0, np.int32)
    if table is None:
        table = build_peel_table(g)
    S = _support_ros_jit(
        jnp.asarray(g.N),
        jnp.asarray(table.e1), jnp.asarray(table.cand_slot),
        jnp.asarray(table.lo), jnp.asarray(table.hi),
        _search_iters(g), g.m,
    )
    return np.asarray(S)
