"""k-core decomposition: BZ (numpy oracle) and ParK-style level-synchronous JAX.

The paper preprocesses every graph with a k-core decomposition + coreness
reordering (its Table 2 shows up to 17x triangle-counting speedups from the
ordering), and PKT itself is "based on a recently proposed algorithm for k-core
decomposition" (ParK). So k-core is a first-class substrate here:

  - ``kcore_numpy``: Batagelj–Zaversnik bucket peeling, O(n + m). Oracle.
  - ``kcore_park``:  ParK-style level-synchronous parallel peeling in JAX —
    the same curr/next frontier pattern PKT uses, over vertices.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph


def kcore_numpy(g: CSRGraph) -> np.ndarray:
    """BZ algorithm: returns coreness per vertex (int32)."""
    n = g.n
    deg = g.degrees.astype(np.int64).copy()
    if n == 0:
        return np.zeros(0, np.int32)
    md = int(deg.max(initial=0))
    # bucket sort vertices by degree
    bin_start = np.zeros(md + 2, dtype=np.int64)
    np.add.at(bin_start, deg + 1, 1)
    bin_start = np.cumsum(bin_start)
    pos = np.zeros(n, dtype=np.int64)
    vert = np.zeros(n, dtype=np.int64)
    fill = bin_start[:-1].copy()
    for v in range(n):
        pos[v] = fill[deg[v]]
        vert[pos[v]] = v
        fill[deg[v]] += 1
    core = deg.copy()
    for i in range(n):
        v = vert[i]
        for j in range(g.Es[v], g.Es[v + 1]):
            u = g.N[j]
            if core[u] > core[v]:
                # move u one bucket down (swap with first vertex of its bucket)
                du = core[u]
                pu = pos[u]
                pw = bin_start[du]
                w = vert[pw]
                if u != w:
                    vert[pu], vert[pw] = w, u
                    pos[u], pos[w] = pw, pu
                bin_start[du] += 1
                core[u] -= 1
    return core.astype(np.int32)


def _kcore_park_jit(Es: jnp.ndarray, N: jnp.ndarray, deg0: jnp.ndarray,
                    n: int, max_deg_pad: int):
    """Level-synchronous peeling over vertices, dense-mask formulation.

    Each sub-level removes the frontier {v alive : deg[v] <= l} at once and
    subtracts, for every remaining vertex, the number of its neighbors that
    just died. Neighbor counts are computed by a scatter-add over the CSR
    (the SPMD analogue of ParK's atomic decrements).
    """
    two_m = N.shape[0]
    row_of_slot = jnp.repeat(jnp.arange(n), Es[1:] - Es[:-1],
                             total_repeat_length=two_m)

    def level_body(state):
        deg, core, alive, l, todo = state

        def sub_body(sub_state):
            deg, core, alive, moved = sub_state
            frontier = alive & (deg <= l)
            core = jnp.where(frontier, l, core)
            alive = alive & ~frontier
            # neighbors of frontier vertices lose one degree per dead slot
            dead_slot = frontier[row_of_slot]
            dec = jnp.zeros((n,), deg.dtype).at[N].add(
                dead_slot.astype(deg.dtype))
            deg = jnp.where(alive, deg - dec, deg)
            return deg, core, alive, jnp.sum(frontier)

        def sub_cond(sub_state):
            deg, _, alive, moved = sub_state
            return moved > 0

        deg, core, alive, _ = jax.lax.while_loop(
            sub_cond, sub_body, (deg, core, alive, jnp.int32(1)))
        todo = jnp.sum(alive)
        return deg, core, alive, l + 1, todo

    def level_cond(state):
        return state[4] > 0

    deg = deg0
    core = jnp.zeros((n,), deg.dtype)
    alive = jnp.ones((n,), jnp.bool_)
    state = (deg, core, alive, jnp.int32(0), jnp.int32(n))
    _, core, _, _, _ = jax.lax.while_loop(level_cond, level_body, state)
    return core


def kcore_park(g: CSRGraph) -> np.ndarray:
    """ParK-style JAX k-core; returns coreness per vertex."""
    if g.n == 0:
        return np.zeros(0, np.int32)
    fn = jax.jit(_kcore_park_jit, static_argnums=(3, 4))
    core = fn(jnp.asarray(g.Es), jnp.asarray(g.N),
              jnp.asarray(g.degrees), g.n, int(g.degrees.max(initial=0)))
    return np.asarray(core)
