"""trusslint: repo-native static analysis for the truss system.

``python -m repro.analysis src/ --strict`` runs the full rule set
(DESIGN.md §14): JAX discipline (J001-J004), Pallas kernel contracts
(P001-P002), lock discipline (L001-L003), and module liveness
(U001/U002).  :class:`RetraceGuard` is the runtime companion used by
``benchmarks/retrace_bench.py`` to budget jit compile-cache growth.
The package is stdlib-only so the CI job runs without installing jax.
"""

from repro.analysis.config import LintConfig, load_config
from repro.analysis.engine import RULE_DOCS, Finding, run_paths
from repro.analysis.retrace import RetraceGuard

__all__ = ["Finding", "LintConfig", "RetraceGuard", "RULE_DOCS",
           "load_config", "run_paths"]
