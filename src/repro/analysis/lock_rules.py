"""Lock-discipline rules (L001-L003) for the serving layer.

The scheduler's concurrency contract (DESIGN.md §12) is: every piece of
shared state is owned by ``self._lock`` (``self._work`` is a Condition
wrapping the same lock, so the two are aliases), and device dispatch
happens strictly outside the lock so a slow flush never blocks
admission.  The analyzer recovers that contract from the code itself:

* **L001** — an attribute assigned or mutated under ``with self._lock``
  anywhere in the class is *guarded*; any access outside a lock context
  (and outside ``__init__``, which runs happens-before thread start) is
  a race.  Functions documented as lock-internal carry a
  ``# trusslint: holds[_lock]`` annotation.
* **L002** — blocking calls (engine dispatch, ``join``, ``result``,
  ``sleep``...) must not run while a lock is held.
* **L003** — lock acquisition order must be acyclic across the whole
  analyzed set, and no lock may be re-acquired while already held
  (``threading.Lock`` is not reentrant).
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _self_attr(node) -> str | None:
    """Attribute name if ``node`` is ``self.<attr>``, else None."""
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


class LockChecker:
    """Stateful checker: per-file L001/L002 plus cross-file L003."""

    def __init__(self, cfg):
        self.cfg = cfg
        self._canon = {}
        for group in cfg.lock_aliases:
            for name in group:
                self._canon[name] = group[0]
        # (held, acquired) -> (rel, line) of the first acquisition site
        self.edges: dict = {}

    def canon(self, attr: str) -> str:
        """Canonical lock name (aliases collapse onto one lock)."""
        return self._canon.get(attr, attr)

    def _lock_of(self, expr) -> str | None:
        """Canonical lock acquired by a ``with`` item, or None."""
        attr = _self_attr(expr)
        if attr in self.cfg.lock_attrs:
            return self.canon(attr)
        return None

    # -- pass 1: guarded-attribute inference ----------------------------

    def _guarded_attrs(self, cls) -> set:
        """Attributes assigned or mutated under a lock in ``cls``."""
        guarded: set = set()

        def visit(node, held):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                locks = [self._lock_of(i.context_expr) for i in node.items]
                held = held + [k for k in locks if k]
            if held:
                self._record_mutations(node, guarded)
            for child in ast.iter_child_nodes(node):
                visit(child, held)

        for method in cls.body:
            if isinstance(method, _FUNC_NODES) \
                    and method.name != "__init__":
                visit(method, [])
        return guarded

    def _record_mutations(self, node, guarded) -> None:
        """Add attributes that ``node`` mutates to ``guarded``."""
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = node.targets
        for tgt in targets:
            if isinstance(tgt, (ast.Tuple, ast.List)):
                targets.extend(tgt.elts)
                continue
            if isinstance(tgt, ast.Subscript):
                tgt = tgt.value
            attr = _self_attr(tgt)
            if attr is not None:
                guarded.add(attr)
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in self.cfg.mutator_methods:
            recv = node.func.value
            if isinstance(recv, ast.Subscript):
                recv = recv.value
            attr = _self_attr(recv)
            if attr is not None:
                guarded.add(attr)

    # -- pass 2: violations ---------------------------------------------

    def _blocking(self, call) -> str | None:
        """Reason string if ``call`` blocks (dispatch/join/...), else None."""
        if not isinstance(call.func, ast.Attribute):
            return None
        name = call.func.attr
        if name in self.cfg.blocking_always:
            return f"`.{name}()` blocks"
        recv = []
        node = call.func.value
        while isinstance(node, ast.Attribute):
            recv.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            recv.append(node.id)
        recv_text = ".".join(recv).lower()
        if name in self.cfg.blocking_engine \
                and any(h in recv_text
                        for h in self.cfg.engine_receiver_hints):
            return f"engine dispatch `.{name}()` blocks on the device"
        return None

    def check_file(self, ctx) -> list:
        """L001/L002 findings for one file; records L003 edges."""
        findings: list = []
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            has_locks = any(
                _self_attr(n) in self.cfg.lock_attrs
                for n in ast.walk(cls) if isinstance(n, ast.Attribute))
            if not has_locks:
                continue
            guarded = self._guarded_attrs(cls)
            for method in cls.body:
                if not isinstance(method, _FUNC_NODES) \
                        or method.name == "__init__":
                    continue
                annotated = {self.canon(k)
                             for k in ctx.holds_for_def(method)}
                self._check_method(method, ctx, guarded,
                                   list(annotated), findings)
        return findings

    def _check_method(self, method, ctx, guarded, held0, findings) -> None:
        """Walk one method tracking the held-lock stack."""

        def visit(node, held):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    lock = self._lock_of(item.context_expr)
                    if lock is None:
                        continue
                    if lock in held:
                        findings.append(Finding(
                            "L003", ctx.rel, node.lineno,
                            f"`{lock}` re-acquired while already held"
                            " (threading.Lock is not reentrant)"))
                    elif held:
                        self.edges.setdefault(
                            (held[-1], lock), (ctx.rel, node.lineno))
                    held = held + [lock]
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.ctx, (ast.Load, ast.Store,
                                              ast.Del)):
                attr = _self_attr(node)
                if attr in guarded and not held:
                    findings.append(Finding(
                        "L001", ctx.rel, node.lineno,
                        f"`self.{attr}` is guarded by a lock but accessed"
                        f" here without holding one"))
            if isinstance(node, ast.Call) and held:
                reason = self._blocking(node)
                if reason is not None:
                    findings.append(Finding(
                        "L002", ctx.rel, node.lineno,
                        f"{reason} while a lock is held"))
            for child in ast.iter_child_nodes(node):
                visit(child, held)

        for stmt in method.body:
            visit(stmt, list(held0))

    # -- cross-file: lock-order cycles ----------------------------------

    def finalize(self) -> list:
        """L003 findings for acquisition-order cycles across all files."""
        graph: dict = {}
        for (a, b) in self.edges:
            graph.setdefault(a, set()).add(b)
        findings = []
        for (a, b), (rel, line) in sorted(self.edges.items()):
            # cycle iff b can reach a
            seen, stack = set(), [b]
            while stack:
                node = stack.pop()
                if node == a:
                    findings.append(Finding(
                        "L003", rel, line,
                        f"lock-order cycle: `{b}` acquired while holding"
                        f" `{a}`, but `{a}` is also acquired under"
                        f" `{b}` elsewhere"))
                    break
                if node in seen:
                    continue
                seen.add(node)
                stack.extend(graph.get(node, ()))
        return findings
