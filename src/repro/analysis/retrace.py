"""Runtime retracing guard: jit compile-cache budgets per call site.

Static rule J002 catches shape-derived static arguments; this module
catches what static analysis cannot — the *observed* number of XLA
compilations a workload actually triggers.  ``RetraceGuard`` snapshots
each registered jit callable's compile-cache size (jax exposes it as
``fn._cache_size()``) around a workload and compares the growth against
a per-site budget from ``[tool.trusslint.retrace]``.  The bench gate
(``benchmarks/retrace_bench.py``) runs the engine-flush and
handle-update smoke workloads under a guard, writes
``BENCH_retrace.json``, and exits nonzero when a hot path (engine
flush, ``_peel_loop`` segments, ``_region_peel``) compiles more than
its budget allows — i.e. when someone breaks the pow2 ``SizeClass``
bucketing contract in a way that only shows up as silent recompiles.

This module never imports jax: it only calls the private-but-stable
``_cache_size`` hook when present, and reports sites as unmeasured
(passing) on jax builds without it.
"""

from __future__ import annotations


def cache_size(fn) -> int | None:
    """Current compile-cache entry count of a jit callable, if exposed."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


class RetraceGuard:
    """Context manager budgeting compile-cache growth per call site.

    >>> guard = RetraceGuard(budgets={"engine_flush": 4})
    >>> guard.track("engine_flush", _batched_truss_dev)
    >>> with guard:
    ...     run_workload()
    >>> guard.ok()
    True
    """

    def __init__(self, budgets: dict | None = None):
        self.budgets = dict(budgets or {})
        self._fns: dict = {}
        self._start: dict = {}
        self._stop: dict = {}

    def track(self, name: str, fn, budget: int | None = None) -> None:
        """Register ``fn`` (jit-wrapped) under call-site name ``name``."""
        self._fns[name] = fn
        if budget is not None:
            self.budgets[name] = budget

    def __enter__(self):
        self._start = {n: cache_size(f) for n, f in self._fns.items()}
        self._stop = {}
        return self

    def __exit__(self, *exc):
        self._stop = {n: cache_size(f) for n, f in self._fns.items()}
        return False

    def compiles(self, name: str) -> int | None:
        """Observed compile count for ``name`` (None if unmeasurable)."""
        start, stop = self._start.get(name), self._stop.get(name)
        if start is None or stop is None:
            return None
        return stop - start

    def report(self) -> dict:
        """Per-site dict: compiles, budget, and the pass/fail verdict."""
        out = {}
        for name in self._fns:
            compiles = self.compiles(name)
            budget = self.budgets.get(name)
            ok = True
            if compiles is not None and budget is not None:
                ok = compiles <= budget
            out[name] = {"compiles": compiles, "budget": budget,
                         "measured": compiles is not None, "ok": ok}
        return out

    def ok(self) -> bool:
        """True when every measured site is within its budget."""
        return all(site["ok"] for site in self.report().values())

    def violations(self) -> list:
        """Names of sites that exceeded their compile budget."""
        return sorted(n for n, s in self.report().items() if not s["ok"])
