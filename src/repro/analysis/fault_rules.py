"""R-rules: fault-routing discipline for the serving path (DESIGN.md §15).

The resilience contract is that *every* failure on the serving path is
typed and routed — to a request future (``_finish(req, exc=e)`` /
``future.set_exception(e)``) or re-raised for the retry/heal machinery to
classify.  A bare ``except Exception:`` that swallows the error instead
silently converts a fault into a wrong or missing answer, which is
exactly what the chaos harness exists to rule out.

R001 therefore flags broad exception handlers (``except Exception`` /
``except BaseException``, bare ``except:``) in the configured fault-path
files (``[tool.trusslint.faults] paths`` — by default the serving layer
and the incremental core) unless the handler body visibly routes the
error: it re-raises (any ``raise``) or calls one of the configured sink
callables (``[tool.trusslint.faults] sinks`` — ``_finish`` and
``set_exception`` by default).  Handlers for narrow exception types are
out of scope: catching a specific error is a decision, catching
everything is a leak.
"""

from __future__ import annotations

import ast
import fnmatch

from repro.analysis.engine import Finding

_BROAD = ("Exception", "BaseException")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    """True for ``except:``, ``except Exception``, ``except BaseException``.

    Tuple handlers count as broad when any member is a broad name, since
    the tuple catches at least that much.
    """
    t = handler.type
    if t is None:
        return True
    members = t.elts if isinstance(t, ast.Tuple) else [t]
    for m in members:
        name = m.id if isinstance(m, ast.Name) else \
            m.attr if isinstance(m, ast.Attribute) else None
        if name in _BROAD:
            return True
    return False


def _routes(handler: ast.ExceptHandler, sinks) -> bool:
    """True if the handler body re-raises or calls a fault sink."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            f = node.func
            name = f.id if isinstance(f, ast.Name) else \
                f.attr if isinstance(f, ast.Attribute) else None
            if name in sinks:
                return True
    return False


def check_file(ctx, cfg) -> list:
    """Run R001 over one parsed file; returns raw findings."""
    if not any(fnmatch.fnmatch(ctx.rel, pat) for pat in cfg.fault_paths):
        return []
    findings = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if not _is_broad(node) or _routes(node, cfg.fault_sinks):
            continue
        caught = "bare except" if node.type is None else \
            f"except {ast.unparse(node.type)}"
        findings.append(Finding(
            rule="R001", path=ctx.rel, line=node.lineno,
            message=f"{caught} swallows the error on the serving path: "
                    f"re-raise it or route it into a typed sink "
                    f"({', '.join(cfg.fault_sinks)}) so no fault "
                    f"disappears silently"))
    return findings
