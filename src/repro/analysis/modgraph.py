"""Module-liveness rules (U001/U002): the dead-code quarantine.

The repo carries pretrain-era scaffolding (``models/``, ``optim/``,
``configs/``...) that the truss system never imports.  Rather than
delete history, the config quarantines those modules: they are excluded
from the AST rule families and from ruff, and these two rules keep the
partition honest by walking the real import graph under ``src_root``:

* **U001** — every module must be reachable from a configured live root
  or explicitly quarantined; anything else is unintegrated dead code
  that would silently rot unanalyzed.
* **U002** — no live module may import a quarantined one, so
  scaffolding cannot leak back into tier-1 import paths.
"""

from __future__ import annotations

import ast
import pathlib

from repro.analysis.engine import Finding


def inventory(src_dir: pathlib.Path) -> dict:
    """Map dotted module name → source path for everything in the tree."""
    inv: dict = {}
    for path in sorted(src_dir.rglob("*.py")):
        parts = path.relative_to(src_dir).with_suffix("").parts
        if parts[-1] == "__init__":
            parts = parts[:-1]
        if parts:
            inv[".".join(parts)] = path
    return inv


def _add_with_ancestors(mod: str, inv: dict, deps: set) -> None:
    """Add ``mod`` (or its longest existing prefix) plus its packages."""
    parts = mod.split(".")
    while parts and ".".join(parts) not in inv:
        parts = parts[:-1]
    while parts:
        deps.add(".".join(parts))
        parts = parts[:-1]


def module_deps(tree, modname: str, is_pkg: bool, inv: dict) -> set:
    """Modules (within the inventory) that ``modname`` imports."""
    deps: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                _add_with_ancestors(alias.name, inv, deps)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                pkg = modname.split(".")
                if not is_pkg:
                    pkg = pkg[:-1]
                pkg = pkg[: len(pkg) - (node.level - 1)]
                base = ".".join(pkg + (node.module or "").split("."))
                base = base.rstrip(".")
            else:
                base = node.module or ""
            if not base:
                continue
            _add_with_ancestors(base, inv, deps)
            for alias in node.names:
                if f"{base}.{alias.name}" in inv:
                    _add_with_ancestors(f"{base}.{alias.name}", inv, deps)
    deps.discard(modname)
    return deps


def _quarantined(mod: str, cfg) -> str | None:
    """The quarantine prefix covering ``mod``, or None if it is live."""
    for q in cfg.quarantine:
        if mod == q or mod.startswith(q + "."):
            return q
    return None


def check(repo_root: pathlib.Path, cfg) -> list:
    """Run the liveness analysis; return U001/U002 findings."""
    src_dir = pathlib.Path(repo_root) / cfg.src_root
    inv = inventory(src_dir)
    if not inv:
        return []
    deps: dict = {}
    for mod, path in inv.items():
        tree = ast.parse(path.read_text(), filename=str(path))
        deps[mod] = module_deps(tree, mod, path.name == "__init__.py", inv)

    findings: list = []
    rel = {mod: path.relative_to(repo_root).as_posix()
           for mod, path in inv.items()}
    reachable: set = set()
    frontier = [r for r in cfg.roots if r in inv]
    # one breach per (module, quarantine prefix), reporting the most
    # specific imported name — `from pkg import sub` resolves to both
    # pkg and pkg.sub, and the finding should name pkg.sub
    breaches: dict = {}
    while frontier:
        mod = frontier.pop()
        if mod in reachable:
            continue
        reachable.add(mod)
        for dep in sorted(deps.get(mod, ())):
            prefix = _quarantined(dep, cfg)
            if prefix is not None:
                if _quarantined(mod, cfg) is None:
                    key = (mod, prefix)
                    if len(dep) > len(breaches.get(key, "")):
                        breaches[key] = dep
                continue  # do not traverse into quarantined subgraphs
            frontier.append(dep)
    for (mod, _prefix), dep in sorted(breaches.items()):
        findings.append(Finding(
            "U002", rel[mod], 1,
            f"live module imports quarantined scaffolding `{dep}`"))
    for mod in sorted(inv):
        if mod in reachable or _quarantined(mod, cfg):
            continue
        findings.append(Finding(
            "U001", rel[mod], 1,
            "module is unreachable from every configured live root;"
            " integrate it, add it to [tool.trusslint.modules].roots, or"
            " quarantine it"))
    return findings
