"""CLI for trusslint: ``python -m repro.analysis [paths...] [--strict]``.

Exit status is 0 when no unwaived findings remain, 1 otherwise (with
``--strict`` this is the CI ``static-analysis`` gate).  ``--json``
emits machine-readable findings; ``--rules`` lists the rule catalogue.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis.config import load_config
from repro.analysis.engine import RULE_DOCS, run_paths


def find_repo_root(start: pathlib.Path) -> pathlib.Path:
    """Nearest ancestor containing pyproject.toml (else ``start``)."""
    for cand in [start] + list(start.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return start


def main(argv=None) -> int:
    """Run the analyzer; return the process exit code."""
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="trusslint: repo-native JAX/Pallas + concurrency"
                    " static analysis (DESIGN.md §14)")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files or directories to analyze"
                             " (default: src/)")
    parser.add_argument("--strict", action="store_true",
                        help="fail on any unwaived finding (the CI gate)")
    parser.add_argument("--json", action="store_true",
                        help="emit findings as JSON")
    parser.add_argument("--show-waived", action="store_true",
                        help="also print findings silenced by waivers")
    parser.add_argument("--rules", action="store_true",
                        help="list the rule catalogue and exit")
    args = parser.parse_args(argv)

    if args.rules:
        for rule, doc in sorted(RULE_DOCS.items()):
            print(f"{rule}  {doc}")
        return 0

    repo_root = find_repo_root(pathlib.Path.cwd())
    cfg = load_config(repo_root)
    paths = args.paths or [cfg.src_root]
    findings = run_paths(paths, cfg, repo_root)
    active = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]

    if args.json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for finding in active:
            print(finding.render())
        if args.show_waived:
            for finding in waived:
                print(f"{finding.render()}  [waived]")
        print(f"trusslint: {len(active)} finding(s),"
              f" {len(waived)} waived")
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
