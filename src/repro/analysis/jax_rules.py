"""JAX/Pallas discipline rules (J001-J004, P001-P002).

These rules encode the contracts the executors rely on (DESIGN.md §14):
traced code never synchronizes with the host (J001), static jit
arguments derived from array shapes go through the pow2 bucketing
wrappers so the compile cache stays bounded (J002 — the
``SizeClass``/``n_pad`` contract from ``serve/truss_engine.py``),
edge-key packing always routes through ``graphs.csr.edge_keys`` for the
int64 widening and the ``MAX_PACK_N`` bound check (J003), donated
buffers are dead after the call that donates them (J004), and modules
built on ``kernels/wedge_common.py`` use its BlockSpec helpers and its
single chunk-clamp home rather than re-deriving either locally
(P001/P002).
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)
_SCOPE_NODES = _FUNC_NODES + (ast.Lambda,)


def _dotted(node) -> str | None:
    """Dotted name of a Name/Attribute chain (``pl.BlockSpec``), else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif parts:
        parts.append("?")
    else:
        return None
    return ".".join(reversed(parts))


def _terminal(node) -> str | None:
    """Last component of a Name/Attribute chain, else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


class JitInfo:
    """Statically known facts about one jit-wrapped function."""

    def __init__(self, name, params, statics, donated):
        self.name = name
        self.params = params      # positional parameter names, in order
        self.statics = statics    # set of static parameter names
        self.donated = donated    # set of donated positional indices


def _const_strs(node) -> list:
    """String constants inside a Constant/tuple/list literal."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [c.value for c in node.elts
                if isinstance(c, ast.Constant) and isinstance(c.value, str)]
    return []


def _const_ints(node) -> list:
    """Integer constants inside a Constant/tuple/list literal."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [c.value for c in node.elts
                if isinstance(c, ast.Constant) and isinstance(c.value, int)]
    return []


def _jit_call_opts(call: ast.Call, cfg):
    """(static names/nums, donate nums) from a jit(...) call's keywords."""
    statics, static_nums, donated = set(), [], set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            statics.update(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            static_nums.extend(_const_ints(kw.value))
        elif kw.arg == "donate_argnums":
            donated.update(_const_ints(kw.value))
    return statics, static_nums, donated


def _decode_jit_decorator(dec, cfg):
    """Decode a decorator if it is a jit wrapper; else None."""
    if _terminal(dec) in cfg.jit_wrappers:
        return set(), [], set()
    if isinstance(dec, ast.Call):
        head = _terminal(dec.func)
        if head in cfg.jit_wrappers:
            return _jit_call_opts(dec, cfg)
        if head == "partial" and dec.args \
                and _terminal(dec.args[0]) in cfg.jit_wrappers:
            return _jit_call_opts(dec, cfg)
    return None


def _jit_registry(tree, cfg) -> dict:
    """Map name → JitInfo for every jit function visible in the module.

    Covers decorated ``def``s and ``name = jax.jit(fn, ...)`` aliases
    (parameter order resolved through ``fn`` when it is a module-level
    def, so positional static arguments are checked too).
    """
    defs = {n.name: n for n in ast.walk(tree) if isinstance(n, _FUNC_NODES)}
    reg: dict = {}

    def _params(node):
        args = node.args
        return [a.arg for a in args.posonlyargs + args.args]

    for node in defs.values():
        for dec in node.decorator_list:
            opts = _decode_jit_decorator(dec, cfg)
            if opts is None:
                continue
            statics, nums, donated = opts
            params = _params(node)
            statics |= {params[i] for i in nums if i < len(params)}
            reg[node.name] = JitInfo(node.name, params, statics, donated)
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)):
            continue
        call = node.value
        if _terminal(call.func) not in cfg.jit_wrappers:
            continue
        statics, nums, donated = _jit_call_opts(call, cfg)
        params = []
        if call.args and _terminal(call.args[0]) in defs:
            params = _params(defs[_terminal(call.args[0])])
        statics |= {params[i] for i in nums if i < len(params)}
        reg[node.targets[0].id] = JitInfo(
            node.targets[0].id, params, statics, donated)
    return reg


def _traced_roots(tree, cfg) -> list:
    """Function/lambda nodes whose bodies run under a JAX trace.

    A function is traced if it is jit-decorated, or if its name (or a
    lambda) is passed to a ``lax`` control-flow combinator.  Anything
    lexically nested inside a traced function executes at trace time
    too, so only the outermost traced nodes are returned.
    """
    defs = {n.name: n for n in ast.walk(tree) if isinstance(n, _FUNC_NODES)}
    traced = {n for n in defs.values()
              if any(_decode_jit_decorator(d, cfg) is not None
                     for d in n.decorator_list)}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _terminal(node.func) in cfg.trace_callers):
            continue
        operands = list(node.args) + [kw.value for kw in node.keywords]
        for arg in operands:
            if isinstance(arg, ast.Name) and arg.id in defs:
                traced.add(defs[arg.id])
            elif isinstance(arg, ast.Lambda):
                traced.add(arg)
    roots, seen = [], set()
    for node in sorted(traced, key=lambda n: n.lineno):
        if id(node) not in seen:
            roots.append(node)
            seen.update(id(sub) for sub in ast.walk(node))
    return roots


def _static_coercion_ok(arg) -> bool:
    """True if an int()/bool()/float() argument is clearly trace-static."""
    if isinstance(arg, ast.Constant):
        return True
    if isinstance(arg, ast.Call) and _terminal(arg.func) in ("len", "ord"):
        return True
    if isinstance(arg, ast.Name) and arg.id.isupper():
        return True  # module-level ALL_CAPS constants
    # shapes are static under trace: int(x.shape[0]) never syncs
    return any(isinstance(sub, ast.Attribute) and sub.attr == "shape"
               for sub in ast.walk(arg))


def _check_host_sync(root, ctx, cfg, findings) -> None:
    """J001 over one traced root: flag host-synchronizing calls."""
    for sub in ast.walk(root):
        if not isinstance(sub, ast.Call):
            continue
        if isinstance(sub.func, ast.Attribute) \
                and sub.func.attr in cfg.host_sync_methods:
            findings.append(Finding(
                "J001", ctx.rel, sub.lineno,
                f"host sync `.{sub.func.attr}()` inside traced code"))
        elif _dotted(sub.func) in cfg.host_sync_funcs:
            findings.append(Finding(
                "J001", ctx.rel, sub.lineno,
                f"host materialization `{_dotted(sub.func)}(...)` inside"
                " traced code"))
        elif isinstance(sub.func, ast.Name) \
                and sub.func.id in cfg.host_coercions \
                and sub.args and not _static_coercion_ok(sub.args[0]):
            findings.append(Finding(
                "J001", ctx.rel, sub.lineno,
                f"`{sub.func.id}()` coercion of a possibly-traced value"
                " inside traced code"))


def _dynamic_shape(node, cfg) -> bool:
    """True if an expression derives from a shape without pow2 bucketing."""
    if isinstance(node, ast.Call) \
            and _terminal(node.func) in cfg.pow2_wrappers:
        return False  # sanctioned bucketing wrapper: anything inside is ok
    if isinstance(node, ast.Attribute) and node.attr in ("shape", "size"):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id == "len":
        return True
    return any(_dynamic_shape(child, cfg)
               for child in ast.iter_child_nodes(node))


def _check_jit_statics(tree, ctx, cfg, reg, findings) -> None:
    """J002: dynamic shapes flowing into static jit arguments."""
    cross = {name: set(statics) for name, statics in cfg.jit_static.items()}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        head = _terminal(node.func)
        info = reg.get(head)
        statics = info.statics if info is not None else cross.get(head)
        if not statics:
            continue
        params = info.params if info is not None else []
        exprs = [(kw.arg, kw.value) for kw in node.keywords
                 if kw.arg in statics]
        exprs += [(params[i], a) for i, a in enumerate(node.args)
                  if i < len(params) and params[i] in statics]
        for name, expr in exprs:
            if _dynamic_shape(expr, cfg):
                findings.append(Finding(
                    "J002", ctx.rel, expr.lineno,
                    f"static jit argument `{name}={ast.unparse(expr)}` of"
                    f" `{head}` is shape-derived without a pow2 bucketing"
                    " wrapper (retracing hazard)"))


def _check_key_packing(tree, ctx, cfg, findings) -> None:
    """J003: raw ``lo * n + hi`` packing outside the blessed helper."""

    def visit(node, fname):
        if isinstance(node, _FUNC_NODES):
            fname = node.name
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add) \
                and isinstance(node.left, ast.BinOp) \
                and isinstance(node.left.op, ast.Mult) \
                and fname not in cfg.pack_homes:
            factors = (_terminal(node.left.left),
                       _terminal(node.left.right))
            if any(f in cfg.pack_space_names for f in factors):
                findings.append(Finding(
                    "J003", ctx.rel, node.lineno,
                    "raw edge-key packing arithmetic; use"
                    " graphs.csr.edge_keys (int64 widening + MAX_PACK_N"
                    " bound check)"))
        for child in ast.iter_child_nodes(node):
            visit(child, fname)

    visit(tree, None)


def _check_use_after_donation(tree, ctx, cfg, reg, findings) -> None:
    """J004: reads of a name after it was donated to a jit call."""
    donors = {name: info for name, info in reg.items() if info.donated}
    if not donors:
        return
    for func in ast.walk(tree):
        if not isinstance(func, _FUNC_NODES):
            continue
        calls = []  # (lineno, donated variable name)
        for node in ast.walk(func):
            if not isinstance(node, ast.Call):
                continue
            info = donors.get(_terminal(node.func))
            if info is None:
                continue
            for pos in info.donated:
                if pos < len(node.args) \
                        and isinstance(node.args[pos], ast.Name):
                    calls.append((node.lineno, node.args[pos].id))
        if not calls:
            continue
        loads = [(n.lineno, n.id) for n in ast.walk(func)
                 if isinstance(n, ast.Name)
                 and isinstance(n.ctx, ast.Load)]
        stores = [(n.lineno, n.id) for n in ast.walk(func)
                  if isinstance(n, ast.Name)
                  and isinstance(n.ctx, ast.Store)]
        for call_line, var in calls:
            for load_line, name in loads:
                if name != var or load_line <= call_line:
                    continue
                rebound = any(s_name == var
                              and call_line <= s_line <= load_line
                              for s_line, s_name in stores)
                if not rebound:
                    findings.append(Finding(
                        "J004", ctx.rel, load_line,
                        f"`{var}` was donated to a jit call on line"
                        f" {call_line} and must not be read afterwards"))
                    break


def _imports_module(tree, suffix: str) -> bool:
    """True if the module imports a module whose name ends in ``suffix``."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name.endswith(suffix) for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.endswith(suffix):
                return True
            if any(a.name == suffix for a in node.names):
                return True
    return False


def _clamp_call(node, cfg):
    """First raw min()/max() call in an expression, skipping pow2 wrappers."""
    if isinstance(node, ast.Call):
        if _terminal(node.func) in cfg.pow2_wrappers:
            return None  # the sanctioned clamp home: anything inside is ok
        if isinstance(node.func, ast.Name) and node.func.id in ("min",
                                                               "max"):
            return node
    for child in ast.iter_child_nodes(node):
        found = _clamp_call(child, cfg)
        if found is not None:
            return found
    return None


def _check_pallas_contracts(tree, ctx, cfg, findings) -> None:
    """P001/P002: wedge_common BlockSpec helpers and the chunk-clamp home."""
    in_home = ctx.rel.endswith(f"{cfg.chunk_home}.py")
    uses_wc = _imports_module(tree, cfg.chunk_home)

    def flag_clamp(target_name, value):
        clamp = _clamp_call(value, cfg)
        if clamp is not None:
            findings.append(Finding(
                "P002", ctx.rel, clamp.lineno,
                f"`{target_name}` is clamped with a local"
                f" {clamp.func.id}(); route through"
                " wedge_common.pow2_chunk so every executor agrees on"
                " the chunk layout"))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if uses_wc and not in_home \
                and _terminal(node.func) == "BlockSpec":
            findings.append(Finding(
                "P001", ctx.rel, node.lineno,
                "raw pl.BlockSpec in a wedge_common-based kernel; use"
                " wedge_common.chunk_spec/replicated_spec so the spec"
                " matches the declared chunk layout"))
    if in_home:
        return
    # chunk-valued bindings end in "chunk" (`chunk`, `sup_chunk`);
    # chunk *counts* (`n_chunks`) are not clamp targets
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                name = _terminal(tgt)
                if name is not None and name.endswith("chunk"):
                    flag_clamp(name, node.value)
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg is not None and kw.arg.endswith("chunk"):
                    flag_clamp(kw.arg, kw.value)


def check_file(ctx, cfg) -> list:
    """Run every JAX/Pallas rule over one parsed file."""
    findings: list = []
    reg = _jit_registry(ctx.tree, cfg)
    for root in _traced_roots(ctx.tree, cfg):
        _check_host_sync(root, ctx, cfg, findings)
    _check_jit_statics(ctx.tree, ctx, cfg, reg, findings)
    _check_key_packing(ctx.tree, ctx, cfg, findings)
    _check_use_after_donation(ctx.tree, ctx, cfg, reg, findings)
    _check_pallas_contracts(ctx.tree, ctx, cfg, findings)
    return findings
