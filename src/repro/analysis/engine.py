"""trusslint driver: file collection, waivers, and the rule runner.

The analyzer is pure stdlib (``ast`` + ``tomllib``/fallback) so the CI
``static-analysis`` job needs no third-party installs and never imports
jax.  Each rule family lives in its own module (``jax_rules``,
``lock_rules``, ``modgraph``); this module owns the shared machinery:

* :class:`Finding` — one diagnostic, keyed by rule id.
* :class:`FileContext` — parsed source plus the per-line waiver and
  ``holds[...]`` annotation maps.
* :func:`run_paths` — collect files, run every rule, apply waivers.

Waiver syntax (DESIGN.md §14): a ``# trusslint: ignore[RULE]`` comment
on the offending line (or on a comment-only line directly above it)
suppresses that rule there; ``ignore[*]`` suppresses every rule.  A
``# trusslint: holds[_lock]`` comment on a ``def`` line asserts the
function is only ever called with that lock held, so the lock analyzer
treats the body as guarded.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import pathlib
import re

WAIVER_RE = re.compile(r"#\s*trusslint:\s*ignore\[([A-Za-z0-9_*,\s]+)\]")
HOLDS_RE = re.compile(r"#\s*trusslint:\s*holds\[([A-Za-z0-9_,\s]+)\]")

#: rule id → one-line contract, kept in sync with DESIGN.md §14.
RULE_DOCS = {
    "J001": "no host synchronization inside traced (jit / lax control"
            " flow) code",
    "J002": "static jit arguments derived from shapes must pass through"
            " a pow2 bucketing wrapper",
    "J003": "edge-key packing arithmetic must go through"
            " graphs.csr.edge_keys (int64 widening + bound check)",
    "J004": "buffers donated to a jit call must not be read afterwards",
    "P001": "modules using kernels.wedge_common must build BlockSpecs"
            " via its chunk_spec/replicated_spec helpers",
    "P002": "chunk clamping (min/max on a chunk value) belongs in"
            " kernels.wedge_common.pow2_chunk only",
    "L001": "attributes assigned under a lock are guarded: no off-lock"
            " access",
    "L002": "no blocking call (device dispatch, join, result) while"
            " holding a lock",
    "L003": "lock acquisition order must be acyclic and non-reentrant",
    "R001": "broad except handlers on the serving path must re-raise or"
            " route the error into a typed sink (_finish/set_exception)",
    "U001": "every module is reachable from a configured live root or"
            " explicitly quarantined",
    "U002": "live code must not import quarantined scaffolding",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: a rule id anchored to a file and line."""

    rule: str
    path: str
    line: int
    message: str
    waived: bool = False

    def render(self) -> str:
        """Format as ``path:line: RULE message`` for terminal output."""
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class FileContext:
    """A parsed source file plus its waiver / holds annotation maps."""

    def __init__(self, path: pathlib.Path, rel: str):
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.tree = ast.parse(self.source, filename=str(path))
        self.lines = self.source.splitlines()
        self.waivers: dict[int, set] = {}
        self.holds: dict[int, set] = {}
        self._scan_comments()

    def _scan_comments(self) -> None:
        """Build per-line waiver and holds maps from magic comments."""
        for idx, line in enumerate(self.lines, start=1):
            match = WAIVER_RE.search(line)
            if match:
                rules = {r.strip() for r in match.group(1).split(",")}
                self.waivers.setdefault(idx, set()).update(rules)
                if line.lstrip().startswith("#"):
                    # comment-only line: the waiver covers the next line
                    self.waivers.setdefault(idx + 1, set()).update(rules)
            match = HOLDS_RE.search(line)
            if match:
                locks = {k.strip() for k in match.group(1).split(",")}
                self.holds.setdefault(idx, set()).update(locks)

    def waived(self, rule: str, line: int) -> bool:
        """True if ``rule`` is waived on ``line`` by an ignore comment."""
        rules = self.waivers.get(line, ())
        return rule in rules or "*" in rules

    def holds_for_def(self, node: ast.AST) -> set:
        """Locks asserted held for a ``def`` via a holds annotation.

        The annotation may sit on the ``def`` line, the line above it,
        or any signature continuation line up to the first body
        statement.
        """
        body_start = node.body[0].lineno if getattr(node, "body", None) \
            else node.lineno
        held: set = set()
        for line in range(node.lineno - 1, body_start + 1):
            held |= self.holds.get(line, set())
        return held


def module_name(rel: str, src_root: str) -> str | None:
    """Dotted module name for a repo-relative path, or None if outside."""
    parts = pathlib.PurePosixPath(rel).parts
    if parts[: len(pathlib.PurePosixPath(src_root).parts)] != \
            pathlib.PurePosixPath(src_root).parts:
        return None
    parts = parts[len(pathlib.PurePosixPath(src_root).parts):]
    if not parts or not parts[-1].endswith(".py"):
        return None
    parts = parts[:-1] + (parts[-1][:-3],)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else None


def _quarantined(mod: str | None, cfg) -> bool:
    """True if ``mod`` falls under a configured quarantine prefix."""
    if mod is None:
        return False
    return any(mod == q or mod.startswith(q + ".") for q in cfg.quarantine)


def collect_files(paths, cfg, repo_root: pathlib.Path) -> list:
    """Expand CLI paths into the analyzable file list.

    Excluded globs and quarantined modules are dropped here, so the AST
    rule families only ever see live code; the module-liveness rules
    (``modgraph``) walk the full ``src_root`` tree themselves.
    """
    repo_root = pathlib.Path(repo_root)
    files: list = []
    for target in paths:
        target = pathlib.Path(target)
        if not target.is_absolute():
            target = repo_root / target
        candidates = [target] if target.is_file() \
            else sorted(target.rglob("*.py"))
        for cand in candidates:
            try:
                rel = cand.resolve().relative_to(repo_root.resolve())
            except ValueError:
                rel = cand
            rel = rel.as_posix()
            if any(fnmatch.fnmatch(rel, pat) for pat in cfg.exclude):
                continue
            if _quarantined(module_name(rel, cfg.src_root), cfg):
                continue
            files.append((cand, rel))
    return files


def run_paths(paths, cfg, repo_root) -> list:
    """Run every rule family over ``paths``; return ordered findings."""
    from repro.analysis import fault_rules, jax_rules, lock_rules, modgraph

    repo_root = pathlib.Path(repo_root)
    findings: list = []
    locks = lock_rules.LockChecker(cfg)
    scanned_src = False
    for path, rel in collect_files(paths, cfg, repo_root):
        ctx = FileContext(path, rel)
        raw = jax_rules.check_file(ctx, cfg) + locks.check_file(ctx) \
            + fault_rules.check_file(ctx, cfg)
        findings.extend(
            dataclasses.replace(f, waived=ctx.waived(f.rule, f.line))
            for f in raw)
        if module_name(rel, cfg.src_root) is not None:
            scanned_src = True
    findings.extend(locks.finalize())
    if scanned_src and cfg.roots:
        findings.extend(modgraph.check(repo_root, cfg))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
