"""Configuration for the trusslint static-analysis pass (DESIGN.md §14).

The defaults below encode the repo's contracts; the ``[tool.trusslint]``
table in ``pyproject.toml`` overrides them so every rule stays
config-driven rather than hard-coded in rule logic.  Python 3.11+ parses
the table with :mod:`tomllib`; older interpreters (the pinned container
runs 3.10, which predates tomllib and ships neither ``tomli`` nor
``toml``) fall back to :func:`parse_toml_subset`, a small built-in
parser covering exactly the TOML subset the table uses — dotted section
headers, double-quoted strings, integers, booleans, and (possibly
nested, possibly multi-line) arrays.
"""

from __future__ import annotations

import dataclasses
import pathlib

try:  # Python >= 3.11
    import tomllib as _toml
except ModuleNotFoundError:  # pragma: no cover - depends on interpreter
    _toml = None


def _strip_comment(line: str) -> str:
    """Drop a trailing ``#`` comment, respecting double-quoted strings."""
    out, in_str = [], False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        elif ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out)


def _depth_delta(text: str) -> int:
    """Net bracket depth of ``text``, ignoring brackets inside strings."""
    depth, in_str = 0, False
    for ch in text:
        if ch == '"':
            in_str = not in_str
        elif not in_str:
            depth += {"[": 1, "]": -1}.get(ch, 0)
    return depth


def _split_items(body: str) -> list[str]:
    """Split an array body on top-level commas (bracket/string aware)."""
    items, buf, depth, in_str = [], [], 0, False
    for ch in body:
        if ch == '"':
            in_str = not in_str
        elif not in_str:
            depth += {"[": 1, "]": -1}.get(ch, 0)
            if ch == "," and depth == 0:
                items.append("".join(buf))
                buf = []
                continue
        buf.append(ch)
    items.append("".join(buf))
    return [s for s in (i.strip() for i in items) if s]


def _parse_value(text: str):
    """Parse one TOML-subset value (string, int, bool, or array)."""
    text = text.strip()
    if text.startswith("["):
        return [_parse_value(i) for i in _split_items(text[1:-1])]
    if text.startswith('"'):
        return text[1:-1]
    if text in ("true", "false"):
        return text == "true"
    return int(text)


def parse_toml_subset(text: str) -> dict:
    """Parse the TOML subset used by ``[tool.trusslint]`` (3.10 fallback)."""
    data: dict = {}
    section = data
    pending_key, buf = None, ""
    for raw in text.splitlines():
        line = _strip_comment(raw).strip()
        if pending_key is not None:
            buf += " " + line
            if _depth_delta(buf) == 0:
                section[pending_key] = _parse_value(buf)
                pending_key, buf = None, ""
            continue
        if not line:
            continue
        if line.startswith("["):
            section = data
            for part in line.strip("[]").split("."):
                section = section.setdefault(part.strip().strip('"'), {})
            continue
        if "=" in line:
            key, value = line.split("=", 1)
            key, value = key.strip().strip('"'), value.strip()
            if value.startswith("[") and _depth_delta(value) != 0:
                pending_key, buf = key, value
            else:
                section[key] = _parse_value(value)
    return data


@dataclasses.dataclass
class LintConfig:
    """Resolved trusslint configuration (defaults ⊕ pyproject table)."""

    # -- file selection -------------------------------------------------
    exclude: tuple = ()
    src_root: str = "src"

    # -- JAX discipline (J-rules) ---------------------------------------
    jit_wrappers: tuple = ("jit", "pjit")
    trace_callers: tuple = ("while_loop", "fori_loop", "scan", "cond",
                            "switch")
    host_sync_methods: tuple = ("item", "tolist", "block_until_ready")
    host_sync_funcs: tuple = ("np.asarray", "np.array", "numpy.asarray",
                              "numpy.array", "np.frombuffer",
                              "jax.device_get")
    host_coercions: tuple = ("int", "bool", "float")
    pow2_wrappers: tuple = ("next_pow2", "pow2_chunk", "auto_chunk",
                            "chunk_layout")
    jit_static: dict = dataclasses.field(default_factory=dict)
    pack_space_names: tuple = ("n",)
    pack_homes: tuple = ("edge_keys",)
    chunk_home: str = "wedge_common"
    blockspec_helpers: tuple = ("chunk_spec", "replicated_spec")

    # -- lock discipline (L-rules) --------------------------------------
    lock_attrs: tuple = ("_lock", "_work")
    lock_aliases: tuple = (("_lock", "_work"),)
    blocking_always: tuple = ("join", "sleep", "block_until_ready",
                              "flush", "result", "acquire")
    blocking_engine: tuple = ("submit", "update", "update_many", "open",
                              "close", "discard", "query", "communities",
                              "community", "hierarchy", "trussness")
    engine_receiver_hints: tuple = ("engine", "handle", "inc")
    mutator_methods: tuple = ("append", "appendleft", "add", "clear",
                              "pop", "popleft", "extend", "remove",
                              "discard", "update", "setdefault", "insert")

    # -- fault routing (R-rules) ----------------------------------------
    fault_paths: tuple = ("src/repro/serve/*", "src/repro/core/truss_inc.py")
    fault_sinks: tuple = ("_finish", "set_exception")

    # -- module liveness (U-rules) --------------------------------------
    roots: tuple = ()
    quarantine: tuple = ()

    # -- runtime retracing budgets (consumed by the bench gate) ---------
    retrace_budgets: dict = dataclasses.field(default_factory=dict)


def _as_tuple(value):
    """Normalise a TOML array into the tuple shape the config stores."""
    if isinstance(value, list):
        return tuple(_as_tuple(v) for v in value)
    return value


def _apply(cfg: LintConfig, table: dict, keys: tuple) -> None:
    """Copy ``keys`` present in ``table`` onto ``cfg`` (arrays → tuples)."""
    for key in keys:
        if key in table:
            value = table[key]
            if isinstance(value, dict):
                value = {k: _as_tuple(v) for k, v in value.items()}
            else:
                value = _as_tuple(value)
            setattr(cfg, key, value)


def load_config(repo_root: pathlib.Path) -> LintConfig:
    """Build the effective config from ``<repo_root>/pyproject.toml``."""
    cfg = LintConfig()
    pyproject = pathlib.Path(repo_root) / "pyproject.toml"
    if not pyproject.is_file():
        return cfg
    text = pyproject.read_text()
    if _toml is not None:
        data = _toml.loads(text)
    else:
        data = parse_toml_subset(text)
    table = data.get("tool", {}).get("trusslint", {})
    _apply(cfg, table, ("exclude", "src_root"))
    _apply(cfg, table.get("jax", {}),
           ("jit_wrappers", "trace_callers", "host_sync_methods",
            "host_sync_funcs", "host_coercions", "pow2_wrappers",
            "jit_static", "pack_space_names", "pack_homes", "chunk_home",
            "blockspec_helpers"))
    _apply(cfg, table.get("locks", {}),
           ("lock_attrs", "lock_aliases", "blocking_always",
            "blocking_engine", "engine_receiver_hints", "mutator_methods"))
    _apply(cfg, table.get("faults", {}), ("fault_paths", "fault_sinks"))
    _apply(cfg, table.get("modules", {}), ("roots", "quarantine"))
    retrace = table.get("retrace", {})
    if retrace:
        cfg.retrace_budgets = dict(retrace)
    return cfg
