"""Checkpointing: sharded, atomic, async, elastic.

Layout (mesh-shape-agnostic — any mesh can restore any checkpoint):

  <dir>/step_<N>.tmp/            written first
  <dir>/step_<N>/                atomic rename commit
      manifest.json              pytree structure + shapes + dtypes
      arr_<i>.npy                one file per leaf (full logical array)

Design notes for the 1000-node deployment (DESIGN.md §8):
  * leaves are written as *full logical arrays*: restore is oblivious to the
    saving mesh → elastic rescaling is a config change, not a migration;
  * in a true multi-controller run each host would write only the shards it
    owns (`process_allgather` is the single-controller shortcut here) —
    the manifest format already carries everything needed;
  * the async writer moves host serialization off the training thread; commit
    is a rename so a crash mid-write never corrupts the latest checkpoint;
  * ``keep`` bounds disk usage (GC oldest).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    keep: int = 3) -> str:
    """Synchronous sharded save with atomic commit. Returns final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append(
            {"path": p, "file": f"arr_{i}.npy", "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    _gc(ckpt_dir, keep)
    return final


def restore_checkpoint(ckpt_dir: str, like: Any, step: int | None = None,
                       *, shardings: Any = None) -> tuple[int, Any] | None:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of shardings
    for direct device placement (elastic re-shard happens here)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        e = by_path.get(p)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(os.path.join(d, e["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"leaf {p!r}: ckpt shape {arr.shape} != expected {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    return step, jax.tree.unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        int(m.group(1)) for m in
        (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(ckpt_dir)) if m)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


class CheckpointManager:
    """Async checkpointing: save() returns immediately; one writer thread."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # materialize on host synchronously (cheap vs serialization), then
        # hand off to the writer thread
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree,
                                keep=self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like: Any, *, shardings: Any = None):
        return restore_checkpoint(self.ckpt_dir, like, shardings=shardings)
