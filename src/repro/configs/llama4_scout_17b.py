"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert; vision frontend is a stub
(early-fusion text backbone only, per assignment). [hf:meta-llama/Llama-4-Scout-17B-16E]"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    shared_expert=True,
    rope="rope",
    rope_theta=5e5,
    act="swiglu",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, n_experts=4, top_k=1, kv_chunk=32)
