"""olmo-1b [dense] — 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304;
non-parametric LayerNorm. [arXiv:2402.00838]"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab=50304,
    norm="rms_nonparam",
    rope="rope",
    rope_theta=1e4,
    act="swiglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, kv_chunk=32)
