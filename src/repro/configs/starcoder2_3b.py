"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA + RoPE, GeLU MLP, LayerNorm. [arXiv:2402.19173]"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab=49152,
    norm="layernorm",
    rope="rope",
    rope_theta=1e5,
    act="gelu",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, kv_chunk=32)
