"""falcon-mamba-7b [ssm] — 64L d_model=4096 attention-free Mamba1,
ssm_state=16, vocab=65024. [arXiv:2410.05355]"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    mamba_version=1,
    rope="none",
    act="swiglu",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab=256, ssm_state=4,
        ssm_q_chunk=16)
