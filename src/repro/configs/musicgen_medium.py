"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24, full MHA) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens, sinusoidal positions; the audio
frontend (EnCodec) is a stub: input_specs provides precomputed frame
embeddings per the assignment. [arXiv:2306.05284]"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    rope="sinusoidal",
    norm="layernorm",
    act="gelu",
    input_is_embeds=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=128, kv_chunk=32)
