"""zamba2-7b [hybrid] — 81L d_model=3584, Mamba2 backbone (ssm_state=64) with
a SHARED attention block (32H GQA kv=32, head_dim=112, d_ff=14336) applied
every 6 slots (13 applications over 81 layers). [arXiv:2411.15242]"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    mamba_version=2,
    ssd_head_p=64,
    attn_every=6,
    rope="rope",
    rope_theta=1e4,
    act="swiglu",
    ssm_q_chunk=256,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=8, ssd_head_p=16, attn_every=3,
        ssm_q_chunk=16, kv_chunk=32)
