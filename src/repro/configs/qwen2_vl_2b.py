"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936; M-RoPE; the vision frontend is a stub: input_specs provides
precomputed patch embeddings per the assignment. [arXiv:2409.12191]"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    rope="mrope",
    rope_theta=1e6,
    act="swiglu",
    input_is_embeds=True,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, kv_chunk=32)
