"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152;
llama-arch small. Also the end-to-end training-example arch.
[hf:HuggingFaceTB/SmolLM-135M]"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab=49152,
    rope="rope",
    rope_theta=1e4,
    act="swiglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=3, n_kv_heads=3, head_dim=16,
        d_ff=96, vocab=256, kv_chunk=32)
