"""Assigned architecture configs (+ reduced smoke variants + input specs).

Each arch module exposes CONFIG (exact assigned dims) and reduced() (smoke).
``get_config(arch)``, ``reduced_config(arch)``, ``input_specs(arch, shape)``
are the public API used by the launcher, dry-run, tests, and benchmarks.
"""

from __future__ import annotations

import importlib

import jax
import numpy as np

from repro.models.model import ModelConfig, init_cache

ARCHS = [
    "phi35_moe_42b",
    "llama4_scout_17b",
    "musicgen_medium",
    "falcon_mamba_7b",
    "qwen3_8b",
    "olmo_1b",
    "smollm_135m",
    "starcoder2_3b",
    "zamba2_7b",
    "qwen2_vl_2b",
]

#: assignment ids → module names
ARCH_IDS = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "musicgen-medium": "musicgen_medium",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen3-8b": "qwen3_8b",
    "olmo-1b": "olmo_1b",
    "smollm-135m": "smollm_135m",
    "starcoder2-3b": "starcoder2_3b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

#: archs that run long_500k (sub-quadratic sequence mixing); the rest are
#: full-attention and are skipped per the assignment (see DESIGN.md §5).
LONG_CONTEXT_OK = {"falcon_mamba_7b", "zamba2_7b"}


def _module(arch: str):
    arch = ARCH_IDS.get(arch, arch)
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def reduced_config(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def cell_is_valid(arch: str, shape: str) -> tuple[bool, str]:
    arch = ARCH_IDS.get(arch, arch)
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "full-attention arch: 500k dense KV cache skipped per assignment"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str, *, dtype_tokens=np.int32):
    """ShapeDtypeStruct stand-ins for every model input of a (cfg, shape) cell.

    train  → batch dict for train_step
    prefill→ batch dict for prefill (full prompt, empty cache elsewhere)
    decode → (tokens-or-embeds for 1 new token, cache at seq_len fill)
    """
    import jax.numpy as jnp
    seq, gbs, kind = SHAPES[shape]
    sds = jax.ShapeDtypeStruct

    def body_inputs(S, B):
        d: dict = {}
        if cfg.input_is_embeds:
            d["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        else:
            d["tokens"] = sds((B, S), jnp.int32)
        if cfg.rope == "mrope":
            d["positions"] = sds((B, S, 3), jnp.int32)
        return d

    if kind == "train":
        batch = body_inputs(seq, gbs)
        batch["labels"] = sds((gbs, seq), jnp.int32)
        return {"kind": "train", "batch": batch}
    if kind == "prefill":
        batch = body_inputs(seq, gbs)
        cache = jax.eval_shape(lambda: init_cache(cfg, gbs, seq))
        return {"kind": "prefill", "batch": batch, "cache": cache}
    # decode: one new token against a cache of size seq
    batch = body_inputs(1, gbs)
    cache = jax.eval_shape(lambda: init_cache(cfg, gbs, seq))
    return {"kind": "decode", "batch": batch, "cache": cache}
