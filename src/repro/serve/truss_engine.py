"""Batched multi-graph truss engine — many small graphs through one compile.

The serving story for truss decomposition is the opposite of the paper's
single-giant-graph benchmark: heavy traffic means a *stream* of modest graphs
(per-user ego nets, transaction neighborhoods, rolling windows) where XLA
compile time and per-dispatch overhead dominate if each graph is decomposed
alone. This engine amortizes both:

  * **Bucketing** — every submission is preprocessed on host (canonicalize,
    optional k-core reorder, CSR build) and assigned to a *size class*: all
    dimensions padded up to powers of two —
    ``(m_pad, sup_pad, peel_pad, chunk, n_pad)``.  Graphs in one class share
    one compiled executable; the pow2 policy bounds the number of distinct
    compiles to O(log m · log wedges) over any workload.  With the default
    ``table_mode="device"`` the wedge tables never exist on host: their
    entry counts are bounded by an O(m) host pass, the *CSR arrays alone*
    are shipped (``CSROperand``), and both tables are built by the vmapped
    device builders inside the batched jit (DESIGN.md §10);
    ``table_mode="numpy"`` keeps the original host-built table operands.
  * **Batching** — a bucket is decomposed by a single ``jax.vmap`` of the
    support + peel pipeline from ``core/pkt.py`` over the stacked, padded
    operands.  Padding edges are pre-marked processed with sentinel support,
    so they are inert in the level loop; padded wedge entries carry empty
    probe ranges (lo == hi) and the anchor sentinel, so they never hit.
  * **Order-aligned results** — ``submit`` returns a ticket; results are
    delivered aligned to each submission's own edge-row order regardless of
    bucket membership or flush timing.

Usage:

    eng = TrussEngine(mode="chunked")
    t1 = eng.submit(edges_a)          # queued
    t2 = eng.submit(edges_b)          # queued (maybe same bucket)
    trussness_b = eng.result(t2)      # flushes pending work once
    trussness_a = eng.result(t1)      # already computed

``mode`` selects the peel executor and ``support_mode`` the support executor
exactly as in ``core.pkt.pkt`` — the kernel paths vmap too: Pallas grids
gain a leading batch dimension, so one bucket dispatch lowers each kernel
once for the whole batch.  Submissions larger than ``max_edges`` canonical
edges are rejected at ``submit`` time with a clear error (the padded
operands of an oversized graph would otherwise compile a bucket no steady
workload ever reuses, and can exhaust device memory).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.csr import (CSRGraph, build_csr, canonical_edges_with_rows,
                              degeneracy_order, edge_keys, relabel)
from repro.core import support as support_mod
from repro.core.hierarchy import HIER_MODES
from repro.core.pkt import (PEEL_MODES, PeelTables, _SENTINEL_S, _peel_loop,
                            align_to_input, chunk_ranges)
from repro.core.ref import truss_numpy
from repro.core.truss_inc import INSERT_MODES, IncrementalTruss, UpdateStats
from repro.kernels import wedge_common
from repro.testing.chaos import fault_point
from repro.kernels.wedge_common import next_pow2 as _next_pow2
from repro.kernels.wedge_common import pad1 as _pad1

_PAD_N = wedge_common.PAD_N  # adjacency padding: larger than any vertex id
_MIN_M_PAD = 8


class SizeClass(NamedTuple):
    """Bucket key: every compiled shape the batched pipeline depends on."""

    m_pad: int        # padded edge count (pow2)
    sup_pad: int      # padded support-table length (pow2)
    peel_pad: int     # padded peel-table length (pow2, multiple of chunk)
    chunk: int        # peel chunk size (pow2, <= peel_pad)
    n_chunks: int     # peel_pad // chunk
    iters: int        # binary-search iteration bound for 2*m_pad-length rows
    sup_chunk: int    # support-kernel chunk size (pow2, <= sup_pad)
    sup_n_chunks: int  # sup_pad // sup_chunk
    n_pad: int        # padded vertex count (pow2; 0 in table_mode="numpy",
    #                   whose operands carry no vertex-indexed arrays)


class _TableDims(NamedTuple):
    """Stand-in for a wedge table when only its entry count is known —
    ``table_mode="device"`` sizes buckets without materializing tables."""

    size: int


class BatchOperand(NamedTuple):
    """Per-graph padded device operands; stacked along axis 0 per bucket."""

    N: jnp.ndarray          # (2*m_pad,) adjacency values
    Eid: jnp.ndarray        # (2*m_pad,) slot → edge id
    s_e1: jnp.ndarray       # (sup_pad,) support-table anchor edges
    s_cand: jnp.ndarray     # (sup_pad,)
    s_lo: jnp.ndarray       # (sup_pad,)
    s_hi: jnp.ndarray       # (sup_pad,)
    p_e1: jnp.ndarray       # (peel_pad,) peel-table anchor edges
    p_cand: jnp.ndarray     # (peel_pad,)
    p_lo: jnp.ndarray       # (peel_pad,)
    p_hi: jnp.ndarray       # (peel_pad,)
    c_start: jnp.ndarray    # (m_pad,) first chunk of edge's entry range
    c_end: jnp.ndarray      # (m_pad,) last chunk (inclusive)
    has_entries: jnp.ndarray  # (m_pad,) bool
    m_real: jnp.ndarray     # () int32 — live edge count of this graph


class CSROperand(NamedTuple):
    """Per-graph padded *CSR* operands (``table_mode="device"``).

    Only graph-sized arrays cross the host boundary; both wedge tables are
    constructed inside the batched jit (vmapped device builders), so a
    submission uploads O(m + n) bytes instead of O(table) — the tables are
    several× the graph size on triangle-rich graphs.
    """

    N: jnp.ndarray          # (2*m_pad,) adjacency values
    Eid: jnp.ndarray        # (2*m_pad,) slot → edge id
    Es: jnp.ndarray         # (n_pad+1,) CSR row offsets
    Eo: jnp.ndarray         # (n_pad,) first >u slot per row
    u: jnp.ndarray          # (m_pad,) edge endpoints (u < v; padding 0)
    v: jnp.ndarray          # (m_pad,)
    m_real: jnp.ndarray     # () int32 — live edge count of this graph


@functools.partial(
    jax.jit,
    static_argnames=("m", "chunk", "n_chunks", "iters", "mode",
                     "support_mode", "sup_chunk", "sup_n_chunks",
                     "interpret"),
)
def _batched_truss(ops: BatchOperand, *, m: int, chunk: int, n_chunks: int,
                   iters: int, mode: str, support_mode: str, sup_chunk: int,
                   sup_n_chunks: int, interpret: bool):
    """vmap of (support → peel) across one bucket of padded graphs."""
    def one(op: BatchOperand):
        if support_mode == "pallas":
            from repro.kernels.support import support_accumulate

            S_acc, _ = support_accumulate(
                op.s_e1, op.s_cand, op.s_lo, op.s_hi, op.N, op.Eid,
                chunk=sup_chunk, n_chunks=sup_n_chunks, iters=iters, m=m,
                interpret=interpret)
            S0 = S_acc[:m]
        else:
            S0 = support_mod._support_jit(
                op.N, op.Eid, op.s_e1, op.s_cand, op.s_lo, op.s_hi, iters, m)
        edge_ok = jnp.arange(m + 1, dtype=jnp.int32) < op.m_real
        S_ext0 = jnp.where(
            edge_ok,
            jnp.concatenate([S0, jnp.zeros((1,), jnp.int32)]),
            _SENTINEL_S)
        processed0 = ~edge_ok
        tabs = PeelTables(op.p_e1, op.p_cand, op.p_lo, op.p_hi,
                          op.c_start, op.c_end, op.has_entries)
        S_ext, _, levels, subs = _peel_loop(
            op.N, op.Eid, S_ext0, processed0, tabs, m=m, chunk=chunk,
            n_chunks=n_chunks, iters=iters, mode=mode, interpret=interpret)
        return S_ext[:m], S0, levels, subs

    return jax.vmap(one)(ops)


@functools.partial(
    jax.jit,
    static_argnames=("m", "chunk", "n_chunks", "iters", "mode",
                     "support_mode", "sup_chunk", "sup_n_chunks", "sup_pad",
                     "peel_pad", "interpret"),
)
def _batched_truss_dev(ops: CSROperand, *, m: int, chunk: int, n_chunks: int,
                       iters: int, mode: str, support_mode: str,
                       sup_chunk: int, sup_n_chunks: int, sup_pad: int,
                       peel_pad: int, interpret: bool):
    """vmap of (build tables → support → peel) across one bucket of graphs.

    The ``table_mode="device"`` pipeline: both wedge tables are built by the
    vmapped device builders (``core.support._build_*_table_dev``) inside
    this one compiled program, so ``flush`` dispatches exactly one
    executable per bucket and no table ever exists on the host.
    """
    def one(op: CSROperand):
        s_e1, s_cand, s_lo, s_hi, _ = support_mod._build_support_table_dev(
            op.u, op.v, op.Es, op.Eo, op.m_real, m=m, size=sup_pad)
        S0 = support_mod.support_from_table_arrays(
            s_e1, s_cand, s_lo, s_hi, op.N, op.Eid, m=m, mode=support_mode,
            chunk=sup_chunk, n_chunks=sup_n_chunks, iters=iters,
            interpret=interpret)
        p_e1, p_cand, p_lo, p_hi, _off, c_start, c_end, has = \
            support_mod._build_peel_table_dev(
                op.u, op.v, op.Es, op.m_real, m=m, size=peel_pad, chunk=chunk)
        edge_ok = jnp.arange(m + 1, dtype=jnp.int32) < op.m_real
        S_ext0 = jnp.where(
            edge_ok,
            jnp.concatenate([S0, jnp.zeros((1,), jnp.int32)]),
            _SENTINEL_S)
        processed0 = ~edge_ok
        tabs = PeelTables(p_e1, p_cand, p_lo, p_hi, c_start, c_end, has)
        S_ext, _, levels, subs = _peel_loop(
            op.N, op.Eid, S_ext0, processed0, tabs, m=m, chunk=chunk,
            n_chunks=n_chunks, iters=iters, mode=mode, interpret=interpret)
        return S_ext[:m], S0, levels, subs

    return jax.vmap(one)(ops)


@dataclasses.dataclass
class _Pending:
    ticket: int
    g: CSRGraph
    n: int
    in_keys: np.ndarray       # per input row: canonical key in relabeled space
    key: SizeClass
    E: np.ndarray             # canonical pre-relabel edges (handle promotion)
    operand: BatchOperand | CSROperand | None = None


class TrussHandle:
    """Persistent decomposition state — the mutable sibling of a ticket.

    Returned by ``TrussEngine.open`` (or by promoting a still-pending
    ticket through ``TrussEngine.update``).  Unlike the single-read ticket
    API, a handle retains its graph, trussness, and support across
    ``update`` calls until ``TrussEngine.close`` releases it.
    """

    __slots__ = ("hid", "_inc", "closed")

    def __init__(self, hid: int, inc: IncrementalTruss):
        self.hid = hid
        self._inc = inc
        self.closed = False

    @property
    def edges(self) -> np.ndarray:
        """Current canonical (m, 2) edge list (key-sorted)."""
        return self._inc.edges

    @property
    def trussness(self) -> np.ndarray:
        """Per-edge trussness aligned to ``edges`` rows."""
        return self._inc.trussness

    @property
    def m(self) -> int:
        """Current number of (unique, canonical) edges."""
        return self._inc.m

    @property
    def n(self) -> int:
        """Vertex-space size (max id + 1 at open; stable across updates)."""
        return self._inc.n

    @property
    def insert_mode(self) -> str:
        """Insertion repair strategy this handle's updates take (§13)."""
        return self._inc.insert_mode

    def query(self, edges) -> np.ndarray:
        """Trussness for specific edges, aligned to the given rows."""
        return self._inc.query(edges)

    # --------------------------------------------- community queries (§11) --
    def hierarchy(self, *, mode: str | None = None):
        """The handle's :class:`~repro.core.hierarchy.TrussHierarchy`.

        Lazily built from the handle's maintained trussness + triangle list
        and cached; local ``TrussEngine.update`` batches carry it forward
        (untouched levels are id-remapped, repaired levels rebuild lazily),
        full rebuilds drop it.  ``mode`` ∈ ``HIER_MODES`` overrides the
        engine's default ("device" label propagation vs the "host"
        union-find oracle — bitwise-identical labels either way); a
        non-default mode returns a standalone index without touching the
        cache, so oracle reads never evict the serving state.
        """
        return self._inc.hierarchy(mode=mode)

    def communities(self, k: int, *,
                    hier_mode: str | None = None) -> list[np.ndarray]:
        """Every k-truss community as a (c, 2) array of edge endpoints.

        Communities are the *triangle-connected* components of the edges
        with trussness >= k (Wang & Cheng), ordered by their representative
        (minimum) edge id; an edge in no surviving triangle forms a
        singleton.  k above the graph's max trussness yields ``[]``.
        ``hier_mode`` overrides the index builder for this call (the
        resilience layer's hierarchy-ladder hook, DESIGN.md §15): a
        non-default mode builds a standalone index, bypassing — and never
        evicting — the cached one, with bitwise-identical labels.
        """
        E = self._inc.edges
        ids_per = self._inc.hierarchy(mode=hier_mode).communities(k)
        return [E[ids] for ids in ids_per]

    def community(self, edge_or_vertex, k: int):
        """The k-truss community around one edge — or all around one vertex.

        An ``(u, v)`` pair returns that edge's community as a (c, 2)
        endpoint array (empty when the edge's trussness is below ``k``; an
        edge not in the graph raises the descriptive alignment ValueError).
        A scalar vertex id returns a *list* of communities, one per distinct
        level-``k`` community among the vertex's incident edges — a vertex,
        unlike an edge, can sit on the border of several k-trusses.
        """
        h = self._inc.hierarchy()
        E = self._inc.edges
        q = np.asarray(edge_or_vertex)
        if q.ndim == 0:                       # vertex query
            v = int(q)
            inc_ids = np.nonzero((E[:, 0] == v) | (E[:, 1] == v))[0]
            labels = h.level_labels(k)[inc_ids]
            reps = np.unique(labels[labels >= 0])
            return [E[h.community_of(int(r), k)] for r in reps]
        eid = int(self._inc.edge_ids(q.reshape(1, 2))[0])
        return E[h.community_of(eid, k)]

    def __repr__(self):
        state = "closed" if self.closed else f"m={self._inc.m}"
        return f"TrussHandle({self.hid}, {state})"


class TrussEngine:
    """Queue API over the batched decomposition pipeline.

    Two traffic shapes share one engine: *single-read tickets*
    (``submit``/``flush``/``result``/``map``) batch same-size-class graphs
    into one vmapped dispatch per bucket, and *persistent handles*
    (``open``/``update``/``update_many``/``close``) absorb edge churn by
    incremental repair (DESIGN.md §9).  ``repro.serve.TrussScheduler``
    wraps an engine with an async continuous-batching facade (§12).

    Args:
        mode: peel executor for every decomposition (see ``core.pkt.pkt``).
        support_mode: support executor (same axes as ``pkt``).
        table_mode: wedge-table builder — "device" ships CSR-only operands
            and builds both tables inside the batched jit (§10); "numpy" is
            the host parity oracle.
        hier_mode: community-index builder for handles (§11).
        insert_mode: handle insertion repair strategy ("batched" /
            "sequential", §13) — one merged-region re-peel per update batch
            vs one re-peel per inserted edge; bitwise-identical results.
        chunk: peel chunk size (rounded up to pow2). ``None`` (default)
            derives it per size class from the tuned-chunk policy
            (``kernels.wedge_common.auto_chunk``, §16).
        reorder: degeneracy-reorder each submission before decomposition.
        max_pending: auto-flush threshold — ``submit`` triggers a full
            ``flush`` once this many submissions are queued.
        max_edges: reject submissions beyond this many canonical edges.
        interpret: force/forbid Pallas interpret mode (default: interpret
            when not on a TPU).

    Raises:
        ValueError: unknown mode axis, or non-positive ``chunk`` /
            ``max_edges``.
    """

    def __init__(self, *, mode: str = "chunked", support_mode: str = "jnp",
                 table_mode: str = "device", hier_mode: str = "device",
                 insert_mode: str = "batched", chunk: int | None = None,
                 reorder: bool = True, max_pending: int = 32,
                 max_edges: int = 1 << 22, interpret: bool | None = None):
        if mode not in PEEL_MODES:
            raise ValueError(f"mode must be one of {PEEL_MODES}, got {mode!r}")
        if support_mode not in support_mod.SUPPORT_MODES:
            raise ValueError(f"support_mode must be one of "
                             f"{support_mod.SUPPORT_MODES}, "
                             f"got {support_mode!r}")
        if table_mode not in support_mod.TABLE_MODES:
            raise ValueError(f"table_mode must be one of "
                             f"{support_mod.TABLE_MODES}, got {table_mode!r}")
        if hier_mode not in HIER_MODES:
            raise ValueError(f"hier_mode must be one of {HIER_MODES}, "
                             f"got {hier_mode!r}")
        if insert_mode not in INSERT_MODES:
            raise ValueError(f"insert_mode must be one of {INSERT_MODES}, "
                             f"got {insert_mode!r}")
        if chunk is not None and chunk < 1:
            raise ValueError("chunk must be positive")
        if max_edges < 1:
            raise ValueError("max_edges must be positive")
        self.mode = mode
        self.support_mode = support_mode
        self.table_mode = table_mode
        self.hier_mode = hier_mode
        self.insert_mode = insert_mode
        self.max_edges = max_edges
        self.chunk = None if chunk is None else _next_pow2(chunk)
        self.reorder = reorder
        self.max_pending = max_pending
        self.interpret = (wedge_common.interpret_default()
                          if interpret is None else interpret)
        self._pending: list[_Pending] = []
        self._results: dict[int, np.ndarray] = {}
        self._next_ticket = 0
        self._handles: dict[int, TrussHandle] = {}
        self._next_handle = 0
        self.stats = {
            "submitted": 0, "flushes": 0, "batches": 0,
            "buckets": set(), "graph_seconds": 0.0, "graphs_done": 0,
            # warm_* counts only dispatches whose bucket was seen before
            # (compile already cached) — the steady-state throughput basis
            "warm_seconds": 0.0, "warm_graphs": 0,
            # handle lifecycle (incremental maintenance)
            "handles_opened": 0, "updates": 0, "updates_local": 0,
            "updates_full": 0, "update_seconds": 0.0,
        }

    # ------------------------------------------------------------- submit --
    def submit(self, edges: np.ndarray) -> int:
        """Queue one graph; returns a ticket for ``result``.

        ``edges`` is any (k, 2) integer array of undirected edges (either
        endpoint order; duplicate rows allowed; self-loops rejected, as are
        negative vertex ids and ids beyond the int32 CSR / int64 key-packing
        bounds — all used to corrupt results silently).  The result is
        aligned to the input rows: ``result(t)[i]`` is the trussness of
        ``edges[i]``.
        """
        E, lo, hi, n = canonical_edges_with_rows(edges)
        ticket = self._next_ticket
        self._next_ticket += 1
        self.stats["submitted"] += 1

        if E.size == 0:
            self._results[ticket] = np.zeros(0, np.int64)
            return ticket
        if E.shape[0] > self.max_edges:
            raise ValueError(
                f"graph too large for this engine: m={E.shape[0]} canonical "
                f"edges exceeds max_edges={self.max_edges}; decompose it "
                f"directly with core.pkt.truss_pkt, or raise max_edges")

        if self.reorder:
            perm = degeneracy_order(E, n)
            r_edges = relabel(E, perm)
        else:
            perm = np.arange(n, dtype=np.int64)
            r_edges = E
        # key of each *input row* in the relabeled space (handles duplicate
        # and endpoint-swapped rows: they map onto the same canonical edge)
        rl, rh = perm[lo], perm[hi]
        in_keys = edge_keys(np.minimum(rl, rh), np.maximum(rl, rh), n)

        g = build_csr(r_edges, n)
        if self.table_mode == "device":
            # tables never materialize on host: bucket by their exact entry
            # counts (O(m) host math) and ship only the CSR arrays
            stab = _TableDims(support_mod.support_table_size(g))
            ptab = _TableDims(support_mod.peel_table_size(g))
            key = self._size_class(g, stab, ptab)
            support_mod._check_table_size(max(key.sup_pad, key.peel_pad))
            operand = self._make_csr_operand(g, key)
        else:
            stab = support_mod.build_support_table(g)
            ptab = support_mod.build_peel_table(g)
            key = self._size_class(g, stab, ptab)
            operand = self._make_operand(g, key, stab, ptab)
        self._pending.append(_Pending(
            ticket=ticket, g=g, n=n, in_keys=in_keys,
            key=key, E=E, operand=operand))
        if len(self._pending) >= self.max_pending:
            self.flush()
        return ticket

    def submit_many(self, graphs) -> list[int]:
        """Submit each graph; returns order-aligned tickets."""
        return [self.submit(e) for e in graphs]

    # ------------------------------------------------------------ results --
    def result(self, ticket: int) -> np.ndarray:
        """Trussness for one ticket, flushing pending work if needed.

        Single-read: each ticket's result is released when collected (keeps
        engine memory bounded under streaming traffic); a second read, or an
        unknown ticket, raises KeyError.
        """
        if ticket not in self._results:
            if any(p.ticket == ticket for p in self._pending):
                self.flush()
            else:
                raise KeyError(
                    f"unknown or already-collected ticket {ticket!r}")
        return self._results.pop(ticket)

    def map(self, graphs) -> list[np.ndarray]:
        """Submit a list of graphs, flush once, return order-aligned results."""
        tickets = self.submit_many(graphs)
        self.flush()
        return [self.result(t) for t in tickets]

    # ----------------------------------------------- incremental handles --
    def open(self, edges, *, local_frac: float = 0.25,
             insert_mode: str | None = None) -> TrussHandle:
        """Decompose ``edges`` into a *persistent* handle for ``update``.

        Unlike ``submit``'s single-read tickets, a handle retains the CSR
        graph, wedge-table-derived state, support, and trussness across
        arbitrarily many ``update`` batches until ``close`` releases it.
        ``insert_mode`` overrides the engine's insertion repair strategy
        for this handle (``None``: engine default, §13).
        """
        inc = IncrementalTruss(
            edges, mode=self.mode, support_mode=self.support_mode,
            table_mode=self.table_mode, hier_mode=self.hier_mode,
            insert_mode=(self.insert_mode if insert_mode is None
                         else insert_mode),
            chunk=self.chunk, local_frac=local_frac,
            interpret=self.interpret)
        h = TrussHandle(self._next_handle, inc)
        self._next_handle += 1
        self._handles[h.hid] = h
        self.stats["handles_opened"] += 1
        return h

    def update(self, ticket_or_handle, *, add_edges=None,
               remove_edges=None,
               insert_mode: str | None = None) -> UpdateStats:
        """Apply one insert/delete batch to a handle (or promote a ticket).

        Accepts a :class:`TrussHandle`, or an *int ticket* whose submission
        is still pending — the ticket is then consumed (it can no longer be
        redeemed through ``result``) and promoted to a fresh handle, which
        the returned stats carry in ``.handle``.  Tickets already flushed or
        collected cannot be promoted (the engine has released their graph);
        re-``open`` the edges instead.

        Small batches are absorbed by local repair (affected-region re-peel,
        see ``core/truss_inc.py``); large ones fall back to a full
        recompute.  ``stats.mode`` reports which path ran.  ``insert_mode``
        overrides the handle's insertion strategy for this call (§13).
        """
        h = self._resolve_handle(ticket_or_handle)
        st = h._inc.update(add_edges=add_edges, remove_edges=remove_edges,
                           insert_mode=insert_mode)
        self.stats["updates"] += 1
        if st.mode == "full":
            self.stats["updates_full"] += 1
        elif st.mode == "local":
            self.stats["updates_local"] += 1
        self.stats["update_seconds"] += st.seconds
        return dataclasses.replace(st, handle=h)

    def update_many(self, ticket_or_handle, batches, *,
                    insert_mode: str | None = None) -> UpdateStats:
        """Apply several queued update batches to one handle as one repair.

        The scheduler's coalescing entry point (DESIGN.md §12): ``batches``
        is a sequence of ``(add_edges, remove_edges)`` pairs in arrival
        order; their set-wise composition (``core.truss_inc.
        compose_update_batches``) is applied as a *single*
        :meth:`IncrementalTruss.update`, so n queued churn batches cost one
        affected-region repair instead of n.

        Args:
            ticket_or_handle: a :class:`TrussHandle` (or promotable ticket,
                as in :meth:`update`).
            batches: iterable of ``(add_edges, remove_edges)`` pairs;
                either element may be ``None``.
            insert_mode: per-call override of the handle's insertion
                strategy (``None``: handle default, §13).

        Returns:
            One :class:`UpdateStats` for the composed repair, with
            ``coalesced`` set to the number of merged batches and
            ``handle`` set to the target handle.  The final state is
            bitwise-identical to applying the batches one at a time.

        Raises:
            ValueError: closed handle, or invalid edge arrays.
            KeyError: a ticket that is not promotable.
        """
        h = self._resolve_handle(ticket_or_handle)
        st = h._inc.update_many(batches, insert_mode=insert_mode)
        self.stats["updates"] += 1
        if st.mode == "full":
            self.stats["updates_full"] += 1
        elif st.mode == "local":
            self.stats["updates_local"] += 1
        self.stats["update_seconds"] += st.seconds
        return dataclasses.replace(st, handle=h)

    def close(self, handle: TrussHandle) -> None:
        """Release a handle's retained state; further use raises."""
        if handle.closed:
            return
        handle.closed = True
        self._handles.pop(handle.hid, None)
        handle._inc = None

    def _resolve_handle(self, ticket_or_handle) -> TrussHandle:
        if isinstance(ticket_or_handle, TrussHandle):
            if ticket_or_handle.closed:
                raise ValueError(
                    f"handle {ticket_or_handle.hid} is closed")
            return ticket_or_handle
        ticket = int(ticket_or_handle)
        for i, p in enumerate(self._pending):
            if p.ticket == ticket:
                del self._pending[i]
                return self.open(p.E)
        raise KeyError(
            f"ticket {ticket!r} cannot be promoted to a handle: it is not "
            f"pending (already decomposed, collected, or unknown) — "
            f"open() the edges to get an updatable handle")

    # ------------------------------------------------------------ internals --
    def _size_class(self, g: CSRGraph, stab, ptab) -> SizeClass:
        m_pad = max(_MIN_M_PAD, _next_pow2(g.m))
        sup_pad = _next_pow2(max(1, stab.size))
        peel_pad = _next_pow2(max(1, ptab.size))
        chunk = wedge_common.pow2_chunk(peel_pad, self.chunk)
        n_chunks = peel_pad // chunk
        iters = int(np.ceil(np.log2(2 * m_pad + 1))) + 1
        sup_chunk = wedge_common.pow2_chunk(sup_pad, self.chunk)
        n_pad = _next_pow2(g.n + 1) if self.table_mode == "device" else 0
        return SizeClass(m_pad, sup_pad, peel_pad, chunk, n_chunks, iters,
                         sup_chunk, sup_pad // sup_chunk, n_pad)

    def _make_csr_operand(self, g: CSRGraph, key: SizeClass) -> CSROperand:
        m_pad = key.m_pad
        two_m = 2 * g.m
        return CSROperand(
            N=jnp.asarray(_pad1(g.N, 2 * m_pad, _PAD_N)),
            Eid=jnp.asarray(_pad1(g.Eid, 2 * m_pad, m_pad)),
            Es=jnp.asarray(_pad1(g.Es, key.n_pad + 1, two_m)),
            Eo=jnp.asarray(_pad1(g.Eo, key.n_pad, two_m)),
            u=jnp.asarray(_pad1(g.El[:, 0], m_pad, 0)),
            v=jnp.asarray(_pad1(g.El[:, 1], m_pad, 0)),
            m_real=jnp.int32(g.m),
        )

    def _make_operand(self, g: CSRGraph, key: SizeClass, stab,
                      ptab) -> BatchOperand:
        m_pad = key.m_pad
        has_p, c_start, c_end = chunk_ranges(ptab.off, key.chunk, m_out=m_pad)
        return BatchOperand(
            N=jnp.asarray(_pad1(g.N, 2 * m_pad, _PAD_N)),
            Eid=jnp.asarray(_pad1(g.Eid, 2 * m_pad, m_pad)),
            s_e1=jnp.asarray(_pad1(stab.e1, key.sup_pad, 0)),
            s_cand=jnp.asarray(_pad1(stab.cand_slot, key.sup_pad, 0)),
            s_lo=jnp.asarray(_pad1(stab.lo, key.sup_pad, 0)),
            s_hi=jnp.asarray(_pad1(stab.hi, key.sup_pad, 0)),
            p_e1=jnp.asarray(_pad1(ptab.e1, key.peel_pad, m_pad)),
            p_cand=jnp.asarray(_pad1(ptab.cand_slot, key.peel_pad, 0)),
            p_lo=jnp.asarray(_pad1(ptab.lo, key.peel_pad, 0)),
            p_hi=jnp.asarray(_pad1(ptab.hi, key.peel_pad, 0)),
            c_start=jnp.asarray(c_start),
            c_end=jnp.asarray(c_end),
            has_entries=jnp.asarray(has_p),
            m_real=jnp.int32(g.m),
        )

    def discard(self, ticket: int) -> None:
        """Drop a ticket without computing or collecting it (scheduler hook).

        Args:
            ticket: a ticket returned by ``submit``; unknown tickets are
                ignored.  Removes the pending operand (or the materialized
                result) so cancelled or failed requests don't pin device
                arrays.
        """
        self._pending = [p for p in self._pending if p.ticket != ticket]
        self._results.pop(ticket, None)

    def bucket_of(self, ticket: int) -> SizeClass | None:
        """Size-class key of a still-pending ticket (scheduler hook).

        Args:
            ticket: a ticket returned by ``submit``.

        Returns:
            The pending submission's :class:`SizeClass` bucket key, or
            ``None`` when the ticket is not pending (empty graphs resolve at
            submit time; an auto-flush may have materialized the result) —
            its result, if any, is already available through ``result``.
        """
        for p in self._pending:
            if p.ticket == ticket:
                return p.key
        return None

    def flush(self, only=None, *, mode: str | None = None,
              support_mode: str | None = None) -> None:
        """Decompose pending graphs, bucket by bucket.

        Args:
            only: optional iterable of :class:`SizeClass` keys — flush only
                the pending submissions in those buckets (the scheduler's
                per-bucket dispatch hook).  ``None`` flushes everything.
            mode: per-call peel-executor override (``None``: the engine's
                configured mode) — the resilience layer's degradation-
                ladder hook (DESIGN.md §15); results are bitwise-identical
                across modes.
            support_mode: per-call support-executor override, same contract.

        Ordering contract: each bucket's results are materialized (and its
        submissions removed from the pending queue) only after its batched
        dispatch succeeds, in submission order within the bucket.  If a
        dispatch raises, that bucket's submissions *and every bucket not yet
        dispatched* remain pending — their tickets stay redeemable by a
        later ``flush``/``result``, and a still-pending ticket can still be
        promoted to a handle by ``update`` (promotions observe the results
        of earlier ``submit`` calls flushed in the same batch: the flush
        and the promotion's from-scratch decomposition agree bitwise, see
        ``tests/test_truss_engine.py``).
        """
        eff_mode = self.mode if mode is None else mode
        eff_support = self.support_mode if support_mode is None \
            else support_mode
        if eff_mode not in PEEL_MODES:
            raise ValueError(
                f"mode must be one of {PEEL_MODES}, got {eff_mode!r}")
        if eff_support not in support_mod.SUPPORT_MODES:
            raise ValueError(
                f"support_mode must be one of {support_mod.SUPPORT_MODES}, "
                f"got {eff_support!r}")
        if not self._pending:
            return
        by_key: dict[SizeClass, list[_Pending]] = {}
        keys = None if only is None else set(only)
        for p in self._pending:
            if keys is None or p.key in keys:
                by_key.setdefault(p.key, []).append(p)
        if not by_key:
            return

        for key, group in by_key.items():
            warm = key in self.stats["buckets"]
            t0 = time.perf_counter()
            fault_point("flush", rung=eff_mode)
            ops = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[p.operand for p in group])
            if self.table_mode == "device":
                S, S0, levels, subs = _batched_truss_dev(
                    ops, m=key.m_pad, chunk=key.chunk,
                    n_chunks=key.n_chunks, iters=key.iters, mode=eff_mode,
                    support_mode=eff_support, sup_chunk=key.sup_chunk,
                    sup_n_chunks=key.sup_n_chunks, sup_pad=key.sup_pad,
                    peel_pad=key.peel_pad, interpret=self.interpret)
            else:
                S, S0, levels, subs = _batched_truss(
                    ops, m=key.m_pad, chunk=key.chunk, n_chunks=key.n_chunks,
                    iters=key.iters, mode=eff_mode,
                    support_mode=eff_support, sup_chunk=key.sup_chunk,
                    sup_n_chunks=key.sup_n_chunks, interpret=self.interpret)
            S = np.asarray(S)
            for i, p in enumerate(group):
                truss = (S[i][: p.g.m] + 2).astype(np.int64)
                self._results[p.ticket] = align_to_input(
                    truss, p.g, None, p.n, keys=p.in_keys)
            # only now is the bucket done: drop its submissions from the
            # pending queue (a dispatch failure above leaves them — and
            # every bucket after them — pending and retryable)
            done = {p.ticket for p in group}
            self._pending = [p for p in self._pending
                             if p.ticket not in done]
            dt = time.perf_counter() - t0
            self.stats["batches"] += 1
            self.stats["buckets"].add(key)
            self.stats["graphs_done"] += len(group)
            self.stats["graph_seconds"] += dt
            if warm:
                self.stats["warm_seconds"] += dt
                self.stats["warm_graphs"] += len(group)
        self.stats["flushes"] += 1

    def flush_host(self, only=None) -> None:
        """Host-numpy fallback flush: the degradation ladder's last rung.

        Resolves the selected pending submissions with the pure-numpy
        reference decomposition (``core.ref.truss_numpy``) — no jax
        dispatch at all, so it stays available when every device executor
        is failing.  Results are bitwise-identical to :meth:`flush` (the
        reference is the repo's parity oracle); the same exception-safety
        contract applies (a failure leaves tickets pending and retryable).

        Args:
            only: optional iterable of :class:`SizeClass` keys, as in
                :meth:`flush`.
        """
        if not self._pending:
            return
        keys = None if only is None else set(only)
        group = [p for p in self._pending
                 if keys is None or p.key in keys]
        if not group:
            return
        t0 = time.perf_counter()
        fault_point("flush", rung="host")
        out = [align_to_input(truss_numpy(p.g.El), p.g, None, p.n,
                              keys=p.in_keys) for p in group]
        # commit only after every graph decomposed (exception safety)
        for p, truss in zip(group, out):
            self._results[p.ticket] = truss
        done = {p.ticket for p in group}
        self._pending = [p for p in self._pending if p.ticket not in done]
        self.stats["flushes"] += 1
        self.stats["graphs_done"] += len(group)
        self.stats["graph_seconds"] += time.perf_counter() - t0

    @property
    def throughput(self) -> float:
        """Graphs decomposed per second of engine compute.

        Based on warm dispatches only (buckets whose executable was already
        compiled); falls back to the all-in rate — which is dominated by XLA
        compile time — until any bucket has gone warm.
        """
        if self.stats["warm_seconds"] > 0:
            return self.stats["warm_graphs"] / self.stats["warm_seconds"]
        secs = self.stats["graph_seconds"]
        return self.stats["graphs_done"] / secs if secs > 0 else 0.0


def truss_batched(graphs, *, mode: str = "chunked",
                  support_mode: str = "jnp", table_mode: str = "device",
                  chunk: int | None = None,
                  reorder: bool = True) -> list[np.ndarray]:
    """One-shot convenience: decompose a list of edge arrays, order-aligned."""
    graphs = list(graphs)
    eng = TrussEngine(mode=mode, support_mode=support_mode,
                      table_mode=table_mode, chunk=chunk,
                      reorder=reorder, max_pending=len(graphs) or 1)
    return eng.map(graphs)
