"""Async continuous-batching truss serving — the event-loop scheduler.

``TrussEngine`` is a synchronous ticket queue: ``submit``/``open``/
``update``/``hierarchy`` all execute on the caller's thread, and nothing
coalesces mixed traffic into device dispatches.  This module puts the
LLM-serving shape on top of it (DESIGN.md §12): requests are admitted
asynchronously and return ``concurrent.futures.Future``s immediately, a
single scheduler thread runs a continuous-batching tick loop, and
compatible work coalesces per tick —

  * **decompositions** (``submit_async``) of one pow2 size class merge into
    one vmapped ``_batched_truss_dev`` dispatch (the engine's bucket
    machinery), released either when the bucket reaches ``max_batch`` or
    when its oldest request has waited ``max_delay_ms`` — the classic
    latency-vs-batch-fullness policy;
  * **handle updates** (``update_async``) queued against one handle merge
    set-wise into a single :class:`~repro.core.truss_inc.IncrementalTruss`
    repair (``compose_update_batches``: n churn batches, one
    affected-region re-peel), bitwise-identical to applying them one at a
    time;
  * **queries** (``query_async``/``communities_async``) serve from the
    handle's maintained trussness and cached hierarchy index, ordered FIFO
    per handle against that handle's updates, so every query observes
    exactly the prefix of updates admitted before it.

Admission control sheds load with a typed :class:`Overloaded` error (never
by silent queueing): a global queue-depth bound (``max_queue``) plus a
per-tenant in-flight cap (``max_inflight``); the error carries a
``retry_after_ms`` hint derived from the current depth and the measured
per-request service time.  Per-stage timing — queue wait, operand build,
device dispatch, result readback, repair, query, heal — is accumulated
and exposed via :meth:`TrussScheduler.stats`.

On top of the engine's exception safety sits the resilience layer
(DESIGN.md §15, ``serve/resilience.py``): every expensive dispatch runs
under bounded retry with deterministic backoff and a per-site executor
degradation ladder (demote to a bitwise-identical slower rung on repeated
failure, probe and re-promote on recovery); requests can carry deadlines
(typed :class:`DeadlineExceeded`); integrity violations in incremental
state quarantine the handle and rebuild it from its retained CSR while
queued requests wait (:class:`~repro.core.truss_inc.IntegrityError` →
heal); and an optional watchdog fails outstanding futures with a typed
:class:`Wedged` (plus the stuck thread's stack) when the tick loop stops
making progress.

Parity: the scheduler adds *no* numeric path of its own.  Async results
are bitwise-equal to the synchronous engine's because every dispatch is an
engine call (``submit``+``flush``+``result``, ``update_many``, handle
queries) and the only reordering it ever performs is across independent
requests — per-handle order is FIFO and update coalescing composes
set-wise exactly (DESIGN.md §12 gives the argument;
``benchmarks/serve_bench.py`` gates it in CI).  Degradation-ladder rungs
are drawn from the repo's parity-gated executor axes, so retries and
demotions never change any completed result
(``benchmarks/chaos_bench.py`` gates *that* under injected faults).

Usage::

    from repro.serve import TrussScheduler

    with TrussScheduler(max_batch=16, max_delay_ms=2.0) as sched:
        f1 = sched.submit_async(edges_a)          # Future[np.ndarray]
        f2 = sched.open_async(edges_b)            # Future[TrussHandle]
        h = f2.result()
        f3 = sched.update_async(h, add_edges=new_rows)
        f4 = sched.query_async(h, some_rows, deadline_ms=250.0)
        print(f1.result(), f3.result().mode, f4.result())
"""

from __future__ import annotations

import dataclasses
import sys
import threading
import time
import traceback
from collections import deque
from concurrent.futures import Future, InvalidStateError

import numpy as np

from repro.core.truss_inc import IntegrityError
from repro.serve.resilience import (DeadlineExceeded, Ladder, RetryPolicy,
                                    Wedged, override_attrs,
                                    run_with_resilience)
from repro.serve.truss_engine import TrussEngine, TrussHandle

_KINDS = ("submit", "open", "update", "query", "communities")

#: degradation-ladder attribute overrides for the region re-peel site
#: (applied to the handle's ``IncrementalTruss`` for one dispatch)
_REGION_OVERRIDES = {
    "default": {},
    "chunked": {"mode": "chunked"},
    "host": {"host_peel_max": 1 << 62},
}

#: ladder overrides for the support-build site (open / full rebuild)
_SUPPORT_OVERRIDES = {
    "default": {},
    "jnp": {"support_mode": "jnp"},
    "numpy": {"support_mode": "jnp", "table_mode": "numpy"},
}


class Overloaded(RuntimeError):
    """Request shed by admission control.

    Raised synchronously by the ``*_async`` entry points when the global
    queue depth reaches ``max_queue`` or the calling tenant already has
    ``max_inflight`` requests in flight.  Shedding at admission (instead of
    queueing unboundedly) keeps tail latency bounded under overload; the
    caller owns the retry policy, and ``retry_after_ms`` informs it: the
    estimated time for the current backlog to drain, computed from the
    queue depth and the measured mean per-request service time in
    ``stats()["stages"]`` (clamped to ``[max_delay_ms, 60000]``; the
    dispatch-delay bound is the floor before any request has completed).
    """

    def __init__(self, message: str, *, retry_after_ms: float | None = None):
        super().__init__(message)
        self.retry_after_ms = retry_after_ms


class Cancelled(RuntimeError):
    """Request cancelled by ``close(drain=False)`` before dispatch.

    Set as the future's exception (so ``result()`` raises it — typed,
    never a bare ``RuntimeError``), carrying the request ``kind`` and the
    request's ``position`` in the cancelled queue snapshot (admission
    order: position 0 was next in line).
    """

    def __init__(self, kind: str, position: int):
        super().__init__(
            f"{kind} request cancelled by close(drain=False) at queue "
            f"position {position}")
        self.kind = kind
        self.position = position


@dataclasses.dataclass(eq=False)
class _Request:
    """One admitted request, queued between admission and completion."""

    kind: str                      # one of _KINDS
    tenant: str
    future: Future
    t_enq: float                   # perf_counter at admission
    edges: np.ndarray | None = None        # submit/open/query payload
    handle: TrussHandle | None = None      # update/query/communities target
    add: np.ndarray | None = None          # update payload
    remove: np.ndarray | None = None
    k: int = 0                             # communities level
    local_frac: float = 0.25               # open policy
    t_deadline: float | None = None        # absolute perf_counter deadline


class TrussScheduler:
    """Event-loop continuous-batching scheduler over a :class:`TrussEngine`.

    One background thread owns the engine; callers interact only through
    the ``*_async`` methods, each returning a ``concurrent.futures.Future``
    (engine errors — validation, oversized graphs, closed handles —
    surface as that future's exception; admission errors raise
    :class:`Overloaded` synchronously).

    Args:
        engine: the engine to serve; ``None`` builds one from
            ``engine_kwargs`` (with ``max_pending`` raised so the engine's
            own auto-flush never preempts the dispatch policy).  Once
            wrapped, the engine must not be driven concurrently from other
            threads.
        max_batch: dispatch a decomposition bucket as soon as it holds this
            many requests.
        max_delay_ms: dispatch a non-empty bucket once its oldest request
            has waited this long, even if not full (the latency bound; 0
            dispatches every tick).
        max_queue: global admitted-but-unfinished request bound; beyond it
            admissions shed with :class:`Overloaded`.
        max_inflight: per-tenant in-flight bound (same shedding).
        deadline_ms: default per-request deadline (``None``: no deadline);
            each ``*_async`` call may override.  Expired requests fail with
            a typed :class:`DeadlineExceeded` — before dispatch for every
            kind, and additionally at delivery for read-only kinds
            (submit/query/communities); committed updates and opens always
            deliver, so deadline pressure never tears state.
        retry: :class:`RetryPolicy` for transient dispatch failures
            (``None``: the default policy — 2 retries, exponential backoff
            from 2ms with deterministic jitter).
        ladder: optional dict of :class:`Ladder` keyword overrides
            (``demote_after``/``probe_after``/``promote_after``) applied to
            every dispatch site's degradation ladder.
        invariant_sample: edges sampled by the post-repair
            ``IncrementalTruss.check_invariants`` sweep (0 disables).
        watchdog_s: if set, a watchdog thread fails all outstanding
            futures with :class:`Wedged` (including the scheduler thread's
            stack as diagnostics) when the tick loop makes no progress for
            this long while work is queued.  ``None`` (default) disables;
            set it comfortably above worst-case cold-compile time.
        start: start the scheduler thread immediately; ``False`` leaves
            requests queued until :meth:`start` (tests use this to stage
            traffic deterministically).
        **engine_kwargs: forwarded to :class:`TrussEngine` when ``engine``
            is ``None`` (``mode``, ``support_mode``, ``table_mode``, …).

    Raises:
        ValueError: non-positive ``max_batch``/``max_queue``/
            ``max_inflight``, negative ``max_delay_ms``, or non-positive
            ``deadline_ms``/``watchdog_s``/``invariant_sample``.
    """

    def __init__(self, engine: TrussEngine | None = None, *,
                 max_batch: int = 16, max_delay_ms: float = 2.0,
                 max_queue: int = 256, max_inflight: int = 64,
                 deadline_ms: float | None = None,
                 retry: RetryPolicy | None = None,
                 ladder: dict | None = None,
                 invariant_sample: int = 64,
                 watchdog_s: float | None = None,
                 start: bool = True, **engine_kwargs):
        if max_batch < 1:
            raise ValueError("max_batch must be positive")
        if max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        if max_queue < 1:
            raise ValueError("max_queue must be positive")
        if max_inflight < 1:
            raise ValueError("max_inflight must be positive")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError("deadline_ms must be positive (or None)")
        if watchdog_s is not None and watchdog_s <= 0:
            raise ValueError("watchdog_s must be positive (or None)")
        if invariant_sample < 0:
            raise ValueError("invariant_sample must be >= 0")
        if engine is None:
            engine_kwargs.setdefault("max_pending", 4 * max_batch + max_queue)
            engine = TrussEngine(**engine_kwargs)
        elif engine_kwargs:
            raise ValueError("pass engine_kwargs only without an engine")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay_ms) / 1e3
        self.max_queue = int(max_queue)
        self.max_inflight = int(max_inflight)
        self.deadline_ms = deadline_ms
        self.retry = retry if retry is not None else RetryPolicy()
        self.invariant_sample = int(invariant_sample)
        self.watchdog_s = watchdog_s
        self._ladders = self._build_ladders(dict(ladder or {}))

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._inbox: deque[_Request] = deque()
        #: bucket key -> [(ticket, request)] awaiting batched dispatch
        self._buckets: dict[object, list[tuple[int, _Request]]] = {}
        #: handle id -> FIFO of update/query/communities requests
        self._hqueues: dict[int, deque[_Request]] = {}
        #: every admitted, unresolved request (the watchdog's fail set;
        #: authoritative for _finish bookkeeping)
        self._outstanding: set[_Request] = set()
        #: handle ids whose incremental state is suspect: healed (rebuilt
        #: from the retained CSR) before the next request is served
        self._quarantined: set[int] = set()
        self._depth = 0                    # admitted, not yet finished
        self._inflight: dict[str, int] = {}
        self._closed = False
        self._drain = True
        self._wedged: str | None = None    # watchdog diagnostics once tripped
        self._heartbeat = time.perf_counter()
        self._nchecks = 0                  # invariant-sweep seed counter
        self._counters = {k: 0 for k in _KINDS}
        self._counters.update(shed=0, done=0, errors=0, cancelled=0,
                              dispatches=0, coalesced_updates=0,
                              retries=0, deadline_exceeded=0, heals=0,
                              heal_failures=0, watchdog_trips=0)
        self._stages = {k: {"count": 0, "seconds": 0.0, "max_seconds": 0.0}
                        for k in ("queue_wait", "build", "dispatch",
                                  "readback", "open", "repair", "query",
                                  "heal")}
        self._thread: threading.Thread | None = None
        self._watchdog_thread: threading.Thread | None = None
        self._watchdog_stop = threading.Event()
        if start:
            self.start()

    def _build_ladders(self, opts: dict) -> dict[str, Ladder]:
        """Per-site degradation ladders from the engine's configured modes.

        Every rung pairing is one of the repo's parity-gated executor
        axes, so demotion changes latency, never results; rungs equal to
        the configured executor are deduplicated away.
        """
        e = self.engine
        flush = [f"{e.mode}+{e.support_mode}"]
        if (e.mode, e.support_mode) != ("chunked", "jnp"):
            flush.append("chunked+jnp")
        flush.append("host")
        region = ["default"]
        if e.mode != "chunked":
            region.append("chunked")
        region.append("host")
        support = ["default"]
        if e.support_mode != "jnp":
            support.append("jnp")
        if e.table_mode != "numpy":
            support.append("numpy")
        hier = ["default"]
        if e.hier_mode != "host":
            hier.append("host")
        return {site: Ladder(tuple(rungs), **opts)
                for site, rungs in (("flush", flush), ("region", region),
                                    ("support", support),
                                    ("hierarchy", hier))}

    # ------------------------------------------------------------ lifecycle --
    def start(self) -> None:
        """Start the scheduler (and watchdog) threads (idempotent)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop, name="truss-scheduler", daemon=True)
                self._thread.start()
            if self.watchdog_s is not None \
                    and self._watchdog_thread is None:
                self._watchdog_thread = threading.Thread(
                    target=self._watchdog, name="truss-watchdog", daemon=True)
                self._watchdog_thread.start()

    def close(self, *, drain: bool = True) -> None:
        """Stop the scheduler.

        Args:
            drain: ``True`` dispatches everything already admitted before
                stopping (their futures complete — a never-started
                scheduler with queued work is started just to drain it);
                ``False`` rejects queued requests with a typed
                :class:`Cancelled` (no future is ever left unresolved).
        """
        if drain:
            with self._lock:
                not_started = self._thread is None and not self._closed
                pending = bool(self._inbox or self._buckets or self._hqueues)
            if not_started and pending:
                self.start()    # someone must run the drain
        with self._work:
            if self._closed and self._thread is None \
                    and self._watchdog_thread is None:
                return
            self._closed = True
            self._drain = drain
            self._work.notify_all()
            t = self._thread
            wt = self._watchdog_thread
        if t is not None:
            t.join()
        else:
            # never-started scheduler: no loop will run _cancel_all, so
            # resolve everything queued inline
            with self._lock:
                batch = list(self._inbox)
                self._inbox.clear()
            self._cancel_all(batch)
        self._watchdog_stop.set()
        if wt is not None:
            wt.join()
        with self._lock:
            self._thread = None
            self._watchdog_thread = None

    def __enter__(self):
        """Context manager: returns self (thread already running)."""
        self.start()
        return self

    def __exit__(self, *exc):
        """Context manager exit: drain and stop the scheduler thread."""
        self.close(drain=True)
        return False

    # ------------------------------------------------------------ admission --
    def _retry_after_ms(self):  # trusslint: holds[_lock]
        """Backlog-drain estimate for the Overloaded hint (under the lock).

        Mean service seconds per completed request (all stages except
        queue wait) times the current depth, clamped to
        ``[max_delay_ms, 60s]``; before any completion the dispatch-delay
        bound is all we know.
        """
        done = max(1, self._counters["done"])
        busy = sum(s["seconds"] for k, s in self._stages.items()
                   if k != "queue_wait")
        per_req = busy / done
        hint = max(self.max_delay * 1e3, self._depth * per_req * 1e3)
        return min(60_000.0, max(1.0, hint))

    def _admit(self, req: _Request) -> Future:
        with self._work:
            if self._closed:
                if self._wedged is not None:
                    raise Wedged(self._wedged)
                raise RuntimeError("scheduler is closed")
            if self._depth >= self.max_queue:
                self._counters["shed"] += 1
                hint = self._retry_after_ms()
                raise Overloaded(
                    f"queue depth {self._depth} at max_queue="
                    f"{self.max_queue}: request shed; retry after "
                    f"~{hint:.0f}ms or raise max_queue",
                    retry_after_ms=hint)
            if self._inflight.get(req.tenant, 0) >= self.max_inflight:
                self._counters["shed"] += 1
                hint = self._retry_after_ms()
                raise Overloaded(
                    f"tenant {req.tenant!r} has "
                    f"{self._inflight[req.tenant]} requests in flight "
                    f"(max_inflight={self.max_inflight}): request shed; "
                    f"retry after ~{hint:.0f}ms",
                    retry_after_ms=hint)
            self._depth += 1
            self._inflight[req.tenant] = \
                self._inflight.get(req.tenant, 0) + 1
            self._counters[req.kind] += 1
            self._outstanding.add(req)
            self._inbox.append(req)
            self._work.notify()
        return req.future

    def _deadline_for(self, t_enq: float, deadline_ms) -> float | None:
        """Absolute deadline for a request admitted at ``t_enq``."""
        dl = self.deadline_ms if deadline_ms is None else deadline_ms
        if dl is None:
            return None
        dl = float(dl)
        if dl <= 0:
            raise ValueError("deadline_ms must be positive (or None)")
        return t_enq + dl / 1e3

    @staticmethod
    def _check_handle(handle) -> TrussHandle:
        if not isinstance(handle, TrussHandle):
            raise TypeError(
                f"expected a TrussHandle (from open_async), got "
                f"{type(handle).__name__}; the scheduler does not promote "
                f"tickets — open the graph instead")
        if handle.closed:
            raise ValueError(f"handle {handle.hid} is closed")
        return handle

    def submit_async(self, edges, *, tenant: str = "default",
                     deadline_ms: float | None = None) -> Future:
        """Queue one decomposition; the future resolves to its trussness.

        Args:
            edges: ``(k, 2)`` integer edge array (``TrussEngine.submit``
                validation applies — on failure the *future* carries the
                ValueError).
            tenant: admission-control accounting key.
            deadline_ms: per-request deadline override (``None``: the
                scheduler default).

        Returns:
            ``Future[np.ndarray]`` — trussness aligned to the input rows,
            bitwise-equal to ``TrussEngine.submit``/``result``.

        Raises:
            Overloaded: shed by queue-depth or per-tenant admission control.
            RuntimeError: the scheduler is closed.
        """
        t = time.perf_counter()
        return self._admit(_Request(
            kind="submit", tenant=tenant, future=Future(), t_enq=t,
            edges=np.asarray(edges),
            t_deadline=self._deadline_for(t, deadline_ms)))

    def open_async(self, edges, *, local_frac: float = 0.25,
                   tenant: str = "default",
                   deadline_ms: float | None = None) -> Future:
        """Queue a persistent-handle open (full decomposition).

        Args:
            edges: ``(k, 2)`` integer edge array.
            local_frac: the handle's local-repair fallback threshold.
            tenant: admission-control accounting key.
            deadline_ms: per-request deadline override (checked before the
                open dispatches; a handle that finished building is always
                delivered, never leaked).

        Returns:
            ``Future[TrussHandle]`` — pass the handle to ``update_async``/
            ``query_async``/``communities_async``.

        Raises:
            Overloaded: shed by admission control.
            RuntimeError: the scheduler is closed.
        """
        t = time.perf_counter()
        return self._admit(_Request(
            kind="open", tenant=tenant, future=Future(), t_enq=t,
            edges=np.asarray(edges), local_frac=local_frac,
            t_deadline=self._deadline_for(t, deadline_ms)))

    def update_async(self, handle: TrussHandle, *, add_edges=None,
                     remove_edges=None, tenant: str = "default",
                     deadline_ms: float | None = None) -> Future:
        """Queue one insert/delete batch against a handle.

        Consecutive updates queued against the same handle (with no query
        between them) coalesce into a single composed repair; each of their
        futures then carries the same :class:`UpdateStats` with
        ``coalesced`` set to the merge width.

        Args:
            handle: an open handle from ``open_async`` (or
                ``TrussEngine.open``).
            add_edges: edges to insert (``None`` for none).
            remove_edges: edges to delete.
            tenant: admission-control accounting key.
            deadline_ms: per-request deadline override (checked before the
                repair dispatches; a committed repair always resolves its
                futures — deadline pressure never tears state).

        Returns:
            ``Future[UpdateStats]`` for the (possibly coalesced) repair.

        Raises:
            Overloaded: shed by admission control.
            TypeError: ``handle`` is not a :class:`TrussHandle`.
            ValueError: the handle is already closed.
            RuntimeError: the scheduler is closed.
        """
        t = time.perf_counter()
        return self._admit(_Request(
            kind="update", tenant=tenant, future=Future(), t_enq=t,
            handle=self._check_handle(handle), add=add_edges,
            remove=remove_edges,
            t_deadline=self._deadline_for(t, deadline_ms)))

    def query_async(self, handle: TrussHandle, edges, *,
                    tenant: str = "default",
                    deadline_ms: float | None = None) -> Future:
        """Queue a trussness query; FIFO-ordered against the handle's updates.

        Args:
            handle: an open handle.
            edges: ``(k, 2)`` rows to look up (endpoint order/dupes OK).
            tenant: admission-control accounting key.
            deadline_ms: per-request deadline override.

        Returns:
            ``Future[np.ndarray]`` — per-row trussness, observing exactly
            the updates admitted on this handle before this query.

        Raises:
            Overloaded: shed by admission control.
            TypeError: ``handle`` is not a :class:`TrussHandle`.
            ValueError: the handle is already closed.
            RuntimeError: the scheduler is closed.
        """
        t = time.perf_counter()
        return self._admit(_Request(
            kind="query", tenant=tenant, future=Future(), t_enq=t,
            handle=self._check_handle(handle), edges=np.asarray(edges),
            t_deadline=self._deadline_for(t, deadline_ms)))

    def communities_async(self, handle: TrussHandle, k: int, *,
                          tenant: str = "default",
                          deadline_ms: float | None = None) -> Future:
        """Queue a k-truss community listing against the cached index.

        Args:
            handle: an open handle.
            k: community level (see ``TrussHandle.communities``).
            tenant: admission-control accounting key.
            deadline_ms: per-request deadline override.

        Returns:
            ``Future[list[np.ndarray]]`` — every level-``k`` community as a
            ``(c, 2)`` endpoint array, served from the handle's lazily
            built, update-surviving hierarchy index.

        Raises:
            Overloaded: shed by admission control.
            TypeError: ``handle`` is not a :class:`TrussHandle`.
            ValueError: the handle is already closed.
            RuntimeError: the scheduler is closed.
        """
        t = time.perf_counter()
        return self._admit(_Request(
            kind="communities", tenant=tenant, future=Future(), t_enq=t,
            handle=self._check_handle(handle), k=int(k),
            t_deadline=self._deadline_for(t, deadline_ms)))

    # ------------------------------------------------------------- the loop --
    def _loop(self) -> None:
        while True:
            self._heartbeat = time.perf_counter()
            with self._work:
                if self._wedged is not None:
                    return
                if not self._inbox and not self._closed:
                    due = self._seconds_to_deadline()
                    if due is None or due > 0:
                        self._work.wait(timeout=due)
                batch = list(self._inbox)
                self._inbox.clear()
                closing = self._closed
                drain = self._drain
            if closing and not drain:
                self._cancel_all(batch)
                return
            self._route(batch)
            self._service_handles()
            self._dispatch_buckets(force=closing)
            with self._lock:
                if (self._closed and not self._inbox and not self._buckets
                        and not self._hqueues):
                    return

    def _seconds_to_deadline(self):  # trusslint: holds[_lock]
        """Time until the next bucket must dispatch; None when no bucket waits.

        The deadline of a bucket is ``oldest.t_enq + max_delay``; a bucket
        at ``max_batch`` is due immediately.  Called under the lock.
        """
        if not self._buckets:
            return None
        now = time.perf_counter()
        due = None
        for entries in self._buckets.values():
            if len(entries) >= self.max_batch:
                return 0.0
            oldest = entries[0][1].t_enq
            d = max(0.0, oldest + self.max_delay - now)
            due = d if due is None else min(due, d)
        return due

    # ------------------------------------------------------------ watchdog --
    def _watchdog(self) -> None:
        period = max(0.01, self.watchdog_s / 4)
        while not self._watchdog_stop.wait(period):
            with self._lock:
                depth = self._depth
                closed = self._closed
            if closed or depth == 0:
                continue
            stalled = time.perf_counter() - self._heartbeat
            if stalled < self.watchdog_s:
                continue
            self._trip_watchdog(stalled)
            return

    def _trip_watchdog(self, stalled: float) -> None:
        """Fail fast: the tick loop is wedged with work queued.

        Captures the scheduler thread's stack, marks the scheduler wedged
        and closed, and fails every outstanding future with a typed
        :class:`Wedged` carrying the diagnostics.  The engine is *not*
        touched (it is owned by the stuck thread and is not thread-safe);
        its state is undefined after a wedge and the scheduler will not
        admit further work.
        """
        with self._lock:
            t = self._thread
        stack = "<scheduler thread stack unavailable>"
        if t is not None and t.ident is not None:
            frames = sys._current_frames()
            if t.ident in frames:
                stack = "".join(traceback.format_stack(frames[t.ident]))
        with self._work:
            diag = (
                f"scheduler tick loop wedged: no progress for "
                f"{stalled:.2f}s (watchdog_s={self.watchdog_s}, depth="
                f"{self._depth}); counters={dict(self._counters)}; "
                f"scheduler thread stack:\n{stack}")
            self._counters["watchdog_trips"] += 1
            self._wedged = diag
            self._closed = True
            outstanding = list(self._outstanding)
            self._outstanding.clear()
            self._depth = 0
            self._inflight.clear()
            self._buckets.clear()
            self._hqueues.clear()
            self._inbox.clear()
            self._work.notify_all()
        exc = Wedged(diag)
        for req in outstanding:
            try:
                req.future.set_exception(exc)
            except InvalidStateError:
                pass    # resolved in the race window; either answer is fine

    # ----------------------------------------------------------- completion --
    def _finish(self, req: _Request, value=None, exc=None) -> None:
        with self._lock:
            if req not in self._outstanding:
                # already finalized (watchdog trip or cancellation) — the
                # bookkeeping is done; at most defensively resolve below
                pass
            else:
                self._outstanding.discard(req)
                self._depth -= 1
                left = self._inflight.get(req.tenant, 1) - 1
                if left <= 0:
                    self._inflight.pop(req.tenant, None)
                else:
                    self._inflight[req.tenant] = left
                self._counters["done"] += 1
                if exc is not None:
                    self._counters["errors"] += 1
                    if isinstance(exc, DeadlineExceeded):
                        self._counters["deadline_exceeded"] += 1
        try:
            if exc is not None:
                req.future.set_exception(exc)
            else:
                req.future.set_result(value)
        except InvalidStateError:
            pass    # the watchdog failed this future first; keep its answer

    def _cancel_all(self, batch) -> None:
        """close(drain=False): reject everything queued with typed Cancelled.

        The dispatch structures are guarded state (`stats()` can race this
        teardown from another thread), so they are snapshotted-and-swapped
        under the lock; the engine discards then run outside it.  Every
        future resolves — with :class:`Cancelled` carrying the request
        kind and queue position — so no caller is ever left hanging.
        """
        pending = list(batch)
        with self._lock:
            buckets, self._buckets = self._buckets, {}
            hqueues, self._hqueues = self._hqueues, {}
        for entries in buckets.values():
            for ticket, r in entries:
                self.engine.discard(ticket)
                pending.append(r)
        for q in hqueues.values():
            pending.extend(q)
        for pos, req in enumerate(pending):
            with self._lock:
                if req not in self._outstanding:
                    continue
                self._outstanding.discard(req)
                self._depth -= 1
                self._counters["cancelled"] += 1
            try:
                req.future.set_exception(Cancelled(req.kind, pos))
            except InvalidStateError:
                pass    # the watchdog beat us to this future
        with self._lock:
            self._inflight.clear()

    def _stage(self, name: str, seconds: float) -> None:
        with self._lock:
            s = self._stages[name]
            s["count"] += 1
            s["seconds"] += seconds
            s["max_seconds"] = max(s["max_seconds"], seconds)

    # ----------------------------------------------------------- resilience --
    def _count_retry(self) -> None:
        with self._lock:
            self._counters["retries"] += 1

    def _expired(self, req: _Request, now: float | None = None) -> bool:
        return req.t_deadline is not None and \
            (time.perf_counter() if now is None else now) >= req.t_deadline

    @staticmethod
    def _deadline_exc(req: _Request) -> DeadlineExceeded:
        over = (time.perf_counter() - req.t_deadline) * 1e3
        return DeadlineExceeded(
            f"{req.kind} request missed its deadline by {over:.1f}ms",
            kind=req.kind)

    def _ensure_healthy(self, handle: TrussHandle) -> None:
        """Heal a quarantined handle before serving it (§15).

        Quarantined handles are not served and not abandoned: the next
        request triggers another rebuild attempt, so queued requests wait
        for recovery rather than fail — they only fail when the rebuild
        itself keeps failing (the exception propagates to their futures).
        """
        with self._lock:
            suspect = handle.hid in self._quarantined
        if suspect:
            self._heal(handle, None)

    def _heal(self, handle: TrussHandle, batches):
        """Quarantine + rebuild from the retained CSR (+ re-apply updates).

        The recovery action for :class:`IntegrityError` (DESIGN.md §15):
        the handle is quarantined, its state rediscovered from scratch
        (``IncrementalTruss.rebuild`` — a full ``pkt`` over the retained
        edge list), the not-yet-committed update ``batches`` re-applied
        (``None`` when the violating repair already committed), and the
        invariant sweep re-run.  Two attempts; on repeated failure the
        handle *stays* quarantined and the error propagates to the
        requests' futures.  Returns the re-applied ``UpdateStats`` (or
        ``None``).
        """
        hid = handle.hid
        inc = handle._inc  # noqa: SLF001 — the scheduler owns its handles
        with self._lock:
            self._quarantined.add(hid)
            self._counters["heals"] += 1
        t0 = time.perf_counter()
        ladders = {k: self._ladders[k] for k in ("region", "support")}

        def attempt(rungs):
            ov = {**_REGION_OVERRIDES[rungs["region"]],
                  **_SUPPORT_OVERRIDES[rungs["support"]]}
            with override_attrs(inc, **ov):
                inc.rebuild()
                return self.engine.update_many(handle, batches) \
                    if batches else None

        for final in (False, True):
            try:
                st = run_with_resilience(
                    attempt, ladders=ladders, primary="support",
                    policy=self.retry, kind="update",
                    on_retry=self._count_retry)
                if self.invariant_sample:
                    self._nchecks += 1
                    inc.check_invariants(sample=self.invariant_sample,
                                         seed=self._nchecks)
            except Exception:           # noqa: BLE001 — one more try, then up
                if final:
                    with self._lock:
                        self._counters["heal_failures"] += 1
                    self._stage("heal", time.perf_counter() - t0)
                    raise
                continue
            with self._lock:
                self._quarantined.discard(hid)
            self._stage("heal", time.perf_counter() - t0)
            return st

    # ------------------------------------------------------------- routing --
    def _route(self, batch) -> None:
        """Admit a tick's inbox into the dispatch structures (build stage)."""
        for req in batch:
            now = time.perf_counter()
            self._stage("queue_wait", now - req.t_enq)
            if self._expired(req, now):
                self._finish(req, exc=self._deadline_exc(req))
                continue
            if req.kind == "submit":
                try:
                    t0 = time.perf_counter()
                    ticket = self.engine.submit(req.edges)
                    self._stage("build", time.perf_counter() - t0)
                    key = self.engine.bucket_of(ticket)
                except Exception as e:          # noqa: BLE001 — to future
                    self._finish(req, exc=e)
                    continue
                if key is None:
                    # resolved at submit (empty graph / engine auto-flush)
                    self._finish(req, value=self.engine.result(ticket))
                else:
                    with self._lock:
                        self._buckets.setdefault(key, []).append(
                            (ticket, req))
            elif req.kind == "open":
                try:
                    t0 = time.perf_counter()
                    h = self._resilient_open(req)
                    self._stage("open", time.perf_counter() - t0)
                except Exception as e:          # noqa: BLE001 — to future
                    self._finish(req, exc=e)
                    continue
                self._finish(req, value=h)
            else:                               # update / query / communities
                with self._lock:
                    self._hqueues.setdefault(
                        req.handle.hid, deque()).append(req)

    def _resilient_open(self, req: _Request) -> TrussHandle:
        """Open under the support-site ladder (engine attrs overridden).

        A demoted rung builds the handle with fallback support executors;
        the handle's own attributes are then reset to the engine defaults
        so it is not permanently demoted.
        """
        def call(rungs):
            ov = _SUPPORT_OVERRIDES[rungs["support"]]
            with override_attrs(self.engine, **ov):
                return self.engine.open(req.edges,
                                        local_frac=req.local_frac)
        h = run_with_resilience(
            call, ladders={"support": self._ladders["support"]},
            primary="support", policy=self.retry, deadline=req.t_deadline,
            kind="open", on_retry=self._count_retry)
        h._inc.support_mode = self.engine.support_mode  # noqa: SLF001
        h._inc.table_mode = self.engine.table_mode      # noqa: SLF001
        return h

    # ------------------------------------------------- handle-op servicing --
    def _service_handles(self) -> None:
        """Drain every handle queue FIFO, coalescing update runs (§12).

        Per handle, consecutive updates (up to the next query) compose into
        one ``engine.update_many`` repair; queries then run against exactly
        the state their admission order promises.
        """
        with self._lock:
            if not self._hqueues:
                return
            queues, self._hqueues = self._hqueues, {}
        for q in queues.values():
            while q:
                run = []
                while q and q[0].kind == "update":
                    run.append(q.popleft())
                if run:
                    self._run_update(run)
                if q:
                    self._run_query(q.popleft())

    def _run_update(self, run) -> None:
        handle = run[0].handle
        now = time.perf_counter()
        live = []
        for r in run:
            if self._expired(r, now):
                # not yet dispatched: excluded from the composed batch, so
                # the deadline rejection is exact (nothing half-applied)
                self._finish(r, exc=self._deadline_exc(r))
            else:
                live.append(r)
        if not live:
            return
        batches = [(r.add, r.remove) for r in live]
        deadlines = [r.t_deadline for r in live if r.t_deadline is not None]
        deadline = min(deadlines) if deadlines else None
        t0 = time.perf_counter()
        try:
            self._ensure_healthy(handle)
            try:
                st = self._resilient_update(handle, batches, deadline)
            except IntegrityError:
                # detected before commit: state untouched (batch-scoped
                # commit), so rebuild and re-apply the whole batch
                st = self._heal(handle, batches)
            else:
                if self.invariant_sample:
                    try:
                        self._nchecks += 1
                        handle._inc.check_invariants(  # noqa: SLF001
                            sample=self.invariant_sample, seed=self._nchecks)
                    except IntegrityError:
                        # committed state is suspect: rebuild in place (the
                        # batch is already in the edge list; not re-applied)
                        self._heal(handle, None)
        except Exception as e:                  # noqa: BLE001 — to futures
            for r in live:
                self._finish(r, exc=e)
            return
        self._stage("repair", time.perf_counter() - t0)
        with self._lock:
            self._counters["dispatches"] += 1
            self._counters["coalesced_updates"] += len(live) - 1
        for r in live:
            self._finish(r, value=st)

    def _resilient_update(self, handle, batches, deadline):
        """One composed repair under the region+support ladders."""
        inc = handle._inc  # noqa: SLF001 — the scheduler owns its handles

        def call(rungs):
            ov = {**_REGION_OVERRIDES[rungs["region"]],
                  **_SUPPORT_OVERRIDES[rungs["support"]]}
            with override_attrs(inc, **ov):
                return self.engine.update_many(handle, batches)
        return run_with_resilience(
            call,
            ladders={k: self._ladders[k] for k in ("region", "support")},
            primary="region", policy=self.retry, deadline=deadline,
            kind="update", on_retry=self._count_retry)

    def _run_query(self, req: _Request) -> None:
        if self._expired(req):
            self._finish(req, exc=self._deadline_exc(req))
            return
        t0 = time.perf_counter()
        try:
            self._ensure_healthy(req.handle)
            if req.kind == "query":
                out = req.handle.query(req.edges)
            else:
                out = self._resilient_communities(req)
        except Exception as e:                  # noqa: BLE001 — to future
            self._finish(req, exc=e)
            return
        self._stage("query", time.perf_counter() - t0)
        if self._expired(req):
            # read-only: dropping the late result is safe and keeps the
            # deadline contract exact
            self._finish(req, exc=self._deadline_exc(req))
            return
        self._finish(req, value=out)

    def _resilient_communities(self, req: _Request):
        """Community listing under the hierarchy-site ladder."""
        def call(rungs):
            rung = rungs["hierarchy"]
            return req.handle.communities(
                req.k, hier_mode=None if rung == "default" else rung)
        return run_with_resilience(
            call, ladders={"hierarchy": self._ladders["hierarchy"]},
            primary="hierarchy", policy=self.retry,
            deadline=req.t_deadline, kind="communities",
            on_retry=self._count_retry)

    # ------------------------------------------------------ bucket dispatch --
    def _dispatch_buckets(self, *, force: bool = False) -> None:
        """Flush every due bucket: full, past deadline, or forced (drain).

        Each bucket flush runs under the flush-site ladder: retries stay
        on the engine's configured executors, demotion falls back to the
        ``chunked+jnp`` pair and finally to the host-numpy reference —
        all bitwise-identical.  Requests already past their deadline are
        rejected before the dispatch (and their tickets discarded);
        read-only submits are deadline-checked again at delivery.
        """
        now = time.perf_counter()
        with self._lock:
            due = []
            for key in list(self._buckets):
                entries = self._buckets[key]
                oldest = entries[0][1].t_enq
                if (force or len(entries) >= self.max_batch
                        or now - oldest >= self.max_delay):
                    due.append((key, entries))
                    del self._buckets[key]
        for key, entries in due:
            now = time.perf_counter()
            live = []
            for ticket, r in entries:
                if self._expired(r, now):
                    self.engine.discard(ticket)
                    self._finish(r, exc=self._deadline_exc(r))
                else:
                    live.append((ticket, r))
            if not live:
                continue
            t0 = time.perf_counter()

            def flush(rungs, key=key):
                rung = rungs["flush"]
                if rung == "host":
                    self.engine.flush_host(only=[key])
                else:
                    m, sm = rung.split("+")
                    self.engine.flush(only=[key], mode=m, support_mode=sm)
            try:
                run_with_resilience(
                    flush, ladders={"flush": self._ladders["flush"]},
                    primary="flush", policy=self.retry, kind="submit",
                    on_retry=self._count_retry)
            except Exception as e:              # noqa: BLE001 — to futures
                for ticket, r in live:
                    self.engine.discard(ticket)
                    self._finish(r, exc=e)
                continue
            self._stage("dispatch", time.perf_counter() - t0)
            with self._lock:
                self._counters["dispatches"] += 1
            for ticket, req in live:
                t1 = time.perf_counter()
                try:
                    out = self.engine.result(ticket)
                except Exception as e:          # noqa: BLE001 — to future
                    self._finish(req, exc=e)
                    continue
                self._stage("readback", time.perf_counter() - t1)
                if self._expired(req):
                    # read-only: the late result is dropped, not delivered
                    self._finish(req, exc=self._deadline_exc(req))
                else:
                    self._finish(req, value=out)

    # --------------------------------------------------------------- stats --
    def stats(self) -> dict:
        """Snapshot of scheduler counters, stage timing, and resilience state.

        Returns:
            A JSON-serializable dict: request ``counters`` (per kind, plus
            ``shed``/``done``/``errors``/``cancelled``/``dispatches``/
            ``coalesced_updates``/``retries``/``deadline_exceeded``/
            ``heals``/``heal_failures``/``watchdog_trips``), current
            ``depth`` and per-tenant ``inflight``, ``buckets_waiting``,
            per-``stages`` timing (``count``/``seconds``/``max_seconds``
            for queue wait, operand build, device dispatch, readback,
            open, repair, query, heal), per-site ``resilience`` ladder
            state (current rung, failures, demotions, promotions, probes),
            ``quarantined`` handle ids, ``wedged`` (watchdog diagnostics
            or ``None``), and the engine's own counters under ``engine``.
        """
        with self._lock:
            snap = {
                "counters": dict(self._counters),
                "depth": self._depth,
                "inflight": dict(self._inflight),
                "buckets_waiting": {
                    str(tuple(k)): len(v) for k, v in self._buckets.items()},
                "stages": {k: dict(v) for k, v in self._stages.items()},
                "quarantined": sorted(self._quarantined),
                "wedged": self._wedged,
            }
        snap["resilience"] = {site: ladder.snapshot()
                              for site, ladder in self._ladders.items()}
        eng = {k: (len(v) if isinstance(v, set) else v)
               for k, v in self.engine.stats.items()}
        snap["engine"] = eng
        return snap
