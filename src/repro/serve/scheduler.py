"""Async continuous-batching truss serving — the event-loop scheduler.

``TrussEngine`` is a synchronous ticket queue: ``submit``/``open``/
``update``/``hierarchy`` all execute on the caller's thread, and nothing
coalesces mixed traffic into device dispatches.  This module puts the
LLM-serving shape on top of it (DESIGN.md §12): requests are admitted
asynchronously and return ``concurrent.futures.Future``s immediately, a
single scheduler thread runs a continuous-batching tick loop, and
compatible work coalesces per tick —

  * **decompositions** (``submit_async``) of one pow2 size class merge into
    one vmapped ``_batched_truss_dev`` dispatch (the engine's bucket
    machinery), released either when the bucket reaches ``max_batch`` or
    when its oldest request has waited ``max_delay_ms`` — the classic
    latency-vs-batch-fullness policy;
  * **handle updates** (``update_async``) queued against one handle merge
    set-wise into a single :class:`~repro.core.truss_inc.IncrementalTruss`
    repair (``compose_update_batches``: n churn batches, one
    affected-region re-peel), bitwise-identical to applying them one at a
    time;
  * **queries** (``query_async``/``communities_async``) serve from the
    handle's maintained trussness and cached hierarchy index, ordered FIFO
    per handle against that handle's updates, so every query observes
    exactly the prefix of updates admitted before it.

Admission control sheds load with a typed :class:`Overloaded` error (never
by silent queueing): a global queue-depth bound (``max_queue``) plus a
per-tenant in-flight cap (``max_inflight``).  Per-stage timing — queue
wait, operand build, device dispatch, result readback, repair, query — is
accumulated and exposed via :meth:`TrussScheduler.stats`.

Parity: the scheduler adds *no* numeric path of its own.  Async results
are bitwise-equal to the synchronous engine's because every dispatch is an
engine call (``submit``+``flush``+``result``, ``update_many``, handle
queries) and the only reordering it ever performs is across independent
requests — per-handle order is FIFO and update coalescing composes
set-wise exactly (DESIGN.md §12 gives the argument;
``benchmarks/serve_bench.py`` gates it in CI).

Usage::

    from repro.serve import TrussScheduler

    with TrussScheduler(max_batch=16, max_delay_ms=2.0) as sched:
        f1 = sched.submit_async(edges_a)          # Future[np.ndarray]
        f2 = sched.open_async(edges_b)            # Future[TrussHandle]
        h = f2.result()
        f3 = sched.update_async(h, add_edges=new_rows)
        f4 = sched.query_async(h, some_rows)
        print(f1.result(), f3.result().mode, f4.result())
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

from repro.serve.truss_engine import TrussEngine, TrussHandle

_KINDS = ("submit", "open", "update", "query", "communities")


class Overloaded(RuntimeError):
    """Request shed by admission control.

    Raised synchronously by the ``*_async`` entry points when the global
    queue depth reaches ``max_queue`` or the calling tenant already has
    ``max_inflight`` requests in flight.  Shedding at admission (instead of
    queueing unboundedly) keeps tail latency bounded under overload; the
    caller owns the retry policy.
    """


@dataclasses.dataclass
class _Request:
    """One admitted request, queued between admission and completion."""

    kind: str                      # one of _KINDS
    tenant: str
    future: Future
    t_enq: float                   # perf_counter at admission
    edges: np.ndarray | None = None        # submit/open/query payload
    handle: TrussHandle | None = None      # update/query/communities target
    add: np.ndarray | None = None          # update payload
    remove: np.ndarray | None = None
    k: int = 0                             # communities level
    local_frac: float = 0.25               # open policy


class TrussScheduler:
    """Event-loop continuous-batching scheduler over a :class:`TrussEngine`.

    One background thread owns the engine; callers interact only through
    the ``*_async`` methods, each returning a ``concurrent.futures.Future``
    (engine errors — validation, oversized graphs, closed handles —
    surface as that future's exception; admission errors raise
    :class:`Overloaded` synchronously).

    Args:
        engine: the engine to serve; ``None`` builds one from
            ``engine_kwargs`` (with ``max_pending`` raised so the engine's
            own auto-flush never preempts the dispatch policy).  Once
            wrapped, the engine must not be driven concurrently from other
            threads.
        max_batch: dispatch a decomposition bucket as soon as it holds this
            many requests.
        max_delay_ms: dispatch a non-empty bucket once its oldest request
            has waited this long, even if not full (the latency bound; 0
            dispatches every tick).
        max_queue: global admitted-but-unfinished request bound; beyond it
            admissions shed with :class:`Overloaded`.
        max_inflight: per-tenant in-flight bound (same shedding).
        start: start the scheduler thread immediately; ``False`` leaves
            requests queued until :meth:`start` (tests use this to stage
            traffic deterministically).
        **engine_kwargs: forwarded to :class:`TrussEngine` when ``engine``
            is ``None`` (``mode``, ``support_mode``, ``table_mode``, …).

    Raises:
        ValueError: non-positive ``max_batch``/``max_queue``/
            ``max_inflight`` or negative ``max_delay_ms``.
    """

    def __init__(self, engine: TrussEngine | None = None, *,
                 max_batch: int = 16, max_delay_ms: float = 2.0,
                 max_queue: int = 256, max_inflight: int = 64,
                 start: bool = True, **engine_kwargs):
        if max_batch < 1:
            raise ValueError("max_batch must be positive")
        if max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        if max_queue < 1:
            raise ValueError("max_queue must be positive")
        if max_inflight < 1:
            raise ValueError("max_inflight must be positive")
        if engine is None:
            engine_kwargs.setdefault("max_pending", 4 * max_batch + max_queue)
            engine = TrussEngine(**engine_kwargs)
        elif engine_kwargs:
            raise ValueError("pass engine_kwargs only without an engine")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay_ms) / 1e3
        self.max_queue = int(max_queue)
        self.max_inflight = int(max_inflight)

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._inbox: deque[_Request] = deque()
        #: bucket key -> [(ticket, request)] awaiting batched dispatch
        self._buckets: dict[object, list[tuple[int, _Request]]] = {}
        #: handle id -> FIFO of update/query/communities requests
        self._hqueues: dict[int, deque[_Request]] = {}
        self._depth = 0                    # admitted, not yet finished
        self._inflight: dict[str, int] = {}
        self._closed = False
        self._drain = True
        self._counters = {k: 0 for k in _KINDS}
        self._counters.update(shed=0, done=0, errors=0, cancelled=0,
                              dispatches=0, coalesced_updates=0)
        self._stages = {k: {"count": 0, "seconds": 0.0, "max_seconds": 0.0}
                        for k in ("queue_wait", "build", "dispatch",
                                  "readback", "open", "repair", "query")}
        self._thread: threading.Thread | None = None
        if start:
            self.start()

    # ------------------------------------------------------------ lifecycle --
    def start(self) -> None:
        """Start the scheduler thread (idempotent)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self._thread is not None:
                return
            self._thread = threading.Thread(
                target=self._loop, name="truss-scheduler", daemon=True)
            self._thread.start()

    def close(self, *, drain: bool = True) -> None:
        """Stop the scheduler.

        Args:
            drain: ``True`` dispatches everything already admitted before
                stopping (their futures complete); ``False`` cancels queued
                requests (their futures report cancelled).
        """
        with self._work:
            if self._closed and self._thread is None:
                return
            self._closed = True
            self._drain = drain
            self._work.notify_all()
            t = self._thread
        if t is not None:
            t.join()
        with self._lock:
            self._thread = None

    def __enter__(self):
        """Context manager: returns self (thread already running)."""
        self.start()
        return self

    def __exit__(self, *exc):
        """Context manager exit: drain and stop the scheduler thread."""
        self.close(drain=True)
        return False

    # ------------------------------------------------------------ admission --
    def _admit(self, req: _Request) -> Future:
        with self._work:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self._depth >= self.max_queue:
                self._counters["shed"] += 1
                raise Overloaded(
                    f"queue depth {self._depth} at max_queue="
                    f"{self.max_queue}: request shed; retry with backoff "
                    f"or raise max_queue")
            if self._inflight.get(req.tenant, 0) >= self.max_inflight:
                self._counters["shed"] += 1
                raise Overloaded(
                    f"tenant {req.tenant!r} has "
                    f"{self._inflight[req.tenant]} requests in flight "
                    f"(max_inflight={self.max_inflight}): request shed")
            self._depth += 1
            self._inflight[req.tenant] = \
                self._inflight.get(req.tenant, 0) + 1
            self._counters[req.kind] += 1
            self._inbox.append(req)
            self._work.notify()
        return req.future

    @staticmethod
    def _check_handle(handle) -> TrussHandle:
        if not isinstance(handle, TrussHandle):
            raise TypeError(
                f"expected a TrussHandle (from open_async), got "
                f"{type(handle).__name__}; the scheduler does not promote "
                f"tickets — open the graph instead")
        if handle.closed:
            raise ValueError(f"handle {handle.hid} is closed")
        return handle

    def submit_async(self, edges, *, tenant: str = "default") -> Future:
        """Queue one decomposition; the future resolves to its trussness.

        Args:
            edges: ``(k, 2)`` integer edge array (``TrussEngine.submit``
                validation applies — on failure the *future* carries the
                ValueError).
            tenant: admission-control accounting key.

        Returns:
            ``Future[np.ndarray]`` — trussness aligned to the input rows,
            bitwise-equal to ``TrussEngine.submit``/``result``.

        Raises:
            Overloaded: shed by queue-depth or per-tenant admission control.
            RuntimeError: the scheduler is closed.
        """
        return self._admit(_Request(
            kind="submit", tenant=tenant, future=Future(),
            t_enq=time.perf_counter(), edges=np.asarray(edges)))

    def open_async(self, edges, *, local_frac: float = 0.25,
                   tenant: str = "default") -> Future:
        """Queue a persistent-handle open (full decomposition).

        Args:
            edges: ``(k, 2)`` integer edge array.
            local_frac: the handle's local-repair fallback threshold.
            tenant: admission-control accounting key.

        Returns:
            ``Future[TrussHandle]`` — pass the handle to ``update_async``/
            ``query_async``/``communities_async``.

        Raises:
            Overloaded: shed by admission control.
            RuntimeError: the scheduler is closed.
        """
        return self._admit(_Request(
            kind="open", tenant=tenant, future=Future(),
            t_enq=time.perf_counter(), edges=np.asarray(edges),
            local_frac=local_frac))

    def update_async(self, handle: TrussHandle, *, add_edges=None,
                     remove_edges=None, tenant: str = "default") -> Future:
        """Queue one insert/delete batch against a handle.

        Consecutive updates queued against the same handle (with no query
        between them) coalesce into a single composed repair; each of their
        futures then carries the same :class:`UpdateStats` with
        ``coalesced`` set to the merge width.

        Args:
            handle: an open handle from ``open_async`` (or
                ``TrussEngine.open``).
            add_edges: edges to insert (``None`` for none).
            remove_edges: edges to delete.
            tenant: admission-control accounting key.

        Returns:
            ``Future[UpdateStats]`` for the (possibly coalesced) repair.

        Raises:
            Overloaded: shed by admission control.
            TypeError: ``handle`` is not a :class:`TrussHandle`.
            ValueError: the handle is already closed.
            RuntimeError: the scheduler is closed.
        """
        return self._admit(_Request(
            kind="update", tenant=tenant, future=Future(),
            t_enq=time.perf_counter(), handle=self._check_handle(handle),
            add=add_edges, remove=remove_edges))

    def query_async(self, handle: TrussHandle, edges, *,
                    tenant: str = "default") -> Future:
        """Queue a trussness query; FIFO-ordered against the handle's updates.

        Args:
            handle: an open handle.
            edges: ``(k, 2)`` rows to look up (endpoint order/dupes OK).
            tenant: admission-control accounting key.

        Returns:
            ``Future[np.ndarray]`` — per-row trussness, observing exactly
            the updates admitted on this handle before this query.

        Raises:
            Overloaded: shed by admission control.
            TypeError: ``handle`` is not a :class:`TrussHandle`.
            ValueError: the handle is already closed.
            RuntimeError: the scheduler is closed.
        """
        return self._admit(_Request(
            kind="query", tenant=tenant, future=Future(),
            t_enq=time.perf_counter(), handle=self._check_handle(handle),
            edges=np.asarray(edges)))

    def communities_async(self, handle: TrussHandle, k: int, *,
                          tenant: str = "default") -> Future:
        """Queue a k-truss community listing against the cached index.

        Args:
            handle: an open handle.
            k: community level (see ``TrussHandle.communities``).
            tenant: admission-control accounting key.

        Returns:
            ``Future[list[np.ndarray]]`` — every level-``k`` community as a
            ``(c, 2)`` endpoint array, served from the handle's lazily
            built, update-surviving hierarchy index.

        Raises:
            Overloaded: shed by admission control.
            TypeError: ``handle`` is not a :class:`TrussHandle`.
            ValueError: the handle is already closed.
            RuntimeError: the scheduler is closed.
        """
        return self._admit(_Request(
            kind="communities", tenant=tenant, future=Future(),
            t_enq=time.perf_counter(), handle=self._check_handle(handle),
            k=int(k)))

    # ------------------------------------------------------------- the loop --
    def _loop(self) -> None:
        while True:
            with self._work:
                if not self._inbox and not self._closed:
                    due = self._seconds_to_deadline()
                    if due is None or due > 0:
                        self._work.wait(timeout=due)
                batch = list(self._inbox)
                self._inbox.clear()
                closing = self._closed
                drain = self._drain
            if closing and not drain:
                self._cancel_all(batch)
                return
            self._route(batch)
            self._service_handles()
            self._dispatch_buckets(force=closing)
            with self._lock:
                if (self._closed and not self._inbox and not self._buckets
                        and not self._hqueues):
                    return

    def _seconds_to_deadline(self):  # trusslint: holds[_lock]
        """Time until the next bucket must dispatch; None when no bucket waits.

        The deadline of a bucket is ``oldest.t_enq + max_delay``; a bucket
        at ``max_batch`` is due immediately.  Called under the lock.
        """
        if not self._buckets:
            return None
        now = time.perf_counter()
        due = None
        for entries in self._buckets.values():
            if len(entries) >= self.max_batch:
                return 0.0
            oldest = entries[0][1].t_enq
            d = max(0.0, oldest + self.max_delay - now)
            due = d if due is None else min(due, d)
        return due

    def _finish(self, req: _Request, value=None, exc=None) -> None:
        with self._lock:
            self._depth -= 1
            left = self._inflight.get(req.tenant, 1) - 1
            if left <= 0:
                self._inflight.pop(req.tenant, None)
            else:
                self._inflight[req.tenant] = left
            self._counters["done"] += 1
            if exc is not None:
                self._counters["errors"] += 1
        if exc is not None:
            req.future.set_exception(exc)
        else:
            req.future.set_result(value)

    def _cancel_all(self, batch) -> None:
        """close(drain=False): cancel everything queued, nothing dispatches.

        The dispatch structures are guarded state (`stats()` can race this
        teardown from another thread), so they are snapshotted-and-swapped
        under the lock; the engine discards then run outside it.
        """
        pending = list(batch)
        with self._lock:
            buckets, self._buckets = self._buckets, {}
            hqueues, self._hqueues = self._hqueues, {}
        for entries in buckets.values():
            for ticket, r in entries:
                self.engine.discard(ticket)
                pending.append(r)
        for q in hqueues.values():
            pending.extend(q)
        for req in pending:
            with self._lock:
                self._depth -= 1
                self._counters["cancelled"] += 1
            req.future.cancel()
        with self._lock:
            self._inflight.clear()

    def _stage(self, name: str, seconds: float) -> None:
        with self._lock:
            s = self._stages[name]
            s["count"] += 1
            s["seconds"] += seconds
            s["max_seconds"] = max(s["max_seconds"], seconds)

    # ------------------------------------------------------------- routing --
    def _route(self, batch) -> None:
        """Admit a tick's inbox into the dispatch structures (build stage)."""
        for req in batch:
            now = time.perf_counter()
            self._stage("queue_wait", now - req.t_enq)
            if req.kind == "submit":
                try:
                    t0 = time.perf_counter()
                    ticket = self.engine.submit(req.edges)
                    self._stage("build", time.perf_counter() - t0)
                    key = self.engine.bucket_of(ticket)
                except Exception as e:          # noqa: BLE001 — to future
                    self._finish(req, exc=e)
                    continue
                if key is None:
                    # resolved at submit (empty graph / engine auto-flush)
                    self._finish(req, value=self.engine.result(ticket))
                else:
                    with self._lock:
                        self._buckets.setdefault(key, []).append(
                            (ticket, req))
            elif req.kind == "open":
                try:
                    t0 = time.perf_counter()
                    h = self.engine.open(req.edges,
                                         local_frac=req.local_frac)
                    self._stage("open", time.perf_counter() - t0)
                except Exception as e:          # noqa: BLE001 — to future
                    self._finish(req, exc=e)
                    continue
                self._finish(req, value=h)
            else:                               # update / query / communities
                with self._lock:
                    self._hqueues.setdefault(
                        req.handle.hid, deque()).append(req)

    # ------------------------------------------------- handle-op servicing --
    def _service_handles(self) -> None:
        """Drain every handle queue FIFO, coalescing update runs (§12).

        Per handle, consecutive updates (up to the next query) compose into
        one ``engine.update_many`` repair; queries then run against exactly
        the state their admission order promises.
        """
        with self._lock:
            if not self._hqueues:
                return
            queues, self._hqueues = self._hqueues, {}
        for q in queues.values():
            while q:
                run = []
                while q and q[0].kind == "update":
                    run.append(q.popleft())
                if run:
                    self._run_update(run)
                if q:
                    self._run_query(q.popleft())

    def _run_update(self, run) -> None:
        handle = run[0].handle
        t0 = time.perf_counter()
        try:
            st = self.engine.update_many(
                handle, [(r.add, r.remove) for r in run])
        except Exception as e:                  # noqa: BLE001 — to futures
            for r in run:
                self._finish(r, exc=e)
            return
        self._stage("repair", time.perf_counter() - t0)
        with self._lock:
            self._counters["dispatches"] += 1
            self._counters["coalesced_updates"] += len(run) - 1
        for r in run:
            self._finish(r, value=st)

    def _run_query(self, req: _Request) -> None:
        t0 = time.perf_counter()
        try:
            if req.kind == "query":
                out = req.handle.query(req.edges)
            else:
                out = req.handle.communities(req.k)
        except Exception as e:                  # noqa: BLE001 — to future
            self._finish(req, exc=e)
            return
        self._stage("query", time.perf_counter() - t0)
        self._finish(req, value=out)

    # ------------------------------------------------------ bucket dispatch --
    def _dispatch_buckets(self, *, force: bool = False) -> None:
        """Flush every due bucket: full, past deadline, or forced (drain)."""
        now = time.perf_counter()
        with self._lock:
            due = []
            for key in list(self._buckets):
                entries = self._buckets[key]
                oldest = entries[0][1].t_enq
                if (force or len(entries) >= self.max_batch
                        or now - oldest >= self.max_delay):
                    due.append((key, entries))
                    del self._buckets[key]
        for key, entries in due:
            t0 = time.perf_counter()
            try:
                self.engine.flush(only=[key])
            except Exception as e:              # noqa: BLE001 — to futures
                for ticket, r in entries:
                    self.engine.discard(ticket)
                    self._finish(r, exc=e)
                continue
            self._stage("dispatch", time.perf_counter() - t0)
            with self._lock:
                self._counters["dispatches"] += 1
            for ticket, req in entries:
                t1 = time.perf_counter()
                try:
                    out = self.engine.result(ticket)
                except Exception as e:          # noqa: BLE001 — to future
                    self._finish(req, exc=e)
                    continue
                self._stage("readback", time.perf_counter() - t1)
                self._finish(req, value=out)

    # --------------------------------------------------------------- stats --
    def stats(self) -> dict:
        """Snapshot of scheduler counters and per-stage timing.

        Returns:
            A JSON-serializable dict: request ``counters`` (per kind, plus
            ``shed``/``done``/``errors``/``dispatches``/
            ``coalesced_updates``), current ``depth`` and per-tenant
            ``inflight``, ``buckets_waiting``, per-``stages`` timing
            (``count``/``seconds``/``max_seconds`` for queue wait, operand
            build, device dispatch, readback, open, repair, query), and the
            engine's own counters under ``engine``.
        """
        with self._lock:
            snap = {
                "counters": dict(self._counters),
                "depth": self._depth,
                "inflight": dict(self._inflight),
                "buckets_waiting": {
                    str(tuple(k)): len(v) for k, v in self._buckets.items()},
                "stages": {k: dict(v) for k, v in self._stages.items()},
            }
        eng = {k: (len(v) if isinstance(v, set) else v)
               for k, v in self.engine.stats.items()}
        snap["engine"] = eng
        return snap
