"""Serving layer: batched truss engine and the async scheduler.

The pretrain-era LM serving scaffolding (``repro.serve.engine``) is
quarantined out of the live import path (trusslint U002, DESIGN.md
§14); import it directly if you need it.
"""

from repro.serve.scheduler import Overloaded, TrussScheduler
from repro.serve.truss_engine import TrussEngine, TrussHandle, truss_batched

__all__ = ["Overloaded", "TrussScheduler",
           "TrussEngine", "TrussHandle", "truss_batched"]
