"""Serving layer: batched truss engine, async scheduler, LM scaffolding."""

from repro.serve.engine import make_prefill_step, make_decode_step
from repro.serve.scheduler import Overloaded, TrussScheduler
from repro.serve.truss_engine import TrussEngine, TrussHandle, truss_batched

__all__ = ["make_prefill_step", "make_decode_step",
           "Overloaded", "TrussScheduler",
           "TrussEngine", "TrussHandle", "truss_batched"]
