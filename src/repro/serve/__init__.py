"""Serving layer: batched truss engine and the async scheduler.

The pretrain-era LM serving scaffolding (``repro.serve.engine``) is
quarantined out of the live import path (trusslint U002, DESIGN.md
§14); import it directly if you need it.
"""

from repro.serve.resilience import (DeadlineExceeded, Ladder, RetryPolicy,
                                    Wedged)
from repro.serve.scheduler import Cancelled, Overloaded, TrussScheduler
from repro.serve.truss_engine import TrussEngine, TrussHandle, truss_batched

__all__ = ["Cancelled", "DeadlineExceeded", "Ladder", "Overloaded",
           "RetryPolicy", "TrussEngine", "TrussHandle", "TrussScheduler",
           "Wedged", "truss_batched"]
