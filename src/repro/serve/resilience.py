"""Retry, deadline, and executor-degradation machinery (DESIGN.md §15).

The scheduler routes every expensive dispatch — engine flush, handle
update (region re-peel + support rebuild), open, community query —
through :func:`run_with_resilience`, which layers three recoveries on
top of the engine's existing exception safety:

- **bounded retry** with exponential backoff and deterministic jitter
  (:class:`RetryPolicy`) for *transient* failures (injected faults,
  runtime/dispatch errors).  Programming errors (``ValueError`` etc.),
  :class:`~repro.core.truss_inc.IntegrityError`, and
  :class:`DeadlineExceeded` are never retried;
- a per-site **degradation ladder** (:class:`Ladder`): consecutive
  failures demote the site to a slower but bitwise-identical executor
  rung (pallas → jnp → host-numpy); after enough consecutive successes
  at a demoted rung the ladder *probes* the faster rung on live
  traffic — probe failures fall back silently without charging the
  request — and re-promotes after consecutive probe successes;
- **deadline enforcement**: an absolute deadline aborts the retry loop
  (and any pending backoff sleep) with a typed :class:`DeadlineExceeded`.

Every rung pairing in the ladders is one of the repo's parity-gated
executor axes, so degradation never changes results — only latency.
"""

from __future__ import annotations

import contextlib
import time
import zlib
from dataclasses import dataclass

from repro.core.truss_inc import IntegrityError

#: exception types never retried: caller bugs, integrity violations
#: (healed at a higher layer), and deadline aborts
PERMANENT_ERRORS = (ValueError, TypeError, KeyError, IntegrityError)


class DeadlineExceeded(RuntimeError):
    """A request missed its deadline before (or while) being served.

    Attributes ``kind`` (request kind, when known) and ``deadline_ms``
    (the budget that was exceeded) support caller-side triage.
    """

    def __init__(self, message: str, *, kind: str | None = None, deadline_ms: float | None = None):
        super().__init__(message)
        self.kind = kind
        self.deadline_ms = deadline_ms


class Wedged(RuntimeError):
    """The scheduler tick loop stopped making progress (watchdog trip).

    The message carries diagnostics: the stalled duration, a snapshot of
    the scheduler counters, and the scheduler thread's current stack.
    """


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    ``backoff(site, attempt)`` returns ``base_delay_s * 2**(attempt-1)``
    scaled by a jitter factor in ``[1, 2)`` derived from
    ``crc32(seed:site:attempt)`` — deterministic across runs, decorrelated
    across sites — and clamped to ``max_delay_s``.
    """

    max_retries: int = 2
    base_delay_s: float = 0.002
    max_delay_s: float = 0.050
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.base_delay_s < 0 or self.max_delay_s < self.base_delay_s:
            raise ValueError("need 0 <= base_delay_s <= max_delay_s")

    def backoff(self, site: str, attempt: int) -> float:
        """Backoff delay in seconds before retry number ``attempt`` (1-based)."""
        frac = zlib.crc32(f"{self.seed}:{site}:{attempt}".encode()) / 2**32
        return min(self.max_delay_s, self.base_delay_s * 2 ** (attempt - 1) * (1.0 + frac))


class Ladder:
    """Health-scored executor degradation ladder for one dispatch site.

    ``rungs`` is ordered fastest-first; position 0 is the configured
    executor.  ``demote_after`` consecutive failures move one rung down.
    After ``probe_after`` consecutive successes at a demoted rung the
    ladder requests a *probe*: the next dispatch runs one rung up.  After
    ``promote_after`` consecutive probe successes the ladder moves back
    up; a probe failure resets the probe streak and stays demoted.
    """

    def __init__(
        self,
        rungs: tuple,
        *,
        demote_after: int = 2,
        probe_after: int = 3,
        promote_after: int = 2,
    ):
        if not rungs:
            raise ValueError("ladder needs at least one rung")
        if min(demote_after, probe_after, promote_after) < 1:
            raise ValueError("demote_after/probe_after/promote_after must be >= 1")
        self.rungs = tuple(rungs)
        self.pos = 0
        self.demote_after = demote_after
        self.probe_after = probe_after
        self.promote_after = promote_after
        self._fails = 0  # consecutive failures at the current rung
        self._streak = 0  # consecutive successes at the current rung
        self._probe_streak = 0  # consecutive successful probes of the rung above
        self.failures = 0
        self.demotions = 0
        self.promotions = 0
        self.probes = 0
        self.probe_failures = 0

    def current(self):
        """The rung dispatches should run at (ignoring probes)."""
        return self.rungs[self.pos]

    def should_probe(self) -> bool:
        """True when the next dispatch should try the rung above."""
        return self.pos > 0 and self._streak >= self.probe_after

    def probe_rung(self):
        """The rung a probe dispatch runs at (one above current)."""
        return self.rungs[self.pos - 1]

    def record_success(self) -> None:
        """A dispatch at the current rung completed."""
        self._fails = 0
        self._streak += 1

    def record_failure(self) -> None:
        """A dispatch at the current rung failed; demote when unhealthy."""
        self.failures += 1
        self._streak = 0
        self._fails += 1
        if self._fails >= self.demote_after and self.pos < len(self.rungs) - 1:
            self.pos += 1
            self.demotions += 1
            self._fails = 0
            self._probe_streak = 0

    def record_probe_success(self) -> None:
        """A probe of the rung above succeeded; promote on a full streak."""
        self.probes += 1
        self._probe_streak += 1
        if self._probe_streak >= self.promote_after:
            self.pos -= 1
            self.promotions += 1
            self._fails = 0
            self._streak = 0
            self._probe_streak = 0

    def record_probe_failure(self) -> None:
        """A probe of the rung above failed; stay demoted, reset streaks."""
        self.probes += 1
        self.probe_failures += 1
        self._probe_streak = 0
        self._streak = 0

    def snapshot(self) -> dict:
        """Counters + current rung, for ``TrussScheduler.stats()``."""
        return {
            "rung": self.rungs[self.pos],
            "rungs": list(self.rungs),
            "failures": self.failures,
            "demotions": self.demotions,
            "promotions": self.promotions,
            "probes": self.probes,
            "probe_failures": self.probe_failures,
        }


def is_transient(exc: BaseException) -> bool:
    """Whether ``exc`` is worth retrying (possibly at a lower rung)."""
    return not isinstance(exc, PERMANENT_ERRORS) and not isinstance(exc, DeadlineExceeded)


@contextlib.contextmanager
def override_attrs(obj, **attrs):
    """Temporarily set attributes on ``obj``, restoring on exit.

    The mechanism by which ladder rungs are applied: executor-mode
    attributes (``mode``, ``support_mode``, ``table_mode``,
    ``host_peel_max``) are overridden for the duration of one dispatch.
    """
    saved = {k: getattr(obj, k) for k in attrs}
    for k, v in attrs.items():
        setattr(obj, k, v)
    try:
        yield obj
    finally:
        for k, v in saved.items():
            setattr(obj, k, v)


def run_with_resilience(
    call,
    *,
    ladders: dict,
    primary: str,
    policy: RetryPolicy,
    deadline: float | None = None,
    kind: str | None = None,
    on_retry=None,
):
    """Run ``call(rungs)`` under retry + ladder + deadline policy.

    ``call`` receives ``{site: rung}`` built from each ladder's current
    (or probe) rung and must dispatch accordingly.  Transient failures
    are charged to the ladder named by the exception's ``site`` attribute
    (falling back to ``primary``), retried up to ``policy.max_retries``
    times with backoff; probe failures retry immediately at the safe rung
    without consuming the request's retry budget.  ``deadline`` is an
    absolute ``time.perf_counter()`` timestamp; crossing it — including
    via a pending backoff sleep — raises :class:`DeadlineExceeded`.
    ``on_retry`` is called once per charged retry (scheduler counters).
    """
    attempt = 0
    while True:
        if deadline is not None and time.perf_counter() >= deadline:
            raise DeadlineExceeded(f"deadline exceeded before {primary} dispatch", kind=kind)
        probe_site = None
        rungs = {}
        for site, ladder in ladders.items():
            if probe_site is None and ladder.should_probe():
                probe_site = site
                rungs[site] = ladder.probe_rung()
            else:
                rungs[site] = ladder.current()
        try:
            out = call(rungs)
        except Exception as e:
            if probe_site is not None:
                # probes ride live traffic but must not fail it: fall back
                # to the demoted rung immediately, uncharged
                ladders[probe_site].record_probe_failure()
                continue
            if not is_transient(e):
                raise
            site = getattr(e, "site", None)
            ladders.get(site, ladders[primary]).record_failure()
            attempt += 1
            if attempt > policy.max_retries:
                raise
            if on_retry is not None:
                on_retry()
            delay = policy.backoff(site or primary, attempt)
            if deadline is not None and time.perf_counter() + delay >= deadline:
                raise DeadlineExceeded(
                    f"deadline exceeded during {primary} retry backoff", kind=kind
                ) from e
            time.sleep(delay)
            continue
        for site, ladder in ladders.items():
            if site == probe_site:
                ladder.record_probe_success()
            else:
                ladder.record_success()
        return out
