"""Serving: prefill and decode steps with sharded KV/SSM caches.

The decode shapes of the assignment (decode_32k, long_500k) lower
``decode_step`` — one new token against a pre-filled cache. Sampling is greedy
or temperature-categorical; batching is static (the batch dim is the data-
sharded axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import ModelConfig, forward, init_cache
from repro.models import sharding as shard_rules


def prefill(params, cfg: ModelConfig, batch: dict, cache):
    """Run the prompt through the model, writing the cache; returns
    (last-token logits, cache)."""
    logits, _, new_cache = forward(params, cfg, batch, cache=cache)
    return logits[:, -1], new_cache


def decode(params, cfg: ModelConfig, tokens, cache, *, positions=None,
           temperature: float = 0.0, key=None):
    """One-token decode + sampling. tokens: (B, 1) int32 (or embeds)."""
    if cfg.input_is_embeds:
        batch = {"embeds": tokens}
    else:
        batch = {"tokens": tokens}
    if positions is not None:
        batch["positions"] = positions
    logits, _, new_cache = forward(params, cfg, batch, cache=cache)
    last = logits[:, -1].astype(jnp.float32)
    if temperature > 0.0 and key is not None:
        nxt = jax.random.categorical(key, last / temperature, axis=-1)
    else:
        nxt = jnp.argmax(last, axis=-1)
    return nxt.astype(jnp.int32), last, new_cache


def make_prefill_step(cfg: ModelConfig, mesh):
    """jitted prefill step for ``cfg`` on ``mesh``."""
    fn = functools.partial(prefill, cfg=cfg)
    return jax.jit(fn)


def make_decode_step(cfg: ModelConfig, mesh, *, seq_shard: bool = False):
    """jitted decode with explicit cache shardings (seq_shard for long ctx)."""
    fn = functools.partial(decode, cfg=cfg)
    return jax.jit(fn)


def cache_shardings(cfg: ModelConfig, mesh, batch: int, max_seq: int,
                    *, seq_shard: bool = False):
    """NamedShardings for a fresh decode cache (seq_shard for long ctx)."""
    shape = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))
    spec = shard_rules.cache_specs(cfg, shape, mesh.axis_names,
                                   seq_shard=seq_shard)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                        is_leaf=lambda x: isinstance(x, P))
