"""Fault-tolerance runtime pieces: heartbeat, straggler detection, retry loop.

On a real fleet these hooks drive the controller (restart a slow/dead host
from the last checkpoint); on this box the same machinery is exercised
end-to-end by tests and the examples with simulated failures.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable


class Heartbeat:
    """Writes {step, time} to a file every beat — the liveness signal a
    fleet controller (launch/run_elastic.sh) watches."""

    def __init__(self, path: str, interval_s: float = 5.0):
        self.path = path
        self.interval = interval_s
        self._last = 0.0
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)

    def beat(self, step: int, **extra) -> None:
        now = time.time()
        if now - self._last < self.interval:
            return
        self._last = now
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": now, **extra}, f)
        os.replace(tmp, self.path)


class StragglerMonitor:
    """EWMA step-time tracker: flags steps slower than ``k`` × the average.

    In multi-controller deployments every host reports; the controller
    compares across hosts and evicts persistent stragglers. Here we expose
    the per-host primitive plus its decision rule.
    """

    def __init__(self, alpha: float = 0.1, k: float = 3.0,
                 warmup_steps: int = 5):
        self.alpha = alpha
        self.k = k
        self.warmup = warmup_steps
        self.ewma: float | None = None
        self.n = 0
        self.flagged: list[tuple[int, float, float]] = []

    def observe(self, step: int, step_time_s: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.ewma is None:
            self.ewma = step_time_s
            return False
        is_straggler = (self.n > self.warmup
                        and step_time_s > self.k * self.ewma)
        if is_straggler:
            self.flagged.append((step, step_time_s, self.ewma))
        else:
            # don't poison the average with outliers
            self.ewma = (1 - self.alpha) * self.ewma \
                + self.alpha * step_time_s
        return is_straggler


def run_with_retries(step_fn: Callable[[int], None], *, start_step: int,
                     end_step: int, max_retries: int = 3,
                     on_retry: Callable[[int, Exception], int] | None = None):
    """Drives step_fn(step) with restart-on-failure semantics.

    ``on_retry(step, exc) -> resume_step`` is where the caller restores from
    the last checkpoint (see examples/train_lm.py); the loop then replays
    deterministically from there (data pipeline is step-keyed).
    """
    step = start_step
    retries = 0
    while step < end_step:
        try:
            step_fn(step)
            step += 1
            retries = 0
        except Exception as e:
            retries += 1
            if retries > max_retries or on_retry is None:
                raise
            step = on_retry(step, e)
