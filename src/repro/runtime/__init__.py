from repro.runtime.fault import StragglerMonitor, Heartbeat, run_with_retries

__all__ = ["StragglerMonitor", "Heartbeat", "run_with_retries"]
