"""Shared wedge-table machinery for the Pallas truss kernels.

Both hot-phase kernels walk the same flat data structure: a *wedge table* —
one row per (anchor edge, candidate adjacency slot) pair, with a probe range
``[lo, hi)`` into the CSR adjacency array ``N``.  The support kernel
(``kernels/support.py``) walks the oriented AM4 table, the peel kernel
(``kernels/peel.py``) the full-adjacency ProcessSubLevel table; the table
*math* is identical and used to be duplicated across the two kernels and
``core/pkt.py``.  This module is its single home:

  * **chunk layout** — tables are cut into fixed-size chunks, one per Pallas
    grid step; ``chunk_layout`` sanitizes a requested chunk size (clamped so
    that ``n_chunks >= 1`` always holds, including zero-entry tables) and
    ``pad_chunked`` pads the four table arrays to a whole number of chunks
    with inert sentinel rows (anchor ``m``, empty probe range ``lo == hi``);
  * **BlockSpec helpers** — ``chunk_spec`` stages one chunk per grid step,
    ``replicated_spec`` replicates a whole array (adjacency, edge state)
    into VMEM at every step;
  * **the search primitive** — ``ranged_searchsorted`` is the branch-free
    vectorized lower-bound binary search both phases use as their membership
    test, and ``probe`` fuses it with the candidate gather and hit predicate
    (``w ∈ N[lo:hi)``).

Everything here is pure jax/numpy so it can be imported from kernels and
from ``core/`` without cycles (``core.support`` re-exports
``ranged_searchsorted`` for its established call sites).
"""

from __future__ import annotations

import json
import os
import pathlib

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


#: adjacency padding value: larger than any vertex id, so padded slots can
#: never match a probe (shared by the batched engine and the local re-peel)
PAD_N = np.int32(1 << 30)


def interpret_default() -> bool:
    """Pallas interpret mode unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (>= 1)."""
    return 1 << max(0, int(x - 1).bit_length())


#: auto-chunk policy: aim for this many chunks per wedge table, so that the
#: chunk-skipping while_loop has skippable units even on small graphs …
AUTO_CHUNK_TARGET = 16
#: … clamped to this band (below: per-chunk dispatch overhead dominates;
#: above: a chunk's VMEM block outgrows the kernel budget)
AUTO_CHUNK_MIN = 1 << 7
AUTO_CHUNK_MAX = 1 << 14


#: tuned-chunk table location: ``benchmarks/hillclimb.py`` measures the best
#: chunk per pow2 table-size bucket and writes it here (override with the
#: env var for experiments); missing/invalid files fall back to the
#: recorded-defaults formula below
TUNED_CHUNKS_ENV = "TRUSS_TUNED_CHUNKS"
TUNED_CHUNKS_PATH = pathlib.Path(__file__).with_name("tuned_chunks.json")

_TUNED_CHUNKS: dict[int, int] | None | bool = False  # False = not loaded yet


def _load_tuned_chunks() -> dict[int, int] | None:
    """Parse the tuned-chunk table: {log2(pow2 table bucket): chunk}.

    Any failure (missing file, wrong format version, non-pow2 values)
    disables the table for the whole process — the formula fallback keeps
    ``auto_chunk`` total, so a stale or corrupt tuning file can never break
    a decomposition, only untune it.
    """
    path = os.environ.get(TUNED_CHUNKS_ENV) or TUNED_CHUNKS_PATH
    try:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("format") != 1:
            return None
        table = {}
        for bucket, chunk in doc["buckets"].items():
            b, c = int(bucket), int(chunk)
            if c < 1 or c & (c - 1):
                return None
            table[b] = c
        return table or None
    except (OSError, ValueError, KeyError, AttributeError, TypeError):
        return None


def reload_tuned_chunks() -> dict[int, int] | None:
    """Drop the cached tuned table and re-read it (test / autotuner hook)."""
    global _TUNED_CHUNKS
    _TUNED_CHUNKS = _load_tuned_chunks()
    return _TUNED_CHUNKS


def auto_chunk(size: int, *, target: int = AUTO_CHUNK_TARGET,
               lo: int = AUTO_CHUNK_MIN, hi: int = AUTO_CHUNK_MAX) -> int:
    """Derive a chunk size from the table size (used when none is requested).

    Consults the tuned-chunk table first: ``benchmarks/hillclimb.py`` sweeps
    chunk candidates per pow2 table-size bucket and records the winner in
    ``tuned_chunks.json``; a hit is clamped to ``[lo, hi]`` and returned.
    Buckets the autotuner never measured (and any load failure) fall back
    to the recorded-defaults formula: a power of two sized so the table
    splits into roughly ``target`` chunks, clamped to ``[lo, hi]``.  The
    old fixed ``1 << 14`` default made every table smaller than 16Ki
    entries a *single* chunk, so the work-efficient chunk-skipping executor
    scanned the whole table every sub-level while still paying the
    while_loop machinery — the chunked-slower-than-dense pathology
    BENCH_smoke.json showed on tiny graphs.  Large tables still get the
    VMEM-budget chunk ``hi``.
    """
    global _TUNED_CHUNKS
    size = max(1, int(size))
    if _TUNED_CHUNKS is False:
        _TUNED_CHUNKS = _load_tuned_chunks()
    if _TUNED_CHUNKS:
        bucket = next_pow2(size).bit_length() - 1
        tuned = _TUNED_CHUNKS.get(bucket)
        if tuned is not None:
            return int(min(hi, max(lo, tuned)))
    want = next_pow2(-(-size // max(1, int(target))))
    return int(min(hi, max(lo, want)))


def pow2_chunk(size_pad: int, chunk: int | None, *,
               size: int | None = None) -> int:
    """Chunk size for a pow2-padded table: a power of two dividing ``size_pad``.

    ``chunk=None`` applies the ``auto_chunk`` policy against the *real*
    table size (``size``, defaulting to ``size_pad``); an explicit chunk is
    rounded down to a power of two so it always divides the padded table.
    """
    if chunk is None:
        chunk = auto_chunk(size_pad if size is None else size)
    else:
        chunk = 1 << max(0, int(chunk).bit_length() - 1)
    return max(1, min(int(chunk), int(size_pad)))


def pad1(x: np.ndarray, size: int, fill) -> np.ndarray:
    """Right-pad a 1-D int array to ``size`` with ``fill`` (int32 out)."""
    out = np.full(size, fill, np.int32)
    out[: x.shape[0]] = x
    return out


def chunk_layout(size: int, chunk: int | None = None) -> tuple[int, int]:
    """Sanitize a requested chunk size against a table of ``size`` entries.

    Returns ``(chunk, n_chunks)`` with ``1 <= chunk`` and ``n_chunks >= 1``:
    a chunk larger than the table, zero, or negative is clamped; a zero-entry
    table yields one all-padding chunk of size 1 (callers that want to skip
    the kernel entirely for empty tables early-exit before this).
    ``chunk=None`` derives the size from the table via ``auto_chunk``.
    """
    size = max(1, int(size))
    if chunk is None:
        chunk = auto_chunk(size)
    chunk = max(1, min(int(chunk), size))
    return chunk, -(-size // chunk)


def pad_chunked(e1: np.ndarray, cand_slot: np.ndarray, lo: np.ndarray,
                hi: np.ndarray, *, m: int, chunk: int,
                n_chunks: int) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                        np.ndarray]:
    """Pad the four wedge-table arrays to ``n_chunks * chunk`` inert rows.

    Padding rows carry the anchor sentinel ``m`` and an empty probe range
    (``lo == hi == 0``), so they can never produce a hit and any scatter
    they feed lands on the absorbing slot ``m``.
    """
    nw = int(e1.shape[0])
    pad = n_chunks * chunk - nw
    assert pad >= 0, (nw, chunk, n_chunks)
    return (
        np.concatenate([e1, np.full(pad, m, np.int32)]).astype(np.int32),
        np.concatenate([cand_slot, np.zeros(pad, np.int32)]).astype(np.int32),
        np.concatenate([lo, np.zeros(pad, np.int32)]).astype(np.int32),
        np.concatenate([hi, np.zeros(pad, np.int32)]).astype(np.int32),
    )


def chunk_spec(chunk: int) -> pl.BlockSpec:
    """One table chunk per grid step."""
    return pl.BlockSpec((chunk,), lambda i: (i,))


def replicated_spec(size: int) -> pl.BlockSpec:
    """Whole array staged at every grid step (adjacency / edge state)."""
    return pl.BlockSpec((size,), lambda i: (0,))


def ranged_searchsorted(N: jnp.ndarray, w: jnp.ndarray, lo: jnp.ndarray,
                        hi: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Vectorized lower-bound binary search of w in sorted N[lo:hi).

    Returns the insertion index (== hi when all elements < w). ``iters`` must
    be >= ceil(log2(max(hi - lo) + 1)).
    """
    def body(_, state):
        lo_, hi_ = state
        mid = (lo_ + hi_) >> 1
        val = N[mid]
        go_right = val < w
        lo_ = jnp.where(go_right & (lo_ < hi_), mid + 1, lo_)
        hi_ = jnp.where((~go_right) & (lo_ < hi_), mid, hi_)
        return lo_, hi_

    lo_f, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo_f


def ranged_searchsorted_np(N: np.ndarray, w: np.ndarray, lo: np.ndarray,
                           hi: np.ndarray, iters: int) -> np.ndarray:
    """Host-numpy mirror of ``ranged_searchsorted`` (same algorithm, same
    bounds contract).  Used by the incremental-maintenance layer, whose
    per-update table shapes vary too much to amortize a jit trace."""
    lo_ = lo.astype(np.int64, copy=True)
    hi_ = hi.astype(np.int64, copy=True)
    top = max(N.shape[0] - 1, 0)
    for _ in range(iters):
        adv = lo_ < hi_
        mid = (lo_ + hi_) >> 1
        val = N[np.minimum(mid, top)]
        go_right = val < w
        lo_ = np.where(adv & go_right, mid + 1, lo_)
        hi_ = np.where(adv & ~go_right, mid, hi_)
    return lo_


def probe_np(N: np.ndarray, cand_slot: np.ndarray, lo: np.ndarray,
             hi: np.ndarray, *, iters: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-numpy mirror of ``probe``: (hit, safe) for w = N[cand_slot]."""
    if N.size == 0 or cand_slot.size == 0:
        z = np.zeros(cand_slot.shape[0], np.int64)
        return z.astype(bool), z
    w = N[cand_slot]
    idx = ranged_searchsorted_np(N, w, lo, hi, iters)
    safe = np.minimum(idx, N.shape[0] - 1)
    hit = (idx < hi) & (N[safe] == w)
    return hit, safe


def probe(N: jnp.ndarray, cand_slot: jnp.ndarray, lo: jnp.ndarray,
          hi: jnp.ndarray, *, iters: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused wedge membership test: is ``w = N[cand_slot]`` in ``N[lo:hi)``?

    Returns ``(hit, safe)`` where ``safe`` is the (clamped) index of the
    matching slot — valid as a gather index whenever ``hit`` is True, and a
    harmless in-bounds index otherwise.  This is the shared inner loop of
    both kernels and of every jnp executor in ``core/``.
    """
    w = N[cand_slot]
    idx = ranged_searchsorted(N, w, lo, hi, iters)
    safe = jnp.minimum(idx, N.shape[0] - 1)
    hit = (idx < hi) & (N[safe] == w)
    return hit, safe
