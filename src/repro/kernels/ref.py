"""Pure-jnp oracle for the Pallas intersect kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def intersect_ref(a: jnp.ndarray, b: jnp.ndarray):
    """Same contract as kernels.intersect.intersect_blocked (no blocking)."""
    eq = a[:, :, None] == b[:, None, :]
    hita = jnp.any(eq, axis=2)
    hitb = jnp.any(eq, axis=1)
    cnt = jnp.sum(hita.astype(jnp.int32), axis=1)
    return cnt, hita.astype(jnp.int32), hitb.astype(jnp.int32)
