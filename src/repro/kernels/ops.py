"""jit'd wrappers around the Pallas intersect kernel.

``compute_support_kernel`` is a drop-in replacement for
``repro.core.support.compute_support``: edges are bucketed by oriented-degree
class (power-of-two padding — the SPMD stand-in for OpenMP dynamic
scheduling), each bucket is intersected by the Pallas kernel, and support
increments are scattered through the Eid maps. Edges whose endpoints exceed
the largest bucket fall back to the ranged-binary-search path (skewed-tail
handling: the few huge-degree rows would waste VMEM padding).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from repro.core import support as support_mod
from repro.kernels.intersect import intersect_blocked
from repro.kernels.wedge_common import interpret_default as _interpret_default

_DEG_CLASSES = (8, 16, 32, 64, 128, 256)


def _block_rows_for(d: int) -> int:
    # keep the (BE, D, D) compare cube ≈ ≤ 16 MiB of VMEM traffic
    return int(max(8, min(1024, (1 << 22) // max(d * d, 1))))


def _gather_rows(N, Eid, start, length, D):
    """(E, D) padded rows of N and Eid: N[start[i] + j] for j < length[i]."""
    idx = start[:, None] + jnp.arange(D, dtype=jnp.int32)[None, :]
    mask = jnp.arange(D, dtype=jnp.int32)[None, :] < length[:, None]
    safe = jnp.minimum(idx, N.shape[0] - 1)
    rows = jnp.where(mask, N[safe], -1)
    eids = jnp.where(mask, Eid[safe], 0)
    return rows, eids, mask


def _bucket_support(N, Eid, u_start, u_len, v_start, v_len, e1, m, D,
                    interpret):
    """Support contributions of one degree-class bucket (jit-traceable)."""
    rows_a, eids_a, _ = _gather_rows(N, Eid, u_start, u_len, D)
    rows_b, eids_b, _ = _gather_rows(N, Eid, v_start, v_len, D)
    rows_b = jnp.where(rows_b < 0, -2, rows_b)  # distinct pad for B side
    cnt, hita, hitb = intersect_blocked(
        rows_a, rows_b, block_rows=_block_rows_for(D), interpret=interpret)
    S = jnp.zeros((m + 1,), jnp.int32)
    S = S.at[e1].add(cnt)
    S = S.at[jnp.where(hita > 0, eids_a, m)].add(hita)
    S = S.at[jnp.where(hitb > 0, eids_b, m)].add(hitb)
    return S


def compute_support_kernel(g: CSRGraph, *, interpret: bool | None = None,
                           classes=_DEG_CLASSES) -> np.ndarray:
    """AM4 support computation with the Pallas intersect kernel."""
    if g.m == 0:
        return np.zeros(0, np.int32)
    if interpret is None:
        interpret = _interpret_default()

    u = g.El[:, 0].astype(np.int64)
    v = g.El[:, 1].astype(np.int64)
    Es = g.Es.astype(np.int64)
    Eo = g.Eo.astype(np.int64)
    dpu = (Es[u + 1] - Eo[u])     # |N⁺(u)|
    dpv = (Es[v + 1] - Eo[v])     # |N⁺(v)|
    dmax = np.maximum(dpu, dpv)

    N = jnp.asarray(g.N)
    Eid = jnp.asarray(g.Eid)
    S_total = jnp.zeros((g.m + 1,), jnp.int32)

    prev = 0
    fallback_mask = dmax > classes[-1]
    for D in classes:
        sel = (dmax > prev) & (dmax <= D)
        prev = D
        ids = np.nonzero(sel)[0]
        if ids.size == 0:
            continue
        S_total = S_total + _bucket_support(
            N, Eid,
            jnp.asarray(Eo[u[ids]], jnp.int32),
            jnp.asarray(dpu[ids], jnp.int32),
            jnp.asarray(Eo[v[ids]], jnp.int32),
            jnp.asarray(dpv[ids], jnp.int32),
            jnp.asarray(ids, jnp.int32),
            g.m, D, interpret)

    S = np.asarray(S_total[: g.m])

    fb = np.nonzero(fallback_mask)[0]
    if fb.size:
        S = S + _fallback_support(g, fb)
    return S.astype(np.int32)


def _fallback_support(g: CSRGraph, edge_ids: np.ndarray) -> np.ndarray:
    """Ranged-binary-search support restricted to the given (huge) edges."""
    u = g.El[edge_ids, 0].astype(np.int64)
    v = g.El[edge_ids, 1].astype(np.int64)
    Es = g.Es.astype(np.int64)
    Eo = g.Eo.astype(np.int64)
    cnt = Es[v + 1] - Eo[v]
    off = np.zeros(edge_ids.size + 1, np.int64)
    np.cumsum(cnt, out=off[1:])
    nw = int(off[-1])
    local = np.repeat(np.arange(edge_ids.size), cnt)
    intra = np.arange(nw) - off[local]
    tab_e1 = edge_ids[local].astype(np.int32)
    cand_slot = (Eo[v[local]] + intra).astype(np.int32)
    lo = Eo[u[local]].astype(np.int32)
    hi = Es[u[local] + 1].astype(np.int32)
    S = support_mod._support_jit(
        jnp.asarray(g.N), jnp.asarray(g.Eid),
        jnp.asarray(tab_e1), jnp.asarray(cand_slot),
        jnp.asarray(lo), jnp.asarray(hi),
        support_mod._search_iters(g, oriented=True), g.m)
    return np.asarray(S)
