"""Pallas TPU kernels (validated in interpret mode on CPU).

The paper's one custom-kernel-worthy hot spot is adjacency-set intersection
(support computation, Alg. 3); see intersect.py. The LM stack deliberately
stays pure-XLA so compiled cost_analysis stays honest for the roofline.
"""

from repro.kernels.intersect import intersect_blocked
from repro.kernels.ops import compute_support_kernel
from repro.kernels.ref import intersect_ref

__all__ = ["intersect_blocked", "compute_support_kernel", "intersect_ref"]
