"""Pallas TPU kernels (validated in interpret mode on CPU).

The paper's two custom-kernel-worthy hot spots are adjacency-set intersection
(support computation, Alg. 3; intersect.py) and the peel phase's wedge-table
SCAN (Alg. 5; peel.py). The LM stack deliberately stays pure-XLA so compiled
cost_analysis stays honest for the roofline.
"""

from repro.kernels.intersect import intersect_blocked
from repro.kernels.ops import compute_support_kernel
from repro.kernels.peel import peel_decrements, peel_decrement_targets
from repro.kernels.ref import intersect_ref

__all__ = ["intersect_blocked", "compute_support_kernel", "intersect_ref",
           "peel_decrements", "peel_decrement_targets"]
