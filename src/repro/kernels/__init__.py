"""Pallas TPU kernels (validated in interpret mode on CPU).

The paper's two custom-kernel-worthy hot spots are the support phase's
oriented wedge-table scan (Alg. 3/AM4; support.py, plus the older
degree-bucketed intersect.py/ops.py variant) and the peel phase's wedge-table
SCAN (Alg. 5; peel.py). Both wedge-table kernels share their chunk layout,
padding policy, and ranged-binary-search probe via wedge_common.py. The LM
stack deliberately stays pure-XLA so compiled cost_analysis stays honest for
the roofline.
"""

from repro.kernels.intersect import intersect_blocked
from repro.kernels.ops import compute_support_kernel
from repro.kernels.peel import peel_decrements, peel_decrement_targets
from repro.kernels.ref import intersect_ref
from repro.kernels.support import (fold_support_targets, support_counts,
                                   support_hit_targets)

__all__ = ["intersect_blocked", "compute_support_kernel", "intersect_ref",
           "peel_decrements", "peel_decrement_targets",
           "support_hit_targets", "support_counts", "fold_support_targets"]
