"""Pallas TPU kernels (validated in interpret mode on CPU).

The paper's two custom-kernel-worthy hot spots are the support phase's
oriented wedge-table scan (Alg. 3/AM4; support.py, plus the older
degree-bucketed intersect.py/ops.py variant) and the peel phase's wedge-table
SCAN (Alg. 5; peel.py). Both wedge-table kernels share their chunk layout,
padding policy, and ranged-binary-search probe via wedge_common.py, and both
fold their scatter on-chip into a VMEM-resident (m+1,) accumulator block
(DESIGN.md §16). The LM stack deliberately stays pure-XLA so compiled
cost_analysis stays honest for the roofline.
"""

from repro.kernels.intersect import intersect_blocked
from repro.kernels.ops import compute_support_kernel
from repro.kernels.peel import peel_decrements, peel_decrement_fold
from repro.kernels.ref import intersect_ref
from repro.kernels.support import support_accumulate, support_counts

__all__ = ["intersect_blocked", "compute_support_kernel", "intersect_ref",
           "peel_decrements", "peel_decrement_fold",
           "support_accumulate", "support_counts"]
