"""Pallas TPU kernel: blocked broadcast-compare sorted-set intersection.

This is the TPU-native replacement for the paper's thread-local X-array
membership test (DESIGN.md §2): a *batch* of adjacency-row pairs is staged in
VMEM and intersected by an all-pairs equality compare on the VPU — dense,
branch-free, layout-friendly work instead of per-thread random access.

Inputs are padded sorted rows: A (E, DA) with pad -1, B (E, DB) with pad -2
(distinct pads so padding never matches). Outputs per row:

  count  (E,)      |A_row ∩ B_row|
  hit_a  (E, DA)   1 where A slot matched something in B
  hit_b  (E, DB)   1 where B slot matched something in A

The hit masks let the caller scatter support increments to the *edge ids* of
the matching adjacency slots (Eid gathers) — the three AtomicAdds of
Algorithm 3 become three masked scatter-adds.

Grid: 1-D over row-blocks of size BE. VMEM per step ≈
BE·(DA+DB)·4 B  + BE·DA·DB·4 B (compare cube, fused by Mosaic) — BE is chosen
in ops.py so this stays ≪ 16 MiB. Matmul-free; lane dim padded to 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intersect_kernel(a_ref, b_ref, cnt_ref, hita_ref, hitb_ref):
    a = a_ref[...]          # (BE, DA) int32
    b = b_ref[...]          # (BE, DB) int32
    # all-pairs equality: (BE, DA, DB)
    eq = a[:, :, None] == b[:, None, :]
    hita = jnp.any(eq, axis=2)
    hitb = jnp.any(eq, axis=1)
    cnt_ref[...] = jnp.sum(hita.astype(jnp.int32), axis=1)
    hita_ref[...] = hita.astype(jnp.int32)
    hitb_ref[...] = hitb.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def intersect_blocked(a: jnp.ndarray, b: jnp.ndarray, *,
                      block_rows: int = 256,
                      interpret: bool = True):
    """Row-wise set intersection of padded sorted id rows.

    a: (E, DA) int32, pad -1 ; b: (E, DB) int32, pad -2. E % block_rows == 0
    is handled here by padding. Returns (count (E,), hit_a (E,DA), hit_b (E,DB)).
    """
    E, DA = a.shape
    _, DB = b.shape
    BE = min(block_rows, max(E, 1))
    Ep = -(-max(E, 1) // BE) * BE
    if Ep != E:
        a = jnp.concatenate(
            [a, jnp.full((Ep - E, DA), -1, a.dtype)], axis=0)
        b = jnp.concatenate(
            [b, jnp.full((Ep - E, DB), -2, b.dtype)], axis=0)

    grid = (Ep // BE,)
    cnt, hita, hitb = pl.pallas_call(
        _intersect_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BE, DA), lambda i: (i, 0)),
            pl.BlockSpec((BE, DB), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BE,), lambda i: (i,)),
            pl.BlockSpec((BE, DA), lambda i: (i, 0)),
            pl.BlockSpec((BE, DB), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ep,), jnp.int32),
            jax.ShapeDtypeStruct((Ep, DA), jnp.int32),
            jax.ShapeDtypeStruct((Ep, DB), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
    return cnt[:E], hita[:E], hitb[:E]
