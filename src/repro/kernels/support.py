"""Pallas TPU kernel for the AM4 support phase (oriented triangle counting).

One grid step evaluates one chunk of the *oriented* wedge table built by
``core.support.build_support_table``: the chunk's ``e1 / cand_slot / lo / hi``
rows are staged in VMEM next to the (replicated) adjacency arrays, the
candidate gather ``w = N[cand_slot]`` is fused with the ranged binary search
of ``w`` in ``N⁺(u) = N[lo:hi)`` (the kernel's membership test, shared with
the peel kernel via ``kernels/wedge_common.py``), and every probe resolves
branch-free on the VPU.

Each hit is one triangle, discovered exactly once (AM4 anchors a triangle at
its lowest-vertex edge), and must increment the support of its three edges.
The fold is fused on-chip: the kernel owns a single ``(m + 1,)`` accumulator
output block whose index map pins it to block 0 for every grid step, so it
stays resident in VMEM across the whole (sequential) grid.  Grid step 0
zeroes it; every step then scatter-adds its chunk's three increment targets —
the edge ids of the anchor ``(u,v)``, the scanned edge ``(v,w)`` and the
closing edge ``(u,w)`` on a hit, or the absorbing sentinel slot ``m``
otherwise — directly into the accumulator.  Integer addition is exact, so
the result is bitwise identical to the jnp path's gather/scatter pipeline
(and to the retired stream-out + host-side fold) regardless of accumulation
order.  Per-chunk triangle partials still stream out one int per grid step
(each AM4 hit is one distinct triangle, so the partials sum to the graph's
total).

Unlike the peel kernel there is no frontier state: the support table is
scanned exactly once per decomposition, so there is no ``active`` mask and no
per-level re-entry — the grid is simply the chunked table.  VMEM per grid
step ≈ 4·(4·chunk + 2·two_m + (m+1)) bytes; callers pick ``chunk`` so this
stays well under the ~16 MiB budget.  On non-TPU backends the kernel runs in
interpret mode (the CI contract: the lowering is exercised on every PR, the
Mosaic path on TPU runners).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import wedge_common


def _support_chunk_kernel(e1_ref, cand_ref, lo_ref, hi_ref, n_ref, eid_ref,
                          s_ref, tri_ref, *, iters: int, m: int):
    """One oriented wedge-table chunk folded into the (m+1,) accumulator."""
    @pl.when(pl.program_id(0) == 0)
    def _zero():
        s_ref[...] = jnp.zeros_like(s_ref)

    N = n_ref[...]                 # (two_m,) int32 adjacency values
    Eid = eid_ref[...]             # (two_m,) int32 slot → edge id
    e1 = e1_ref[...]               # (chunk,) anchor edge ids (m = padding)
    cand = cand_ref[...]           # (chunk,) CSR slot of candidate w
    lo = lo_ref[...]               # (chunk,) probe range start
    hi = hi_ref[...]               # (chunk,) probe range end (lo==hi → miss)

    hit, safe = wedge_common.probe(N, cand, lo, hi, iters=iters)
    tgt1 = jnp.where(hit, e1, m).astype(jnp.int32)
    tgt2 = jnp.where(hit, Eid[cand], m).astype(jnp.int32)
    tgt3 = jnp.where(hit, Eid[safe], m).astype(jnp.int32)
    s_ref[...] = s_ref[...].at[tgt1].add(1).at[tgt2].add(1).at[tgt3].add(1)
    # on-chip partial accumulation: this chunk's triangle count
    tri_ref[...] = jnp.sum(hit.astype(jnp.int32), keepdims=True)


def support_accumulate(e1, cand, lo, hi, N, Eid, *, chunk: int,
                       n_chunks: int, iters: int, m: int,
                       interpret: bool = True):
    """Fused support fold (and per-chunk triangle partials) for a full table.

    Table arrays are (n_chunks*chunk,) int32, padded per
    ``wedge_common.pad_chunked``; N/Eid are (two_m,) int32.  Returns
    ``(S_ext, tri_partial)`` — ``S_ext`` the (m+1,) int32 support vector
    accumulated on-chip (slot ``m`` absorbs padding rows and misses; read
    ``S_ext[:m]``), ``tri_partial`` the (n_chunks,) int32 per-chunk triangle
    counts.  Trace-level: the batched engine and the distributed path call
    this inside their own jit/vmap/shard_map scopes.
    """
    two_m = N.shape[0]
    kernel = functools.partial(_support_chunk_kernel, iters=iters, m=m)
    cspec = wedge_common.chunk_spec(chunk)
    full = wedge_common.replicated_spec
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[cspec, cspec, cspec, cspec, full(two_m), full(two_m)],
        out_specs=[full(m + 1), wedge_common.chunk_spec(1)],
        out_shape=[jax.ShapeDtypeStruct((m + 1,), jnp.int32),
                   jax.ShapeDtypeStruct((n_chunks,), jnp.int32)],
        interpret=interpret,
    )(e1, cand, lo, hi, N, Eid)


@functools.partial(jax.jit, static_argnames=("chunk", "n_chunks", "iters",
                                             "m", "interpret"))
def support_counts(e1, cand, lo, hi, N, Eid, *, chunk: int, n_chunks: int,
                   iters: int, m: int, interpret: bool = True):
    """Jitted convenience wrapper: fused kernel → ((m+1,) S, triangles).

    Used by ``core.support.compute_support(mode="pallas")``, tests, and the
    CI interpret-lowering gate; the batched engine and the distributed path
    trace ``support_accumulate`` directly inside their own jit/shard_map
    scopes.
    """
    S, tri = support_accumulate(
        e1, cand, lo, hi, N, Eid, chunk=chunk, n_chunks=n_chunks,
        iters=iters, m=m, interpret=interpret)
    return S, jnp.sum(tri)
