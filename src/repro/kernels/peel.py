"""Pallas TPU kernel for the PKT peel phase (Algorithm 5's SCAN hot loop).

One grid step evaluates one wedge-table chunk: the chunk's ``e1 / cand_slot /
lo / hi`` rows are staged in VMEM next to the (replicated) adjacency arrays
and edge-state vectors, the ranged binary search runs branch-free on the VPU,
and the frontier / processed / tie-break predicates of ProcessSubLevel are
evaluated as dense masks.  The decrement fold is fused on-chip: the kernel
owns a single ``(m + 1,)`` accumulator output block pinned to block 0 for
every grid step, so it stays resident in VMEM across the whole (sequential)
grid.  Grid step 0 zeroes it; every step scatter-adds its chunk's two
decrement targets — the edge id of each non-anchor triangle edge when the
paper's AtomicSub would fire, or the absorbing sentinel slot ``m``
otherwise — directly into the accumulator.  Integer addition is exact, so
the fused fold is bitwise identical to the jnp executors (and to the retired
target-stream + host-side scatter) regardless of accumulation order.

The incremental layer's ``pinned`` schedule mask (edges that process their
triangles at a replayed level but never receive decrements,
core/truss_inc.py) rides into the kernel as one more replicated (m+1,)
state vector and suppresses the decrement predicate in place — the retired
stream path had to re-route pinned targets to the sentinel on the host.

Chunk skipping (the paper's dynamic scheduling) survives as an ``active``
mask input: a Pallas grid is static, so sub-levels that only touch a few
chunks still *stream* every block, but inactive blocks short-circuit to
sentinel writes — compute is masked even though DMA is not.  The
work-efficient ``mode="chunked"`` while_loop in ``core/pkt.py`` remains the
right choice for very sparse frontiers; this kernel wins when frontiers are
wide (dense sub-levels dominate total peel time, paper Fig. 6).

VMEM per grid step ≈ 4·(chunk + two_m·2 + 5·(m+1)) bytes; callers pick
``chunk`` so this stays well under the ~16 MiB budget.  On non-TPU backends
the kernel runs in interpret mode (the CI contract: the lowering is
exercised on every PR, the Mosaic path on TPU runners).

Chunk layout, padding, and the fused gather + ranged-binary-search probe are
shared with the support kernel via ``kernels/wedge_common.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import wedge_common

_interpret_default = wedge_common.interpret_default


def _peel_chunk_kernel(act_ref, l_ref, e1_ref, cand_ref, lo_ref, hi_ref,
                       n_ref, eid_ref, s_ref, proc_ref, curr_ref, pin_ref,
                       dec_ref, *, iters: int, m: int):
    """One wedge-table chunk folded into the (m+1,) decrement accumulator."""
    @pl.when(pl.program_id(0) == 0)
    def _zero():
        dec_ref[...] = jnp.zeros_like(dec_ref)

    N = n_ref[...]                 # (two_m,) int32 adjacency values
    Eid = eid_ref[...]             # (two_m,) int32 slot → edge id
    S = s_ref[...]                 # (m+1,)  int32 extended support
    proc = proc_ref[...] != 0      # (m+1,)  processed mask
    curr = curr_ref[...] != 0      # (m+1,)  current-frontier mask
    pin = pin_ref[...] != 0        # (m+1,)  pinned schedule mask
    act = act_ref[0] != 0          # chunk overlaps a frontier edge's range
    l = l_ref[0]                   # current peel level

    e1 = e1_ref[...]               # (chunk,) anchor edge ids (m = padding)
    cand = cand_ref[...]           # (chunk,) CSR slot of candidate w
    lo = lo_ref[...]               # (chunk,) probe range start
    hi = hi_ref[...]               # (chunk,) probe range end (lo==hi → miss)

    in1 = curr[e1]                 # padding rows carry e1 == m → curr[m] False
    hit, safe = wedge_common.probe(N, cand, lo, hi, iters=iters)
    e2 = Eid[cand]
    e3 = Eid[safe]
    valid = act & in1 & hit & (~proc[e2]) & (~proc[e3])
    # the paper's tie-break: of two frontier edges sharing a triangle, the
    # lower edge id processes it (each triangle decremented exactly once)
    dec2 = valid & (S[e2] > l) & ((~curr[e3]) | (e1 < e3)) & (~pin[e2])
    dec3 = valid & (S[e3] > l) & ((~curr[e2]) | (e1 < e2)) & (~pin[e3])
    tgt2 = jnp.where(dec2, e2, m).astype(jnp.int32)
    tgt3 = jnp.where(dec3, e3, m).astype(jnp.int32)
    dec_ref[...] = dec_ref[...].at[tgt2].add(1).at[tgt3].add(1)


def peel_decrement_fold(active, l, e1, cand, lo, hi, N, Eid,
                        S_ext, processed, inCurr, pinned, *, chunk: int,
                        n_chunks: int, iters: int, m: int,
                        interpret: bool = True):
    """Fused decrement fold over the wedge table at sub-level ``l``.

    active: (n_chunks,) int32 chunk mask; l: (1,) int32; table arrays
    (n_chunks*chunk,) int32; N/Eid: (two_m,) int32;
    S_ext/processed/inCurr/pinned: (m+1,) int32 (pinned all-zero when the
    caller has no schedule edges).  Returns the (m+1,) int32 decrement
    vector accumulated on-chip — slot ``m`` absorbs sentinel writes; read
    the result below index m.  Trace-level: ``core/pkt.py`` calls this
    inside its jitted peel loop.
    """
    two_m = N.shape[0]
    kernel = functools.partial(_peel_chunk_kernel, iters=iters, m=m)
    chunk_spec = wedge_common.chunk_spec(chunk)
    full = wedge_common.replicated_spec
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            wedge_common.chunk_spec(1),           # active (per chunk)
            full(1),                              # l (replicated scalar)
            chunk_spec, chunk_spec, chunk_spec, chunk_spec,
            full(two_m), full(two_m),             # N, Eid
            full(m + 1), full(m + 1),             # S_ext, processed
            full(m + 1), full(m + 1),             # inCurr, pinned
        ],
        out_specs=[full(m + 1)],
        out_shape=[jax.ShapeDtypeStruct((m + 1,), jnp.int32)],
        interpret=interpret,
    )(active, l, e1, cand, lo, hi, N, Eid, S_ext, processed, inCurr,
      pinned)[0]


@functools.partial(jax.jit, static_argnames=("chunk", "n_chunks", "iters",
                                             "m", "interpret"))
def peel_decrements(active, l, e1, cand, lo, hi, N, Eid, S_ext, processed,
                    inCurr, *, chunk: int, n_chunks: int, iters: int, m: int,
                    interpret: bool = True):
    """Jitted convenience wrapper: fused fold with no pinned edges → (m+1,)
    decrement vector (slot m absorbs sentinel writes). Used directly by tests
    and the CI interpret-compile gate; ``core/pkt.py`` traces
    ``peel_decrement_fold`` inside its peel loop."""
    return peel_decrement_fold(
        active, l, e1, cand, lo, hi, N, Eid, S_ext, processed, inCurr,
        jnp.zeros((m + 1,), jnp.int32),
        chunk=chunk, n_chunks=n_chunks, iters=iters, m=m, interpret=interpret)
