"""Pallas TPU kernel for the PKT peel phase (Algorithm 5's SCAN hot loop).

One grid step evaluates one wedge-table chunk: the chunk's ``e1 / cand_slot /
lo / hi`` rows are staged in VMEM next to the (replicated) adjacency arrays
and edge-state vectors, the ranged binary search runs branch-free on the VPU,
and the frontier / processed / tie-break predicates of ProcessSubLevel are
evaluated as dense masks.  The kernel emits, per wedge entry, the *decrement
target* for each of the two non-anchor triangle edges — the edge id when the
paper's AtomicSub would fire, or the sentinel ``m`` otherwise.  The caller
folds the two target streams into the decrement vector with two scatter-adds
(slot ``m`` absorbs the no-ops), which keeps the kernel store-contention-free:
every output slot is written by exactly one grid step.

Chunk skipping (the paper's dynamic scheduling) survives as an ``active``
mask input: a Pallas grid is static, so sub-levels that only touch a few
chunks still *stream* every block, but inactive blocks short-circuit to
sentinel writes — compute is masked even though DMA is not.  The
work-efficient ``mode="chunked"`` while_loop in ``core/pkt.py`` remains the
right choice for very sparse frontiers; this kernel wins when frontiers are
wide (dense sub-levels dominate total peel time, paper Fig. 6).

VMEM per grid step ≈ 4·(chunk + two_m·2 + 3·(m+1)) bytes plus the output
blocks; callers pick ``chunk`` so this stays well under the ~16 MiB budget.
On non-TPU backends the kernel runs in interpret mode (the CI contract: the
lowering is exercised on every PR, the Mosaic path on TPU runners).

Chunk layout, padding, and the fused gather + ranged-binary-search probe are
shared with the support kernel via ``kernels/wedge_common.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import wedge_common

_interpret_default = wedge_common.interpret_default


def _peel_chunk_kernel(act_ref, l_ref, e1_ref, cand_ref, lo_ref, hi_ref,
                       n_ref, eid_ref, s_ref, proc_ref, curr_ref,
                       tgt2_ref, tgt3_ref, *, iters: int, m: int):
    """One wedge-table chunk → decrement targets (edge id, or m for no-op)."""
    N = n_ref[...]                 # (two_m,) int32 adjacency values
    Eid = eid_ref[...]             # (two_m,) int32 slot → edge id
    S = s_ref[...]                 # (m+1,)  int32 extended support
    proc = proc_ref[...] != 0      # (m+1,)  processed mask
    curr = curr_ref[...] != 0      # (m+1,)  current-frontier mask
    act = act_ref[0] != 0          # chunk overlaps a frontier edge's range
    l = l_ref[0]                   # current peel level

    e1 = e1_ref[...]               # (chunk,) anchor edge ids (m = padding)
    cand = cand_ref[...]           # (chunk,) CSR slot of candidate w
    lo = lo_ref[...]               # (chunk,) probe range start
    hi = hi_ref[...]               # (chunk,) probe range end (lo==hi → miss)

    in1 = curr[e1]                 # padding rows carry e1 == m → curr[m] False
    hit, safe = wedge_common.probe(N, cand, lo, hi, iters=iters)
    e2 = Eid[cand]
    e3 = Eid[safe]
    valid = act & in1 & hit & (~proc[e2]) & (~proc[e3])
    # the paper's tie-break: of two frontier edges sharing a triangle, the
    # lower edge id processes it (each triangle decremented exactly once)
    dec2 = valid & (S[e2] > l) & ((~curr[e3]) | (e1 < e3))
    dec3 = valid & (S[e3] > l) & ((~curr[e2]) | (e1 < e2))
    tgt2_ref[...] = jnp.where(dec2, e2, m).astype(jnp.int32)
    tgt3_ref[...] = jnp.where(dec3, e3, m).astype(jnp.int32)


def peel_decrement_targets(active, l, e1, cand, lo, hi, N, Eid,
                           S_ext, processed, inCurr, *, chunk: int,
                           n_chunks: int, iters: int, m: int,
                           interpret: bool = True):
    """Decrement targets for every wedge-table entry at sub-level ``l``.

    active: (n_chunks,) int32 chunk mask; l: (1,) int32; table arrays
    (n_chunks*chunk,) int32; N/Eid: (two_m,) int32; S_ext/processed/inCurr:
    (m+1,) int32.  Returns (tgt2, tgt3), each (n_chunks*chunk,) int32 in
    [0, m] — scatter ``+1`` at both and read the result below index m.
    """
    two_m = N.shape[0]
    nw = n_chunks * chunk
    kernel = functools.partial(_peel_chunk_kernel, iters=iters, m=m)
    chunk_spec = wedge_common.chunk_spec(chunk)
    full = wedge_common.replicated_spec
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            wedge_common.chunk_spec(1),           # active (per chunk)
            full(1),                              # l (replicated scalar)
            chunk_spec, chunk_spec, chunk_spec, chunk_spec,
            full(two_m), full(two_m),             # N, Eid
            full(m + 1), full(m + 1), full(m + 1),  # S_ext, processed, inCurr
        ],
        out_specs=[chunk_spec, chunk_spec],
        out_shape=[jax.ShapeDtypeStruct((nw,), jnp.int32)] * 2,
        interpret=interpret,
    )(active, l, e1, cand, lo, hi, N, Eid, S_ext, processed, inCurr)


@functools.partial(jax.jit, static_argnames=("chunk", "n_chunks", "iters",
                                             "m", "interpret"))
def peel_decrements(active, l, e1, cand, lo, hi, N, Eid, S_ext, processed,
                    inCurr, *, chunk: int, n_chunks: int, iters: int, m: int,
                    interpret: bool = True):
    """Jitted convenience wrapper: targets folded into the (m+1,) decrement
    vector (slot m absorbs sentinel writes). Used directly by tests and the
    CI interpret-compile gate; ``core/pkt.py`` traces the unjitted version
    inside its peel loop."""
    tgt2, tgt3 = peel_decrement_targets(
        active, l, e1, cand, lo, hi, N, Eid, S_ext, processed, inCurr,
        chunk=chunk, n_chunks=n_chunks, iters=iters, m=m, interpret=interpret)
    dec = jnp.zeros((m + 1,), jnp.int32)
    dec = dec.at[tgt2].add(1)
    dec = dec.at[tgt3].add(1)
    return dec
