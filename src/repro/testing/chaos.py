"""Deterministic fault injection for the serving stack (DESIGN.md §15).

Every expensive dispatch site in the serving path — engine flush, region
re-peel, support build, hierarchy flood — calls :func:`fault_point` just
before it commits to real work.  When no :class:`FaultPlan` is active the
call is a single global-load-and-compare and injects nothing, so the hooks
are safe to leave in production code.  When a plan *is* active (via the
plan's context manager, or :func:`activate` for long-lived processes such
as ``launch/truss.py --serve --fault-rate``), each hook consults the
plan's seeded rules and may:

- ``raise``   — throw a typed, transient :class:`InjectedFault`;
- ``delay``   — sleep for a configured duration before proceeding;
- ``corrupt`` — return the string ``"corrupt"``, instructing the call
  site to deterministically perturb its own intermediate state in a way
  the existing integrity checks are guaranteed to detect.

Rules fire either a fixed number of times (``times=N``, fully
deterministic — the backbone of the test matrix) or at a seeded
Bernoulli ``rate`` (the chaos bench's swept fault rates).  All decisions
derive from ``random.Random(seed)`` and the arrival order of hook calls,
so a single-threaded scheduler replays identically under a fixed seed.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

# the dispatch sites wrapped by fault_point hooks, in serving-path order
DISPATCH_SITES = ("flush", "region", "support", "hierarchy")

_MODES = ("raise", "delay", "corrupt")


class InjectedFault(RuntimeError):
    """Transient fault thrown by a ``raise``-mode rule at a dispatch site.

    Carries the ``site`` and ``rung`` it fired at so the resilience layer
    can attribute the failure to the right degradation ladder.
    """

    def __init__(self, site: str, rung: str | None):
        super().__init__(f"injected fault at dispatch site {site!r} (rung {rung!r})")
        self.site = site
        self.rung = rung


@dataclass
class _Rule:
    site: str
    mode: str = "raise"
    times: int | None = None  # fire the first N matching calls; None = use rate
    rate: float = 0.0  # Bernoulli fire probability when times is None
    delay_s: float = 0.0  # sleep duration for mode="delay"
    rung: str | None = None  # only fire when the site runs on this executor rung
    fired: int = 0


@dataclass
class FaultPlan:
    """A seeded, ordered set of fault rules, activated as a context manager.

    >>> plan = FaultPlan(seed=7)
    >>> plan.add("flush", mode="raise", times=1)      # doctest: +SKIP
    >>> with plan:                                    # doctest: +SKIP
    ...     ...  # first engine flush raises InjectedFault, rest run clean
    """

    seed: int = 0
    _rules: dict = field(default_factory=dict, repr=False)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _rng: random.Random = field(default=None, repr=False)
    calls: dict = field(default_factory=dict)
    injected: dict = field(default_factory=dict)

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def add(
        self,
        site: str,
        *,
        mode: str = "raise",
        times: int | None = None,
        rate: float = 0.0,
        delay_s: float = 0.0,
        rung: str | None = None,
    ) -> "FaultPlan":
        """Register a rule at ``site``; returns self for chaining."""
        if site not in DISPATCH_SITES:
            raise ValueError(f"unknown dispatch site {site!r}; expected one of {DISPATCH_SITES}")
        if mode not in _MODES:
            raise ValueError(f"unknown fault mode {mode!r}; expected one of {_MODES}")
        if times is None and not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        if times is not None and times < 0:
            raise ValueError(f"times must be >= 0, got {times}")
        rule = _Rule(site=site, mode=mode, times=times, rate=rate, delay_s=delay_s, rung=rung)
        self._rules.setdefault(site, []).append(rule)
        return self

    @classmethod
    def uniform(
        cls,
        rate: float,
        *,
        sites: tuple = DISPATCH_SITES,
        seed: int = 0,
        mode: str = "raise",
        delay_s: float = 0.0,
    ) -> "FaultPlan":
        """A plan injecting ``mode`` faults at ``rate`` across ``sites``."""
        plan = cls(seed=seed)
        for site in sites:
            plan.add(site, mode=mode, rate=rate, delay_s=delay_s)
        return plan

    # -- hook protocol -------------------------------------------------------

    def _hit(self, site: str, rung: str | None) -> str | None:
        delay = None
        outcome = None
        with self._lock:
            self.calls[site] = self.calls.get(site, 0) + 1
            for rule in self._rules.get(site, ()):
                if rule.rung is not None and rule.rung != rung:
                    continue
                if rule.times is not None:
                    fire = rule.fired < rule.times
                else:
                    fire = rule.rate > 0.0 and self._rng.random() < rule.rate
                if not fire:
                    continue
                rule.fired += 1
                self.injected[site] = self.injected.get(site, 0) + 1
                if rule.mode == "raise":
                    raise InjectedFault(site, rung)
                if rule.mode == "delay":
                    delay = rule.delay_s
                else:  # corrupt
                    outcome = "corrupt"
                break
        if delay:
            time.sleep(delay)  # outside the lock: other hook calls must not block
        return outcome

    def stats(self) -> dict:
        """Per-site hook-call and injection counts (snapshot)."""
        with self._lock:
            return {"calls": dict(self.calls), "injected": dict(self.injected)}

    # -- activation ----------------------------------------------------------

    def __enter__(self) -> "FaultPlan":
        activate(self)
        return self

    def __exit__(self, *exc) -> None:
        deactivate(self)


_active: FaultPlan | None = None


def activate(plan: FaultPlan) -> None:
    """Install ``plan`` as the process-global fault plan."""
    global _active
    if _active is not None and _active is not plan:
        raise RuntimeError("a FaultPlan is already active; deactivate it first")
    _active = plan


def deactivate(plan: FaultPlan | None = None) -> None:
    """Remove the active fault plan (no-op if ``plan`` is not the active one)."""
    global _active
    if plan is None or _active is plan:
        _active = None


def fault_point(site: str, rung: str | None = None) -> str | None:
    """Dispatch-site hook: no-op unless a plan is active.

    Returns ``"corrupt"`` when a corrupt-mode rule fires (the call site
    applies its own detectable perturbation), else ``None``.  Raises
    :class:`InjectedFault` for raise-mode rules; sleeps for delay-mode.
    """
    plan = _active
    if plan is None:
        return None
    return plan._hit(site, rung)
