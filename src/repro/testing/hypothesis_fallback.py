"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The tier-1 suite uses a small, fixed slice of the hypothesis API:
``given``, ``settings``, ``HealthCheck`` and the strategies ``integers``,
``floats``, ``sampled_from`` and ``composite``.  Some CI-less environments
(including the offline container this repo is developed in) don't ship
hypothesis and nothing may be pip-installed there, which used to abort test
*collection* for half the suite.

``install()`` registers a deterministic fallback under the ``hypothesis``
module name: each ``@given`` test runs ``max_examples`` examples drawn from a
seeded ``numpy`` generator (seed = CRC32 of the test name, so failures
reproduce).  It is installed by ``tests/conftest.py`` only when the real
package is missing — with hypothesis available the shim is inert, and CI
installs the real thing.

This is *not* property-based testing (no shrinking, no example database); it
is a deterministic N-example sampler that keeps the suite collectable and
meaningful everywhere.
"""

from __future__ import annotations

import sys
import types
import zlib

import numpy as np

_DEFAULT_EXAMPLES = 20


class SearchStrategy:
    """A strategy is just a sampler: rng -> value."""

    def __init__(self, sample):
        self.sample = sample

    def map(self, f):
        return SearchStrategy(lambda rng: f(self.sample(rng)))

    def filter(self, pred, *, max_tries: int = 100):
        def sample(rng):
            for _ in range(max_tries):
                v = self.sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(sample)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> SearchStrategy:
    pool = list(elements)
    return SearchStrategy(lambda rng: pool[int(rng.integers(0, len(pool)))])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def lists(elem: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def sample(rng):
        k = int(rng.integers(min_size, max_size + 1))
        return [elem.sample(rng) for _ in range(k)]
    return SearchStrategy(sample)


def composite(fn):
    """``@st.composite`` — fn(draw, *args) becomes a strategy factory."""
    def make(*args, **kwargs):
        def sample(rng):
            return fn(lambda s: s.sample(rng), *args, **kwargs)
        return SearchStrategy(sample)
    make.__name__ = getattr(fn, "__name__", "composite")
    return make


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


def settings(*args, max_examples: int | None = None, **_ignored):
    """Decorator recording max_examples; all other knobs are no-ops here."""
    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn
    if args and callable(args[0]):  # bare @settings
        return args[0]
    return deco


def given(*strategies, **kw_strategies):
    def deco(fn):
        def runner():
            n = (getattr(runner, "_stub_max_examples", None)
                 or getattr(fn, "_stub_max_examples", None)
                 or _DEFAULT_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                a = [s.sample(rng) for s in strategies]
                kw = {k: s.sample(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*a, **kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} (seed={seed}): "
                        f"args={a!r} kwargs={kw!r}") from e

        # Deliberately NOT functools.wraps: pytest must see a zero-arg
        # signature, or it would treat the generated args as fixtures.
        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.hypothesis_stub = True
        return runner
    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "just",
                 "lists", "composite"):
        setattr(st_mod, name, globals()[name])
    st_mod.SearchStrategy = SearchStrategy

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st_mod
    hyp.__is_repro_fallback__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
