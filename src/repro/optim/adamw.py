"""AdamW with warmup+cosine schedule; optimizer state shards like params
(ZeRO-1+: the m/v trees reuse the param PartitionSpecs, so with FSDP enabled
they are fully sharded over (pod, data) × model)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"   # cosine | linear | const


def lr_at_step(step, cfg: AdamWConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    if cfg.schedule == "const":
        decay = 1.0
    else:
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * t))
        else:
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * (1 - t)
    return cfg.lr * warm * decay


def adamw_init(params) -> dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros)}


def adamw_update(params, grads, opt, step, cfg: AdamWConfig):
    lr = lr_at_step(step, cfg)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}
