"""Gradient compression for cross-pod reduction (int8 + error feedback).

On a 2-pod (or N-pod) deployment the `pod`-axis gradient all-reduce crosses
the slow inter-pod links; quantizing that traffic to int8 cuts it 4× vs f32
(2× vs bf16). Scheme (1-bit-Adam-style simplified):

  q = round(clip(g / s, ±127)),  s = max|g| / 127   (per-tensor symmetric)
  e' = g - dequant(q)                                (error feedback, carried)

The within-pod reduction stays bf16 (cheap links); only the pod-axis
exchange is quantized. In pjit-land we express this as a grad transform
(quantize → dequant with the EF residual folded into the next step) — the
wire format the collective would carry; tests prove optimizer-trajectory
parity within tolerance and strict improvement over no-EF quantization.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    s = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-20) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / s), -127, 127)
    return q.astype(jnp.int8), s


def dequantize_int8(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * s


def compress_grads(grads: Any, error: Any | None = None):
    """Quantize a grad pytree with error feedback.

    Returns (dequantized grads, new error pytree). ``error`` carries the
    per-leaf quantization residual from the previous step (or None).
    """
    flat, tdef = jax.tree.flatten(grads)
    err = (jax.tree.leaves(error) if error is not None
           else [jnp.zeros_like(g, jnp.float32) for g in flat])
    out, new_err = [], []
    for g, e in zip(flat, err):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        out.append(deq.astype(g.dtype))
        new_err.append(corrected - deq)
    return jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, new_err)


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes(grads: Any) -> tuple[int, int]:
    """(compressed, uncompressed-f32) bytes the pod link would carry."""
    flat = jax.tree.leaves(grads)
    n = sum(int(g.size) for g in flat)
    return n + 4 * len(flat), 4 * n
