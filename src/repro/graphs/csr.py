"""CSR graph container mirroring the paper's Figure 2 data structures.

The decomposition algorithms never touch an adjacency hash table; everything is
driven by these arrays (paper §3, "Unlike other k-core and k-truss algorithms,
we do not use a hash table"):

  Es  : (n+1,) int32   CSR row offsets
  N   : (2m,)  int32   CSR column indices (sorted per row)
  Eid : (2m,)  int32   edge id of each adjacency slot (both slots of an edge
                       share one id in [0, m))
  El  : (m, 2) int32   edge endpoints, El[e] = (u, v) with u < v
  Eo  : (n,)   int32   first slot j in [Es[u], Es[u+1]) with N[j] > u
  S   : (m,)   int32   edge support (filled by support computation)

Persistent footprint with 4-byte ints: (n+1) + 2m + 2m + 2m + n = 28m + 8n
bytes, matching the paper's accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


#: Largest vertex-id space for which ``lo * n + hi`` key packing stays inside
#: int64: floor(sqrt(2**63 - 1)).  The CSR arrays themselves are int32, so the
#: effective vertex-id bound is the tighter ``_MAX_N`` below — but any caller
#: packing keys with a caller-supplied ``n`` must respect this one too.
MAX_PACK_N = 3_037_000_499
#: CSR layout bound: vertex ids live in int32 columns (Fig. 2 arrays).
_MAX_N = np.iinfo(np.int32).max


def check_edge_array(edges) -> np.ndarray:
    """Validate a user-supplied edge array; returns it as (k, 2) int64.

    Rejects (with a descriptive ValueError) anything the downstream key
    packing or CSR build would otherwise silently corrupt: non-integer
    dtypes, shapes other than (k, 2), negative vertex ids (which corrupt the
    ``lo * n + hi`` packing), vertex ids beyond the int32 CSR layout, and
    self-loop rows.  Empty inputs of any shape pass through as (0, 2).
    """
    edges = np.asarray(edges)
    if edges.size == 0:
        return np.zeros((0, 2), np.int64)
    if not np.issubdtype(edges.dtype, np.integer):
        raise ValueError(
            f"edges must have an integer dtype, got {edges.dtype}")
    if edges.ndim != 2 or edges.shape[1] != 2:
        raise ValueError(f"edges must be (k, 2), got shape {edges.shape}")
    edges = edges.astype(np.int64, copy=False)
    vmin, vmax = int(edges.min()), int(edges.max())
    if vmin < 0:
        bad = edges[(edges < 0).any(axis=1)][0]
        raise ValueError(
            f"negative vertex ids are not allowed (e.g. edge "
            f"({bad[0]}, {bad[1]})): they corrupt the lo*n+hi key packing")
    if vmax >= _MAX_N:
        raise ValueError(
            f"vertex id {vmax} exceeds the int32 CSR layout bound "
            f"({_MAX_N - 1}); relabel vertices to a compact id space "
            f"(key packing itself overflows int64 beyond n={MAX_PACK_N})")
    if (edges[:, 0] == edges[:, 1]).any():
        v = int(edges[edges[:, 0] == edges[:, 1]][0, 0])
        raise ValueError(f"self-loops are not allowed (vertex {v})")
    return edges


def edge_keys(lo: np.ndarray, hi: np.ndarray, n: int) -> np.ndarray:
    """Pack canonical (lo < hi) endpoint pairs into unique int64 keys.

    The single blessed home for the ``lo * n + hi`` packing (trusslint
    J003): operands are widened to int64 *before* the multiply and both
    the pack space and the ids are bounds-checked, so a key can never
    wrap silently — ``n <= MAX_PACK_N`` implies ``n*n - 1 < 2**63``.
    """
    n = int(n)
    if n > MAX_PACK_N:
        raise ValueError(
            f"n={n} overflows int64 lo*n+hi key packing (max {MAX_PACK_N})")
    lo = np.asarray(lo)
    hi = np.asarray(hi)
    if lo.size:
        vmin = min(int(lo.min()), int(hi.min()))
        vmax = max(int(lo.max()), int(hi.max()))
        if vmin < 0 or vmax >= n:
            raise ValueError(
                f"vertex ids must lie in [0, n={n}) for lo*n+hi key "
                f"packing; got range [{vmin}, {vmax}] — keys would "
                f"collide or wrap")
    return lo.astype(np.int64) * n + hi


def canonical_edges_with_rows(edges) -> tuple[np.ndarray, np.ndarray,
                                              np.ndarray, int]:
    """Validate + canonicalize, keeping per-input-row endpoint order.

    Returns ``(E, lo, hi, n)``: ``E`` the unique canonical (u < v) edge array
    sorted by key, ``lo``/``hi`` the canonical endpoints of every *input row*
    (so callers can map deduped results back to their own row order), and
    ``n`` the vertex-id space.  The validation of ``check_edge_array``
    applies (self-loops, negatives, huge ids all rejected).
    """
    edges = check_edge_array(edges)
    if edges.size == 0:
        return (np.zeros((0, 2), np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.int64), 0)
    n = int(edges.max()) + 1
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    uniq = np.unique(edge_keys(lo, hi, n))
    E = np.stack([uniq // n, uniq % n], axis=1)
    return E, lo, hi, n


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Undirected simple graph in the paper's array layout (host numpy)."""

    n: int
    m: int
    Es: np.ndarray   # (n+1,) int32
    N: np.ndarray    # (2m,) int32
    Eid: np.ndarray  # (2m,) int32
    El: np.ndarray   # (m, 2) int32
    Eo: np.ndarray   # (n,) int32
    #: lazy per-graph cache of device copies (see ``device_arrays``); a
    #: mutable field on a frozen dataclass so repeated decompositions of one
    #: graph share uploads without the graph itself becoming mutable
    _dev: dict = dataclasses.field(default_factory=dict, repr=False,
                                   compare=False)

    def device_arrays(self) -> dict:
        """Device copies of the CSR arrays, uploaded once per graph.

        Every decomposition entry point (``pkt``, ``compute_support``,
        ``truss_inc`` repairs, ``pkt_dist``) gathers against ``N``/``Eid``
        and — with device-side table construction — reads ``Es``/``Eo``/
        ``El`` on device too; before this cache each call re-uploaded the
        same arrays.  Keys: ``N, Eid, Es, Eo, El``.  jax is imported lazily
        so the graph container stays usable in numpy-only contexts.
        """
        if not self._dev:
            import jax.numpy as jnp

            self._dev.update(
                N=jnp.asarray(self.N), Eid=jnp.asarray(self.Eid),
                Es=jnp.asarray(self.Es), Eo=jnp.asarray(self.Eo),
                El=jnp.asarray(self.El))
        return self._dev

    @property
    def degrees(self) -> np.ndarray:
        return (self.Es[1:] - self.Es[:-1]).astype(np.int32)

    @property
    def dplus(self) -> np.ndarray:
        """Out-degree under the id orientation: |{w in N(u) : w > u}|."""
        return (self.Es[1:] - self.Eo).astype(np.int32)

    def wedge_count(self) -> int:
        d = self.degrees.astype(np.int64)
        return int((np.sum(d * d) - 2 * self.m) // 2)

    def work_estimate_oriented(self) -> int:
        """Sum of d+(v)^2 — the ordering-aware work estimate of Table 2."""
        dp = self.dplus.astype(np.int64)
        return int(np.sum(dp * dp))

    def work_estimate_oblivious(self) -> int:
        d = self.degrees.astype(np.int64)
        return int(np.sum(d * d))

    def validate(self) -> None:
        assert self.Es.shape == (self.n + 1,)
        assert self.Es[0] == 0 and self.Es[-1] == 2 * self.m
        assert self.N.shape == (2 * self.m,)
        assert self.Eid.shape == (2 * self.m,)
        assert self.El.shape == (self.m, 2)
        assert self.Eo.shape == (self.n,)
        # per-row sorted, no self loops, no duplicates
        for u in range(self.n):
            row = self.N[self.Es[u]:self.Es[u + 1]]
            assert np.all(np.diff(row) > 0), f"row {u} not strictly sorted"
            assert not np.any(row == u), f"self loop at {u}"
        # Eid consistency: both slots of edge e point at El[e]
        for j in range(2 * self.m):
            pass  # O(m) python loops only in validate(); used on tiny graphs
        assert np.all(self.El[:, 0] < self.El[:, 1])


def edges_from_arrays(src: np.ndarray, dst: np.ndarray, n: Optional[int] = None) -> np.ndarray:
    """Canonicalize a (possibly directed, loopy, duplicated) edge array.

    Returns unique undirected edges as an (m, 2) int64 array with u < v —
    the paper's preprocessing ("made undirected ... removed self loops and
    duplicate edges").
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    if n is None:
        n = int(max(lo.max(initial=-1), hi.max(initial=-1)) + 1) if lo.size else 0
    key = np.unique(edge_keys(lo, hi, n))
    return np.stack([key // n, key % n], axis=1)


def build_csr(edges: np.ndarray, n: Optional[int] = None) -> CSRGraph:
    """Build the full Fig. 2 structure from canonical (m,2) u<v edges."""
    edges = np.asarray(edges)
    if edges.size == 0:
        n = int(n or 0)
        return CSRGraph(
            n=n, m=0,
            Es=np.zeros(n + 1, np.int32), N=np.zeros(0, np.int32),
            Eid=np.zeros(0, np.int32), El=np.zeros((0, 2), np.int32),
            Eo=np.zeros(n, np.int32),
        )
    assert edges.ndim == 2 and edges.shape[1] == 2
    assert np.all(edges[:, 0] < edges[:, 1]), "edges must be canonical u < v"
    if n is None:
        n = int(edges.max() + 1)
    m = edges.shape[0]

    # Edge ids follow lexicographic (u, v) order so that "lower edge id" is a
    # stable total order (the tie-break used in concurrent triangle processing).
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    El = edges[order].astype(np.int32)

    # Symmetrize with edge ids attached to both directions.
    eid = np.arange(m, dtype=np.int32)
    src = np.concatenate([El[:, 0], El[:, 1]])
    dst = np.concatenate([El[:, 1], El[:, 0]])
    ids = np.concatenate([eid, eid])

    # CSR by (src, dst) sort.
    perm = np.lexsort((dst, src))
    src, dst, ids = src[perm], dst[perm], ids[perm]
    counts = np.bincount(src, minlength=n).astype(np.int64)
    Es = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=Es[1:])

    # Eo: first slot with neighbor > row vertex (adjacency sorted ascending).
    rows = np.arange(n, dtype=np.int64)
    Eo = Es[:-1] + np.array(
        [np.searchsorted(dst[Es[u]:Es[u + 1]], u, side="right") for u in rows],
        dtype=np.int64,
    ) if n < (1 << 15) else _eo_vectorized(Es, dst, n)

    g = CSRGraph(
        n=n, m=m,
        Es=Es.astype(np.int32),
        N=dst.astype(np.int32),
        Eid=ids.astype(np.int32),
        El=El,
        Eo=Eo.astype(np.int32),
    )
    return g


def _eo_vectorized(Es: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Vectorized Eo: count of neighbors < row vertex, offset by row start."""
    row_of_slot = np.repeat(np.arange(n, dtype=np.int64), np.diff(Es))
    less = dst < row_of_slot
    cnt = np.bincount(row_of_slot[less], minlength=n)
    return Es[:-1] + cnt


def relabel(edges: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Relabel endpoints by perm (old id -> new id) and re-canonicalize.

    Used for k-core ordering (KCO): perm[v] = rank of v in increasing coreness
    order, so after relabel the id orientation coincides with core orientation.
    """
    e = perm[edges]
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    return np.stack([lo, hi], axis=1)


def degeneracy_order(edges: np.ndarray, n: int) -> np.ndarray:
    """Coreness-based vertex permutation: perm[v] = new id of vertex v.

    Vertices sorted by (coreness, id). Matches the paper's preprocessing
    ("doing a k-core decomposition and then reordering vertices").
    """
    from repro.core.kcore import kcore_numpy  # local import to avoid cycle

    g = build_csr(edges, n)
    core = kcore_numpy(g)
    order = np.lexsort((np.arange(n), core))  # stable by id within coreness
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    return perm


def degree_order(edges: np.ndarray, n: int) -> np.ndarray:
    """Degree-based vertex permutation (cheaper alternative ordering)."""
    deg = np.bincount(edges.ravel(), minlength=n)
    order = np.lexsort((np.arange(n), deg))
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    return perm
