"""Deterministic named graphs for tests and the benchmark suite."""

from __future__ import annotations

import numpy as np

from repro.graphs.csr import edges_from_arrays
from repro.graphs import gen


def paper_fig1_edges() -> np.ndarray:
    """The example graph of the paper's Figure 1 (reconstructed).

    Two triangle-rich lobes joined by a 2-truss bridge: all vertices have
    coreness 3, two edges have trussness 2, the rest trussness 3, and there are
    two 3-trusses. Construction: two K4-minus-an-edge... we use two diamonds
    (4-cycles with one chord each give trussness 3 on all edges) linked by two
    bridge edges of trussness 2.
    """
    # Lobe A: vertices 0..3, edges of K4 minus (1,2)? K4 has every edge in 2
    # triangles -> trussness 4. For trussness 3 on all edges use a "diamond":
    # cycle 0-1-2-3 with chord 0-2: edges (0,1),(1,2),(2,3),(0,3),(0,2) —
    # chord in 2 triangles, rim edges in 1 -> 3-truss requires >=1 triangle/edge.
    a = [(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)]
    b = [(4, 5), (5, 6), (6, 7), (4, 7), (4, 6)]
    bridges = [(3, 4), (2, 5)]
    e = np.array(a + b + bridges, dtype=np.int64)
    return edges_from_arrays(e[:, 0], e[:, 1], 8)


def karate_like_edges() -> np.ndarray:
    """A fixed small social-like graph (deterministic, 34 vertices)."""
    rng = np.random.default_rng(34)
    # planted: two communities of 17 with dense intra, sparse inter edges
    src, dst = [], []
    for base in (0, 17):
        for i in range(17):
            for j in range(i + 1, 17):
                if rng.random() < 0.45:
                    src.append(base + i)
                    dst.append(base + j)
    for _ in range(10):
        src.append(int(rng.integers(0, 17)))
        dst.append(int(rng.integers(17, 34)))
    return edges_from_arrays(np.array(src), np.array(dst), 34)


def triangle_edges() -> np.ndarray:
    return np.array([[0, 1], [0, 2], [1, 2]], dtype=np.int64)


def k4_edges() -> np.ndarray:
    return np.array([[0, 1], [0, 2], [0, 3], [1, 2], [1, 3], [2, 3]], dtype=np.int64)


def path_edges(n: int = 5) -> np.ndarray:
    return np.stack([np.arange(n - 1), np.arange(1, n)], axis=1).astype(np.int64)


def named_graph(name: str) -> np.ndarray:
    if name == "fig1":
        return paper_fig1_edges()
    if name == "karate_like":
        return karate_like_edges()
    if name == "triangle":
        return triangle_edges()
    if name == "k4":
        return k4_edges()
    if name == "path":
        return path_edges()
    kind, _, size = name.partition("-")
    return gen.random_graph_edges(kind, size or "small")


#: The benchmark suite mirroring the paper's Table 1 *structure* (ordered by
#: rising wedge count; mixes social-like skew with flat and deep-truss
#: shapes). Sized for a single-core CPU run of the full harness.
GRAPH_SUITE = [
    "cliques-tiny",
    "er-small",
    "ba-small",
    "rmat-small",
    "cliques-small",
    "ba-medium",
]

#: Larger suite for headline benchmarks (kept laptop-tractable).
GRAPH_SUITE_LARGE = GRAPH_SUITE + [
    "er-medium", "rmat-medium", "cliques-medium", "ba-large", "rmat-large"]
