"""Synthetic graph generators (offline stand-ins for the paper's SNAP/UFL suite).

The paper's 15 graphs are social networks and web crawls with skewed degree
distributions. Offline we mirror the *shape statistics* that drive the
algorithms (skew → wedge/triangle ratio, coreness spread):

  - RMAT         : skewed, social-network-like (the Graph500 generator)
  - Erdős–Rényi  : flat degrees, low clustering (adversarial for ordering wins)
  - Barabási–Albert : power-law-ish, moderate clustering
  - ring of cliques  : high trussness, deep peeling (web-crawl-like t_max)

All generators return canonical (m,2) int64 u<v unique edge arrays.
"""

from __future__ import annotations

import numpy as np

from repro.graphs.csr import edges_from_arrays


def rmat_edges(
    scale: int,
    edge_factor: int = 8,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> np.ndarray:
    """Graph500-style R-MAT: 2^scale vertices, ~edge_factor * 2^scale edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    a_norm = a / ab
    c_norm = c / (1.0 - ab)
    for _ in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        go_down = r1 >= ab
        go_right = np.where(go_down, r2 >= c_norm, r2 >= a_norm)
        src = 2 * src + go_down
        dst = 2 * dst + go_right
    return edges_from_arrays(src, dst, n)


def erdos_renyi_edges(n: int, avg_degree: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    m_target = int(n * avg_degree / 2)
    src = rng.integers(0, n, size=2 * m_target)
    dst = rng.integers(0, n, size=2 * m_target)
    e = edges_from_arrays(src, dst, n)
    if e.shape[0] > m_target:
        sel = rng.choice(e.shape[0], size=m_target, replace=False)
        e = e[np.sort(sel)]
    return e


def barabasi_albert_edges(n: int, m_attach: int, seed: int = 0) -> np.ndarray:
    """Preferential attachment via the repeated-nodes trick (vectorized-ish)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list[int] = []
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(m_attach, n):
        for t in targets:
            src_l.append(v)
            dst_l.append(t)
        repeated.extend(targets)
        repeated.extend([v] * m_attach)
        idx = rng.integers(0, len(repeated), size=m_attach)
        targets = list({repeated[i] for i in idx})
        while len(targets) < m_attach:
            targets.append(int(rng.integers(0, v + 1)))
            targets = list(set(targets))
    return edges_from_arrays(np.array(src_l), np.array(dst_l), n)


def ring_of_cliques_edges(n_cliques: int, clique_size: int, seed: int = 0) -> np.ndarray:
    """n_cliques cliques of clique_size vertices, chained in a ring.

    Every intra-clique edge has trussness = clique_size; bridge edges have
    trussness 2 — a deterministic ground-truth-rich instance.
    """
    del seed
    src_l: list[int] = []
    dst_l: list[int] = []
    for ci in range(n_cliques):
        base = ci * clique_size
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                src_l.append(base + i)
                dst_l.append(base + j)
        nxt = ((ci + 1) % n_cliques) * clique_size
        src_l.append(base)
        dst_l.append(nxt)
    n = n_cliques * clique_size
    return edges_from_arrays(np.array(src_l), np.array(dst_l), n)


def random_graph_edges(kind: str, size: str, seed: int = 0) -> np.ndarray:
    """Convenience dispatcher used by benchmarks: kind x {tiny,small,medium,large}."""
    if kind == "rmat":
        scale = {"tiny": 8, "small": 12, "medium": 15, "large": 17}[size]
        return rmat_edges(scale, edge_factor=8, seed=seed)
    if kind == "er":
        n = {"tiny": 256, "small": 4096, "medium": 32768, "large": 131072}[size]
        return erdos_renyi_edges(n, avg_degree=16.0, seed=seed)
    if kind == "ba":
        n = {"tiny": 256, "small": 4096, "medium": 32768, "large": 131072}[size]
        return barabasi_albert_edges(n, m_attach=8, seed=seed)
    if kind == "cliques":
        k = {"tiny": (8, 8), "small": (64, 12), "medium": (256, 16), "large": (512, 24)}[size]
        return ring_of_cliques_edges(*k)
    raise ValueError(f"unknown graph kind {kind!r}")
