"""Graph substrate: generators, CSR construction, datasets.

All graphs are undirected simple graphs held in the paper's (Fig. 2) layout:
CSR ``(Es, N)`` plus ``Eid`` (edge id per adjacency slot), ``El`` (edge list,
u < v), ``Eo`` (first adjacency slot whose neighbor is > the row vertex).
"""

from repro.graphs.csr import CSRGraph, build_csr, relabel, edges_from_arrays
from repro.graphs.gen import (
    rmat_edges,
    erdos_renyi_edges,
    barabasi_albert_edges,
    ring_of_cliques_edges,
)
from repro.graphs.datasets import named_graph, GRAPH_SUITE

__all__ = [
    "CSRGraph",
    "build_csr",
    "relabel",
    "edges_from_arrays",
    "rmat_edges",
    "erdos_renyi_edges",
    "barabasi_albert_edges",
    "ring_of_cliques_edges",
    "named_graph",
    "GRAPH_SUITE",
]
